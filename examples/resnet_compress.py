"""Paper Sec. IV-B: ResNet conv compression with FK/PK x FP/FS (Table I).

Reduced pre-act ResNet on procedural textures (CPU container; the ResNet-34
config itself is exercised with sampled channels).

    PYTHONPATH=src python examples/resnet_compress.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import CompressionConfig, compress_conv_kernel
from repro.core.cost import ModelCostReport
from repro.data.synthetic import batches, textures_like
from repro.models.resnet import (conv_kernels, init_resnet, resnet_forward,
                                 resnet_loss, resnet_small_config)


def main() -> None:
    cfg = resnet_small_config(classes=6)
    xs, ys = textures_like(512, size=24, classes=6, seed=0)
    xte, yte = textures_like(128, size=24, classes=6, seed=1)
    params = init_resnet(jax.random.PRNGKey(0), cfg)
    from repro.optim.optimizers import sgd
    opt = sgd(momentum=0.9)
    state = opt.init(params)
    grad = jax.jit(jax.value_and_grad(resnet_loss))
    print("== training reduced pre-act ResNet on textures ==")
    for ep in range(12):
        for xb, yb in batches(xs, ys, 64, seed=ep):
            loss, g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = opt.update(g, state, params, 0.05)
    logits = resnet_forward(params, jnp.asarray(xte))
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean())
    print(f"   accuracy {acc:.3f}")

    print("== Table I grid: conv representation x LCC algorithm ==")
    print("method,alg,adds_ratio")
    for conv_method in ("fk", "pk"):
        for alg in ("fp", "fs"):
            rep = ModelCostReport()
            for name, k in conv_kernels(params)[1:]:
                compress_conv_kernel(name, np.asarray(k, np.float64),
                                     CompressionConfig(algorithm=alg,
                                                       conv_method=conv_method,
                                                       weight_sharing=False), rep)
            print(f"{conv_method},{alg},{rep.ratio('lcc'):.2f}")


if __name__ == "__main__":
    main()
