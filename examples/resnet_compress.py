"""Paper Sec. IV-B: ResNet conv compression with FK/PK x FP/FS (Table I),
driven through the unified pipeline API (``api.compress_model`` -> the
``CompressedModel`` artifact; per-channel conv jobs fan out over workers).

Reduced pre-act ResNet on procedural textures (CPU container; the ResNet-34
config itself is exercised with sampled channels).

    PYTHONPATH=src python examples/resnet_compress.py [--workers 2]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.core.compress import CompressionConfig
from repro.core.artifact import CompressedModel
from repro.data.synthetic import batches, textures_like
from repro.models import api
from repro.models.resnet import (init_resnet, resnet_forward, resnet_loss,
                                 resnet_small_config)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2,
                    help="pipeline worker processes")
    args = ap.parse_args()

    cfg = resnet_small_config(classes=6)
    xs, ys = textures_like(512, size=24, classes=6, seed=0)
    xte, yte = textures_like(128, size=24, classes=6, seed=1)
    params = init_resnet(jax.random.PRNGKey(0), cfg)
    from repro.optim.optimizers import sgd
    opt = sgd(momentum=0.9)
    state = opt.init(params)
    grad = jax.jit(jax.value_and_grad(resnet_loss))
    print("== training reduced pre-act ResNet on textures ==")
    for ep in range(12):
        for xb, yb in batches(xs, ys, 64, seed=ep):
            loss, g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = opt.update(g, state, params, 0.05)
    logits = resnet_forward(params, jnp.asarray(xte))
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean())
    print(f"   accuracy {acc:.3f}")

    print(f"== Table I grid: conv representation x LCC algorithm "
          f"({args.workers} workers) ==")
    print("method,alg,adds_ratio,wall_s")
    art = None
    for conv_method in ("fk", "pk"):
        for alg in ("fp", "fs"):
            # the residual blocks only, like Table I (stem/head excluded)
            art = api.compress_model(
                params, cfg,
                CompressionConfig(algorithm=alg, conv_method=conv_method,
                                  weight_sharing=False),
                include="block", n_workers=args.workers, build_packed=False)
            print(f"{conv_method},{alg},{art.report.ratio('lcc'):.2f},"
                  f"{art.pipeline_stats['wall_s']}")

    print("== artifact round-trip: conv records + effective kernels ==")
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        art = CompressedModel.load(d)
    logits_c = resnet_forward(art.params, jnp.asarray(xte))
    acc_c = float((jnp.argmax(logits_c, -1) == jnp.asarray(yte)).mean())
    print(f"   reloaded {len(art.records)} conv units; accuracy "
          f"{acc:.3f} -> {acc_c:.3f} with effective kernels")


if __name__ == "__main__":
    main()
