"""Paper Sec. IV-A end to end: MLP-300 + Algorithm 1 (regularized training ->
affinity-propagation weight sharing -> LCC) on the unified pipeline API, with
compressed-accuracy checks via the serializable ``CompressedModel`` artifact.

    PYTHONPATH=src python examples/mlp_mnist_compress.py [--lam 0.1] \
        [--epochs 10] [--workers 2]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core.artifact import CompressedModel
from repro.data.synthetic import batches, digits_like
from repro.models import api
from repro.models.mlp import MLPConfig, init_mlp, mlp_accuracy, mlp_loss
from repro.optim.optimizers import prox_sgd, step_decay


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=300)
    ap.add_argument("--algorithm", choices=["fp", "fs"], default="fs")
    ap.add_argument("--workers", type=int, default=2,
                    help="pipeline worker processes")
    args = ap.parse_args()

    print("== 1. regularized training (ProxSGD, eq. (7)/(8)) ==")
    cfg = MLPConfig(hidden=args.hidden)
    xs, ys = digits_like(2048, seed=0)
    xte, yte = digits_like(512, seed=1)
    params = init_mlp(jax.random.PRNGKey(0), hidden=cfg.hidden)
    opt = prox_sgd(momentum=0.9, prox_spec={"fc1/w": (args.lam, "columns")})
    state = opt.init(params)
    lr = step_decay(0.1, 0.95, 10)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    for ep in range(args.epochs):
        for xb, yb in batches(xs, ys, 128, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
    acc = float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte)))
    w1 = np.asarray(params["fc1"]["w"], np.float64)
    kept = int((np.linalg.norm(w1, axis=0) > 1e-8).sum())
    print(f"   accuracy {acc:.3f};  input neurons kept {kept}/{cfg.in_dim}")

    print("== 2+3. weight sharing + LCC via the parallel pipeline "
          f"({args.workers} workers) ==")
    art = api.compress_model(
        params, cfg, core.CompressionConfig(algorithm=args.algorithm),
        include="fc1", n_workers=args.workers)
    lc = art.report.layers[0]
    print(f"   clusters: {lc.extra['clusters']}  achieved SNR: "
          f"{lc.extra['achieved_snr_db']:.1f} dB  "
          f"({art.pipeline_stats['jobs']} slice jobs, "
          f"{art.pipeline_stats['wall_s']}s)")
    print(art.report.table())

    print("== 4. artifact round-trip: compress once, evaluate from disk ==")
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        art = CompressedModel.load(d)
    # the artifact's params carry fc1's dense-effective map — drop-in forward
    acc_c = float(mlp_accuracy(art.params, jnp.asarray(xte), jnp.asarray(yte)))
    print(f"== result: accuracy {acc:.3f} -> {acc_c:.3f} compressed; "
          f"adds ratio {lc.ratio('lcc'):.1f}x ==")


if __name__ == "__main__":
    main()
