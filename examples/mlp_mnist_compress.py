"""Paper Sec. IV-A end to end — the complete Algorithm-1 loop:

1. compression-aware regularized training: ProxSGD (eq. (7)/(8)) whose group
   layout is derived from the SAME adapter sites the compressor later slices,
   so regularization and compression can never disagree;
2. prune-aware parallel compression: exactly-zero input groups become 0-add
   skipped slice jobs, partially-dead slices shrink;
3. post-compression recovery fine-tuning: a dense residual trained on top of
   the frozen shift-add chains, written back into the artifact;
4. serving from the (saved + reloaded) ``CompressedModel`` artifact.

    PYTHONPATH=src python examples/mlp_mnist_compress.py [--lam 0.1] \
        [--epochs 12] [--workers 2] [--recover 60]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

import repro.core as core
from repro.core.artifact import CompressedModel
from repro.data.mnist_like import train_test
from repro.data.synthetic import batches
from repro.models import api
from repro.models.mlp import MLPConfig, init_mlp, mlp_accuracy, mlp_loss
from repro.optim.optimizers import prox_sgd, step_decay
from repro.training import regularize
from repro.training.recover import recover_artifact


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--hidden", type=int, default=300)
    ap.add_argument("--algorithm", choices=["fp", "fs"], default="fp")
    ap.add_argument("--workers", type=int, default=2,
                    help="pipeline worker processes")
    ap.add_argument("--recover", type=int, default=60,
                    help="recovery fine-tune steps (0 disables)")
    ap.add_argument("--budget", type=int, default=None,
                    help="global additions budget (allocator)")
    args = ap.parse_args()

    print("== 1. compression-aware regularized training (ProxSGD, eq. (7)) ==")
    cfg = MLPConfig(hidden=args.hidden)
    (xs, ys), (xte, yte) = train_test(4000, 1000, seed=0)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    params = init_mlp(jax.random.PRNGKey(0), hidden=cfg.hidden)
    specs = regularize.site_group_specs(params, cfg, args.lam, include="fc1")
    opt = prox_sgd(momentum=0.9, specs=specs)
    state = opt.init(params)
    lr = step_decay(0.08, 0.95, 3)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    for ep in range(args.epochs):
        for xb, yb in batches(xs, ys, 128, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
    acc = float(mlp_accuracy(params, xte_j, yte_j))
    rep = regularize.sparsity_report(params, specs)
    print(f"   accuracy {acc:.3f};  dead input groups "
          f"{regularize.dead_group_fraction(rep):.1%}")

    print(f"== 2. prune-aware compression ({args.workers} workers) ==")
    comp = core.CompressionConfig(algorithm=args.algorithm,
                                  weight_sharing=False, prune_tol=-1e-6,
                                  snr_offset_db=-12.0)
    art = api.compress_model(params, cfg, comp, n_workers=args.workers,
                             budget_adds=args.budget)
    ps = art.pipeline_stats
    print(f"   adds {art.report.total_baseline()} -> "
          f"{art.report.total_stage('lcc')};  dead groups "
          f"{ps['dead_groups']}, skipped {ps['skipped_jobs']} / shrunk "
          f"{ps['shrunk_jobs']} of {ps['jobs']} slice jobs")
    acc_c = float(mlp_accuracy(art.params, xte_j, yte_j))

    acc_r = acc_c
    if args.recover:
        print(f"== 3. recovery fine-tuning ({args.recover} steps) ==")

        def loss_fn(p, b):
            return mlp_loss(p, b[0], b[1])

        def rec_batches():
            n, ep = 0, 0
            while n < args.recover:
                for xb, yb in batches(xs, ys, 128, seed=1000 + ep):
                    if n >= args.recover:
                        return
                    yield jnp.asarray(xb), jnp.asarray(yb)
                    n += 1
                ep += 1

        res = recover_artifact(art, loss_fn, rec_batches(), lr=2e-3)
        acc_r = float(mlp_accuracy(art.params, xte_j, yte_j))
        extra = sum(u.get("recover_adds", 0) for u in res["units"].values())
        print(f"   loss {res['losses'][0]:.4f} -> {res['losses'][-1]:.4f};  "
              f"residual adds +{extra}")

    print("== 4. artifact round-trip: serve the recovered model from disk ==")
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        art = CompressedModel.load(d)
    acc_d = float(mlp_accuracy(art.params, xte_j, yte_j))
    print(f"== result: dense {acc:.3f} -> compressed {acc_c:.3f} -> "
          f"recovered {acc_r:.3f} (from disk {acc_d:.3f});  adds ratio "
          f"{art.report.ratio('lcc'):.2f}x ==")


if __name__ == "__main__":
    main()
