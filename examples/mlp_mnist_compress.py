"""Paper Sec. IV-A end to end: MLP-300 + Algorithm 1 (regularized training ->
affinity-propagation weight sharing -> LCC), with compressed-accuracy checks.

    PYTHONPATH=src python examples/mlp_mnist_compress.py [--lam 0.1] [--epochs 10]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.data.synthetic import batches, digits_like
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss
from repro.optim.optimizers import prox_sgd, step_decay


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--hidden", type=int, default=300)
    ap.add_argument("--algorithm", choices=["fp", "fs"], default="fs")
    args = ap.parse_args()

    print("== 1. regularized training (ProxSGD, eq. (7)/(8)) ==")
    xs, ys = digits_like(2048, seed=0)
    xte, yte = digits_like(512, seed=1)
    params = init_mlp(jax.random.PRNGKey(0), hidden=args.hidden)
    opt = prox_sgd(momentum=0.9, prox_spec={"fc1/w": (args.lam, "columns")})
    state = opt.init(params)
    lr = step_decay(0.1, 0.95, 10)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    for ep in range(args.epochs):
        for xb, yb in batches(xs, ys, 128, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
    acc = float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte)))
    w1 = np.asarray(params["fc1"]["w"], np.float64)
    kept = int((np.linalg.norm(w1, axis=0) > 1e-8).sum())
    print(f"   accuracy {acc:.3f};  input neurons kept {kept}/784")

    print("== 2+3. weight sharing + LCC (Algorithm 1 steps 2-3) ==")
    rep = core.ModelCostReport()
    cd = core.compress_dense_matrix(
        "fc1", w1, core.CompressionConfig(algorithm=args.algorithm), rep)
    lc = rep.layers[0]
    print(f"   clusters: {lc.extra['clusters']}  achieved SNR: "
          f"{lc.extra['achieved_snr_db']:.1f} dB")
    print(rep.table())

    eff = np.zeros_like(w1)
    eff[:, cd.kept_columns] = cd.effective
    fc1 = lambda x: x @ jnp.asarray(eff, jnp.float32).T  # noqa: E731
    acc_c = float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte),
                               fc1_matvec=fc1))
    print(f"== result: accuracy {acc:.3f} -> {acc_c:.3f} compressed; "
          f"adds ratio {lc.ratio('lcc'):.1f}x ==")


if __name__ == "__main__":
    main()
