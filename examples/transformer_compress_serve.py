"""End-to-end driver for the unified compression API: train a small LM, run
the paper's Algorithm 1 over every compressible unit (``api.compress_model``
via the family adapter registry), save/load the resulting ``CompressedModel``
artifact through the msgpack+crc32 checkpointer, and SERVE batched requests
with EVERY compressed site — FFN and attention projections — executing on the
fused LCC kernel path *inside* the jitted decode step
(``ServingEngine(artifact=...)`` builds a site-keyed ``CompressedExecutor``).

    train -> compress_model -> CompressedModel.save -> load -> serve

    PYTHONPATH=src python examples/transformer_compress_serve.py [--steps 60]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_config
from repro.core import CompressionConfig
from repro.core.artifact import CompressedModel
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.optim.optimizers import sgd
from repro.serving.engine import ServingEngine
from repro.training.trainer import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="olmo-1b")
    args = ap.parse_args()

    cfg = reduced_config(get_arch(args.arch), vocab=64, n_layers=2, d_model=64,
                         d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16)
    lm = MarkovLM(vocab=64, k=4, seed=0)
    print(f"== 1. train {args.arch}-reduced on a Markov stream "
          f"(entropy {lm.entropy:.2f} nats/token) ==")
    opt = sgd(momentum=0.9)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, lr=0.3))
    for i in range(args.steps):
        b = lm.batch(8, 32, seed=i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"   step {i:3d}  loss {float(m['loss']):.3f}")
    params = state.params

    print("== 2. Algorithm 1 over every FFN + attention projection ==")
    # every compressed site executes as fused kernel launches at serve time —
    # pass include="ffn." to restrict compression to the FFN projections
    art = api.compress_model(
        params, cfg,
        CompressionConfig(algorithm="fp", weight_sharing=True,
                          max_share_rel_err=0.06))
    print(art.report.table())

    print("== 3. artifact round-trip: compress once offline, serve many ==")
    with tempfile.TemporaryDirectory() as d:
        art.save(d)
        art = CompressedModel.load(d)
    n_packed = sum(1 for p in art.packed.values() if p.col_slices)
    print(f"   reloaded {len(art.records)} compressed units "
          f"({n_packed} with fused FP kernel buffers)")

    print("== 4. serve batched requests: original vs compressed-kernel ==")
    prompts = [lm.sample(1, 8, seed=100 + i)[0, :8].tolist() for i in range(6)]
    eng = ServingEngine(params, cfg, n_slots=4, max_len=64)
    eng_c = ServingEngine(artifact=art, n_slots=4, max_len=64)
    assert eng_c.executor is not None  # every site on the kernel path
    res = eng.generate(prompts, max_new_tokens=12)
    res_c = eng_c.generate(prompts, max_new_tokens=12)
    assert eng_c.executor.routed == eng_c.executor.sites  # all sites fused
    agree = np.mean([np.mean(np.array(a.tokens[a.prompt_len:])
                             == np.array(b.tokens[b.prompt_len:]))
                     for a, b in zip(res, res_c)])
    # token validity: generated tokens follow the chain's transition structure
    def validity(rs):
        ok = tot = 0
        for r in rs:
            for t in range(len(r.tokens) - 1):
                ok += r.tokens[t + 1] in lm.succ[r.tokens[t]]
                tot += 1
        return ok / tot
    print(f"   greedy-token agreement original vs compressed: {agree:.2%}")
    print(f"   chain-validity original {validity(res):.2%} | "
          f"compressed {validity(res_c):.2%}")
    print(f"   total adds ratio (all compressed sites): {art.report.ratio('lcc'):.1f}x")


if __name__ == "__main__":
    main()
