"""Quickstart: LCC-compress a matrix, count adds, run it through the TPU kernel.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.csd import adds_csd_matrix
from repro.core.lcc import lcc_decompose
from repro.kernels import ops


def main() -> None:
    rng = np.random.default_rng(0)
    w = rng.standard_normal((300, 16))  # a tall matrix — LCC's sweet spot

    baseline = adds_csd_matrix(w, frac_bits=8)
    print(f"CSD shift-add baseline:        {baseline} additions")

    for alg in ("fp", "fs"):
        dec = lcc_decompose(w, algorithm=alg, frac_bits=8)
        print(f"LCC-{alg.upper()}: {dec.num_adds()} additions "
              f"(ratio {baseline / dec.num_adds():.2f}x, "
              f"SNR {dec.meta['achieved_snr_db']:.1f} dB)")

    # run the FP decomposition through the Pallas kernel (interpret mode here;
    # on TPU the compact factors stream HBM->VMEM and feed the MXU)
    dec = lcc_decompose(w, algorithm="fp", frac_bits=8)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y_kernel = ops.apply_packed_decomposition(packed, x)
    y_exact = jnp.asarray(w, jnp.float32) @ x
    rel = float(jnp.linalg.norm(y_kernel - y_exact) / jnp.linalg.norm(y_exact))
    print(f"kernel apply vs exact W@x: relative error {rel:.2e} "
          f"(the LCC approximation error, by design ~CSD-quantization level)")


if __name__ == "__main__":
    main()
