"""Paper Fig. 2: compression-accuracy tradeoff of the MLP first layer.

Reproduction on the procedural-digits stand-in (no MNIST offline — DESIGN.md):
for each lambda, train MLP-300 with ProxSGD (eq. (7)), then report the
compression ratio after (a) pruning only, (b) + weight sharing, (c) + LCC —
the dots / crosses / triangles of Fig. 2 — plus the two claims quantified in
Sec. IV-A: LCC-on-pruned gain (paper: 2.4-3.1x) and LCC-direct-on-unpruned
gain (paper: ~2x) whose quotient is the "combining gain" (paper: up to ~50%).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core.csd import adds_csd_matrix
from repro.core.lcc import lcc_decompose
from repro.data.synthetic import batches, digits_like
from repro.models.mlp import init_mlp, mlp_accuracy, mlp_loss
from repro.optim.optimizers import prox_sgd, step_decay

LAMBDAS = (0.05, 0.1, 0.2)
EPOCHS = 10


def train_one(lam: float, hidden: int = 300, epochs: int = EPOCHS):
    xs, ys = digits_like(2048, seed=0)
    xte, yte = digits_like(512, seed=1)
    params = init_mlp(jax.random.PRNGKey(0), hidden=hidden, classes=10)
    opt = prox_sgd(momentum=0.9, prox_spec={"fc1/w": (lam, "columns")})
    state = opt.init(params)
    lr = step_decay(0.1, 0.95, 10)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    for ep in range(epochs):
        for xb, yb in batches(xs, ys, 128, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
    acc = float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte)))
    return params, acc, (jnp.asarray(xte), jnp.asarray(yte))


def run(csv_rows: list[str]) -> None:
    t0 = time.time()
    # the paper's baseline: the UNregularized model's first layer in CSD
    params0, acc0, _ = train_one(0.0)
    w0 = np.asarray(params0["fc1"]["w"], np.float64)
    baseline = adds_csd_matrix(w0, 8)
    # paper Sec. IV-A reference point: LCC directly on the unpruned matrix ~ 2x
    d_direct = lcc_decompose(w0, algorithm="fp", frac_bits=8)
    direct_ratio = baseline / max(d_direct.num_adds(), 1)
    csv_rows.append(f"fig2_mlp,baseline,acc={acc0:.3f},adds={baseline},"
                    f"direct_lcc_ratio={direct_ratio:.2f}")
    print(csv_rows[-1], flush=True)

    for lam in LAMBDAS:
        params, acc, (xte, yte) = train_one(lam)
        w1 = np.asarray(params["fc1"]["w"], np.float64)
        rep = core.ModelCostReport()
        cd = core.compress_dense_matrix(
            "fc1", w1, core.CompressionConfig(algorithm="fs"), rep)
        lc = rep.layers[0]
        # compressed accuracy (the y-axis of Fig. 2)
        eff = np.zeros_like(w1)
        eff[:, cd.kept_columns] = cd.effective
        fc1 = lambda x, m=eff: x @ jnp.asarray(m, jnp.float32).T  # noqa: E731
        acc_lcc = float(mlp_accuracy(params, xte, yte, fc1_matvec=fc1))
        # all ratios vs the common unregularized baseline (paper protocol)
        r_pruned = baseline / max(lc.stage_adds["pruned"], 1)
        r_shared = baseline / max(lc.stage_adds["shared"], 1)
        r_lcc = baseline / max(lc.stage_adds["lcc"], 1)
        lcc_gain_on_pruned = lc.stage_adds["shared"] / max(lc.stage_adds["lcc"], 1)
        combining_gain = lcc_gain_on_pruned / max(direct_ratio, 1e-9)
        row = (f"fig2_mlp,lam={lam},acc={acc:.3f},kept={lc.extra['kept_cols']},"
               f"clusters={lc.extra['clusters']},ratio_pruned={r_pruned:.2f},"
               f"ratio_shared={r_shared:.2f},ratio_lcc={r_lcc:.2f},"
               f"acc_lcc={acc_lcc:.3f},lcc_gain_on_pruned={lcc_gain_on_pruned:.2f},"
               f"combining_gain={combining_gain:.2f}")
        print(row, flush=True)
        csv_rows.append(row)
    csv_rows.append(f"fig2_mlp_wall_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    run([])
