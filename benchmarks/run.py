"""Benchmark harness: one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call_or_value,derived`` CSV lines (harness contract) and
writes them to benchmarks/results.csv.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_fig2_mlp, bench_kernels, bench_lcc_scaling,
                            bench_table1_resnet, roofline)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = {
        "kernels": bench_kernels.run,
        "lcc_scaling": bench_lcc_scaling.run,
        "fig2": bench_fig2_mlp.run,
        "table1": bench_table1_resnet.run,
        "roofline": roofline.run,
    }
    rows: list[str] = ["name,value,derived"]
    t0 = time.time()
    for name, fn in suites.items():
        if only and only != name:
            continue
        print(f"== {name} ==", flush=True)
        fn(rows)
    rows.append(f"total_wall_s,{time.time() - t0:.1f},")
    with open("benchmarks/results.csv", "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"\nwrote benchmarks/results.csv ({len(rows)} rows, "
          f"{time.time() - t0:.0f}s total)")


if __name__ == "__main__":
    main()
