"""Compression-pipeline benchmark: offline Algorithm-1 throughput (units/sec
and wall-clock) vs worker count, plus the content-addressed cache-hit
speedup — emitted as machine-readable ``BENCH_compress.json`` so the offline
path's perf trajectory is tracked across PRs like the serving loop's.

    PYTHONPATH=src python benchmarks/bench_compress_pipeline.py [--smoke] [--out F]

CPU-container numbers measure pipeline orchestration + numpy matching-pursuit
throughput on the host's cores (2 here, so the parallel ceiling is ~2x even
at 4 workers); the cross-PR signal is the wall-clock trend of the identical
workload and the cache-hit speedup.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import tempfile
import time


def bench_run(units, cfg, n_workers: int, cache_dir: str | None) -> dict:
    from repro.pipeline import run_pipeline

    t0 = time.time()
    res = run_pipeline(units, cfg, n_workers=n_workers, cache_dir=cache_dir)
    wall = time.time() - t0
    return {"n_workers": n_workers, "units": res.stats["units"],
            "jobs": res.stats["jobs"], "wall_s": round(wall, 3),
            "units_per_s": round(res.stats["units"] / wall, 3),
            "cache_hits": res.stats["cache_hits"],
            "lcc_adds": res.report.total_stage("lcc")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_compress.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-bounded: tiny model, ffn units only")
    args = ap.parse_args()

    import jax

    from repro import core
    from repro.configs import get_arch
    from repro.configs.base import reduced_config
    from repro.models import api

    if args.smoke:
        cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                             n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                             n_layers=2)
        include = "ffn."
    else:
        cfg = reduced_config(get_arch("olmo-1b"), d_model=64, n_heads=4,
                             n_kv_heads=4, head_dim=16, d_ff=128, vocab=64,
                             n_layers=2)
        include = None  # FFN + attention projections

    params = api.init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import compress_adapters
    sites = compress_adapters.sites_for(params, cfg)
    if include:
        sites = [s for s in sites if s.name.startswith(include)]
    units = compress_adapters.units_from_sites(params, sites)
    comp = core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                  max_share_rel_err=0.06)

    results = []
    ref_adds = None
    with tempfile.TemporaryDirectory() as tmp:
        # boot the forkserver + 4-worker pool once so pool startup doesn't
        # skew the measured rows (the pool persists across runs)
        bench_run(units[:1], comp, 4, None)
        for n_workers in (1, 4):
            cold = os.path.join(tmp, f"cold_{n_workers}")
            row = bench_run(units, comp, n_workers, cold)
            if ref_adds is None:
                ref_adds = row["lcc_adds"]
            # parallel output must match serial output exactly
            assert row["lcc_adds"] == ref_adds, "parallel != serial adds"
            results.append(row)
            print(f"workers={n_workers}: {row['wall_s']}s "
                  f"({row['units_per_s']} units/s, {row['jobs']} jobs)")
        # cache-hit speedup: identical run over the populated cold_4 cache
        warm = bench_run(units, comp, 4, os.path.join(tmp, "cold_4"))
        assert warm["lcc_adds"] == ref_adds

    cold4 = next(r for r in results if r["n_workers"] == 4)
    cold1 = next(r for r in results if r["n_workers"] == 1)
    report = {
        "bench": "compress_pipeline",
        "arch": cfg.name,
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "platform": platform.machine(),
        "units": cold4["units"],
        "jobs": cold4["jobs"],
        "results": results,
        "speedup_4v1": round(cold1["wall_s"] / cold4["wall_s"], 2),
        "cache": {
            "cold_s": cold4["wall_s"],
            "warm_s": warm["wall_s"],
            "speedup": round(cold4["wall_s"] / max(warm["wall_s"], 1e-9), 2),
            "warm_hits": warm["cache_hits"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"speedup 4v1 workers: {report['speedup_4v1']}x   "
          f"cache-hit speedup: {report['cache']['speedup']}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
