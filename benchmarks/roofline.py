"""Roofline report: render dryrun_results.json into the EXPERIMENTS.md tables.

Per (arch x shape x mesh): the three roofline terms, the dominant one, the
MODEL_FLOPS/HLO ratio, per-device memory, and a one-line bottleneck note.
"""
from __future__ import annotations

import json
import sys

NOTES = {
    "compute_s": "compute-bound: raise MXU utilization (larger per-chip tiles, "
                 "fewer remat passes) or accept — this is the roofline target",
    "memory_s": "HBM-bound: cut activation traffic (fusion, bf16 masks, "
                "flash-style attention) or raise arithmetic intensity",
    "collective_s": "ICI-bound: cut FSDP gathers (weight-stationary where it fits), "
                    "overlap collectives with compute, int8-compress cross-pod grads",
}


def fmt(v, digits=3):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e5:
            return f"{v:.2e}"
        return f"{v:.{digits}f}"
    return str(v)


def render(path: str = "dryrun_results.json", mesh: str | None = None,
           variant: str = "baseline") -> str:
    results = [r for r in json.load(open(path))
               if r.get("variant", "baseline") == variant]
    rows = []
    hdr = ("| arch | shape | mesh | status | compute_s | memory_s | collective_s "
           "| dominant | MODEL/HLO flops | roofline frac | bytes/dev (GB) |")
    sep = "|" + "---|" * 11
    rows += [hdr, sep]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        rl = r.get("roofline", {})
        mem = r.get("memory", {})
        gb = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)) / 2**30 \
            if mem else None
        status = r["status"] if r["status"] != "SKIP" else f"SKIP({r['reason'][:40]})"
        rows.append("| " + " | ".join([
            r["arch"], r["shape"], r["mesh"], status,
            fmt(rl.get("compute_s")), fmt(rl.get("memory_s")),
            fmt(rl.get("collective_s")), r.get("dominant", "-"),
            fmt(r.get("useful_flop_ratio")), fmt(r.get("roofline_fraction"), 4),
            fmt(gb, 2),
        ]) + " |")
    return "\n".join(rows)


def bottleneck_notes(path: str = "dryrun_results.json") -> str:
    results = json.load(open(path))
    out = []
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != "16x16" or "dominant" not in r \
                or r.get("variant", "baseline") != "baseline":
            continue
        out.append(f"- **{r['arch']} x {r['shape']}** — dominant {r['dominant']}: "
                   f"{NOTES[r['dominant']]}")
    return "\n".join(out)


def run(csv_rows: list[str]) -> None:
    try:
        results = json.load(open("dryrun_results.json"))
    except FileNotFoundError:
        csv_rows.append("roofline,skipped,no dryrun_results.json (run repro.launch.dryrun)")
        print(csv_rows[-1])
        return
    n_pass = sum(r["status"] == "PASS" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    csv_rows.append(f"dryrun_cells,{len(results)},pass={n_pass}/skip={n_skip}/fail={n_fail}")
    for r in results:
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        csv_rows.append(
            f"roofline,{r['arch']}|{r['shape']}|{r['mesh']},"
            f"compute={rl['compute_s']:.3g}s/memory={rl['memory_s']:.3g}s/"
            f"collective={rl['collective_s']:.3g}s/dom={r['dominant']}/"
            f"frac={r.get('roofline_fraction', 0):.4f}")
    for row in csv_rows[-min(len(csv_rows), 8):]:
        print(row, flush=True)


if __name__ == "__main__":
    print(render(*sys.argv[1:]))
