"""LCC structural claims (paper Sec. III-A): tall matrices are LCC-friendly,
unstructured sparsity hurts, FS beats FP on small/ill-conditioned matrices."""
from __future__ import annotations

import time

import numpy as np

from repro.core.csd import adds_csd_matrix
from repro.core.lcc import lcc_decompose


def run(csv_rows: list[str]) -> None:
    t0 = time.time()
    rng = np.random.default_rng(0)

    # claim 1: compression improves with aspect ratio (N/K) at fixed N
    for k in (64, 32, 16, 8):
        w = rng.standard_normal((256, k))
        base = adds_csd_matrix(w, 8)
        d = lcc_decompose(w, algorithm="fp", frac_bits=8)
        row = f"lcc_scaling,aspect,N=256,K={k},ratio={base / max(d.num_adds(), 1):.2f}"
        print(row, flush=True)
        csv_rows.append(row)

    # claim 2: unstructured sparsity degrades LCC vs structured (column) removal
    w = rng.standard_normal((256, 32))
    w_unstruct = w * (rng.random((256, 32)) > 0.5)  # random 50% zeros
    w_struct = w[:, :16]  # drop half the columns instead
    for name, m in (("dense", w), ("unstructured_50", w_unstruct),
                    ("structured_half", w_struct)):
        base = adds_csd_matrix(m, 8)
        d = lcc_decompose(m, algorithm="fp", frac_bits=8)
        row = f"lcc_scaling,sparsity={name},ratio={base / max(d.num_adds(), 1):.2f}"
        print(row, flush=True)
        csv_rows.append(row)

    # claim 3: FS >= FP on small / not-well-behaved (rank-deficient) matrices
    small = rng.standard_normal((48, 8))
    lowrank = (rng.standard_normal((48, 3)) @ rng.standard_normal((3, 8)))
    for name, m in (("small", small), ("rank3", lowrank)):
        dfp = lcc_decompose(m, algorithm="fp", target_snr_db=40.0)
        dfs = lcc_decompose(m, algorithm="fs", target_snr_db=40.0)
        row = (f"lcc_scaling,{name},fp_adds={dfp.num_adds()},fs_adds={dfs.num_adds()},"
               f"fs_gain={dfp.num_adds() / max(dfs.num_adds(), 1):.2f}")
        print(row, flush=True)
        csv_rows.append(row)
    run_fidelity_sweep(csv_rows)
    csv_rows.append(f"lcc_scaling_wall_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    run([])


def run_fidelity_sweep(csv_rows: list[str]) -> None:
    """Beyond-paper ablation: adds & stream-bytes vs fidelity target.

    The paper fixes fidelity at the CSD-quantization SNR; serving systems pick
    a point on this curve (int8-equivalent ~ 40 dB is the common deployment
    choice)."""
    rng = np.random.default_rng(1)
    w = rng.standard_normal((256, 16))
    base = adds_csd_matrix(w, 8)
    dense_bytes = 2 * 256 * 16
    for snr in (25.0, 30.0, 40.0, 50.0, 60.0):
        d = lcc_decompose(w, algorithm="fs", target_snr_db=snr)
        row = (f"lcc_fidelity,snr_target={snr:.0f}dB,adds_ratio="
               f"{base / max(d.num_adds(), 1):.2f},"
               f"stream_vs_bf16={dense_bytes / max(d.storage_bytes(), 1):.2f}")
        print(row, flush=True)
        csv_rows.append(row)
