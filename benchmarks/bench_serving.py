"""Serving-loop benchmark: steady-state decode throughput and prefill latency,
dense vs compressed, at n_slots in {1, 8} — emitted as machine-readable
``BENCH_serving.json`` so the perf trajectory is tracked across PRs.

Three compressed workloads exercise the site-keyed executor:
``compressed`` (FFN sites only, the historical row), ``compressed+attn``
(FFN + attention q/k/v/o through the grouped fused launches), and an MoE
section whose experts apply their chains in one grouped dispatch per layer.
Each compressed row also reports the paper's Table-1 additions metric
(``models.flops.compressed_adds``) plus the measured ``pallas_launches`` per
decode step; with the layer-plan executor active this equals ``n_layer_plans``
(one launch per identical-layer stack).  A ``roofline`` section ties each
artifact's per-site shift-add budget to the throughput it actually achieved.

Two paged-KV sections ride on the same engines:

* ``poisson`` — an arrival-trace mode: requests arrive by a Poisson process
  whose rate is calibrated to ~60% of the engine's measured service rate, and
  the scheduler admits them continuously (no drain between requests).  Reports
  sustained req/s and p50/p99 end-to-end latency, dense vs compressed, at
  ``n_slots=8``.
* ``prefix_cache`` — cold vs warm prefill for a block-aligned prompt: the warm
  repeat is a full prefix-cache hit (zero forward passes), so its latency is
  pure admission bookkeeping.

    PYTHONPATH=src python benchmarks/bench_serving.py [--smoke] [--out FILE]

CPU-container numbers measure the serving loop's dispatch/transfer overhead
(interpret-mode Pallas for the compressed path), not TPU kernel speed; the
cross-PR signal is the tok/s trend of the identical workload.
"""
from __future__ import annotations

import argparse
import gc
import json
import platform
import time

import jax


def _drive_steps(eng, n_steps: int) -> float:
    """Time n_steps fused decode steps with every slot active; returns tok/s."""
    t0 = time.time()
    for _ in range(n_steps):
        eng.step()
    dt = time.time() - t0
    return eng.n_slots * n_steps / dt


def bench_engine(make_engine, *, n_slots: int, prompt_len: int,
                 steps: int, warmup: int) -> dict:
    from repro.data.synthetic import MarkovLM

    eng = make_engine(n_slots)
    # every slot must stay active through warmup + timed steps: cap at the
    # decode headroom the KV cache leaves after the prompt
    steps = max(1, min(steps, eng.max_len - prompt_len - warmup - 1))
    lm = MarkovLM(vocab=eng.cfg.vocab, k=8, seed=0)
    prompts = [lm.sample(1, prompt_len, seed=i)[0, :prompt_len].tolist()
               for i in range(n_slots + 1)]

    # prefill: warm the bucket's compile cache with a throwaway request (one
    # generated token, then the slot frees), then time a steady-state submit
    eng.submit(prompts[0], max_new=1)
    while eng.active.any():
        eng.step()
    jax.block_until_ready(eng.state)
    t0 = time.time()
    eng.submit(prompts[1], max_new=eng.max_len)
    jax.block_until_ready(eng.state)  # async dispatch: wait for the prefill
    prefill_s = time.time() - t0
    for p in prompts[2:]:
        eng.submit(p, max_new=eng.max_len)

    for _ in range(warmup):  # compile + steady-state the fused step
        eng.step()
    tok_s = _drive_steps(eng, steps)
    assert eng.active.sum() == n_slots, "a slot finished mid-measurement"
    return {"n_slots": n_slots, "prompt_len": prompt_len,
            "steps_timed": steps,  # post-clamp, the count actually measured
            "decode_tok_s": round(tok_s, 2),
            "prefill_ms": round(prefill_s * 1e3, 2),
            "step_dispatches": eng.step_dispatches,
            # measured at the first decode trace: with layer plans active
            # these two are equal (one launch covers a whole layer stack)
            "pallas_launches": eng.pallas_launches_per_step,
            "n_layer_plans": eng.n_layer_plans,
            # why any plan fell back to the per-region route (empty = none)
            "plan_fallbacks": (eng.plan_stats()["fallbacks"]
                               if hasattr(eng, "plan_stats") else {})}


def bench_poisson(make_engine, *, n_slots: int, n_requests: int,
                  prompt_len: int, max_new: int, utilization: float = 0.6,
                  seed: int = 0) -> dict:
    """Drive a Poisson arrival trace through the continuous-batching
    scheduler; wall-clock end-to-end latency per request."""
    import numpy as np

    from repro.data.synthetic import MarkovLM
    from repro.serving.scheduler import Scheduler

    from repro.obs import RequestTracer

    eng = make_engine(n_slots)
    # span tracing drives the row's latency percentiles: the scheduler
    # opens a span per request (enqueue -> admit -> tokens -> retire), so
    # TTFT / per-token latency come from the request lifecycle itself
    # instead of ad-hoc host timestamps around the drive loop
    eng.tracer = RequestTracer(metrics=eng.metrics)
    lm = MarkovLM(vocab=eng.cfg.vocab, k=8, seed=1)
    # warm + calibrate: two full rounds through every slot — the first pays
    # compilation, the second measures the true service rate (prefill +
    # decode + host-side block bookkeeping)
    for r in range(2):
        t0 = time.time()
        for i in range(n_slots):
            p = lm.sample(1, prompt_len,
                          seed=7 + r * n_slots + i)[0, :prompt_len].tolist()
            eng.submit(p, max_new=max_new)
        while eng.active.any():
            eng.step()
        round_s = time.time() - t0
    rate = utilization * n_slots / round_s

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    prompts = [lm.sample(1, prompt_len, seed=1000 + i)[0, :prompt_len].tolist()
               for i in range(n_requests)]
    sched = Scheduler(eng)
    done_at: dict[int, float] = {}
    enq: dict[int, float] = {}  # scheduler rid -> arrival time
    batch_drains = 0
    i = 0
    t0 = time.time()
    while len(done_at) < n_requests:
        now = time.time() - t0
        while i < n_requests and arrivals[i] <= now:
            enq[sched.enqueue(prompts[i], max_new=max_new)] = arrivals[i]
            i += 1
        if not (sched.pending or sched.inflight or eng.active.any()):
            batch_drains += 1  # idle gap in the trace: sleep to next arrival
            time.sleep(max(0.0, arrivals[i] - (time.time() - t0)))
            continue
        for ev in sched.step():
            if ev.finished:
                done_at[ev.rid] = time.time() - t0
    wall = time.time() - t0
    res = [sched.take_result(r) for r in sorted(enq)]
    spans = eng.tracer.spans("ok")
    assert eng.tracer.open_count == 0, "unclosed spans after the trace drained"

    def pct(vals, q):
        a = np.array([v for v in vals if v is not None]) * 1e3
        return round(float(np.percentile(a, q)), 1) if a.size else None

    e2e = [s.e2e_s for s in spans]
    return {"n_slots": n_slots, "n_requests": n_requests,
            "prompt_len": prompt_len, "max_new": max_new,
            "offered_req_s": round(rate, 2),
            "sustained_req_s": round(n_requests / wall, 2),
            # request-span lifecycle, not ad-hoc host timing
            "latency_p50_ms": pct(e2e, 50),
            "latency_p99_ms": pct(e2e, 99),
            "latency_mean_ms": round(
                float(np.mean([v * 1e3 for v in e2e])), 1) if e2e else None,
            "ttft_p50_ms": pct([s.ttft_s for s in spans], 50),
            "ttft_p99_ms": pct([s.ttft_s for s in spans], 99),
            "tpot_p50_ms": pct([s.tpot_s for s in spans], 50),
            "tpot_p99_ms": pct([s.tpot_s for s in spans], 99),
            "queue_wait_p99_ms": pct([s.queue_wait_s for s in spans], 99),
            "errors": sum(r.error is not None for r in res),
            "batch_drains": batch_drains,
            "continuous_admissions": sched.admitted_while_running,
            "mem_stalls": sched.mem_stalls,
            "peak_kv_blocks": eng.pool_stats().get("peak_in_use_blocks")}


def bench_prefix(make_engine, *, prompt_len: int) -> dict | None:
    """Cold vs warm prefill latency for a repeated block-aligned prompt.
    The warm submit is a full prefix-cache hit — no forward pass at all."""
    from repro.data.synthetic import MarkovLM

    eng = make_engine(2)
    if eng.pool is None or not eng.pool.prefix_cache:
        return None
    lm = MarkovLM(vocab=eng.cfg.vocab, k=8, seed=2)
    bs = eng.pool.block_size
    plen = -(-prompt_len // bs) * bs  # full blocks: the repeat hits end-to-end

    def timed_submit(p):
        t0 = time.time()
        eng.submit(p, max_new=2)
        jax.block_until_ready(eng.state)
        dt = time.time() - t0
        while eng.active.any():
            eng.step()
        return dt

    timed_submit(lm.sample(1, plen, seed=5)[0, :plen].tolist())  # compile the bucket
    prompt = lm.sample(1, plen, seed=6)[0, :plen].tolist()
    cold = timed_submit(prompt)
    warm = timed_submit(prompt)
    s = eng.pool_stats()
    return {"prompt_len": plen, "block_size": bs,
            "cold_prefill_ms": round(cold * 1e3, 2),
            "warm_prefill_ms": round(warm * 1e3, 2),
            "speedup": round(cold / warm, 1),
            "prefix_hit_tokens": s["prefix_hit_tokens"],
            "leaked_blocks": s["in_use_blocks"]}


def bench_obs_overhead(make_engine, *, n_slots: int, prompt_len: int,
                       steps: int, attempts: int = 5) -> dict:
    """Decode step wall with full telemetry (metrics + tracer + profiler) vs
    everything disabled (``metrics=False``), scheduler-driven so the tracer's
    token hooks are on the measured path.

    Methodology (mirrors ``tests/test_obs.py``): single-step alternation
    between two pre-primed engines (shared-noise windows), alternation order
    rotated per round, per-step *medians* compared.  Host noise only ever
    inflates a measurement, so each attempt upper-bounds the true overhead —
    report the tightest (lowest) of ``attempts``."""
    from repro.data.synthetic import MarkovLM
    from repro.serving.scheduler import Scheduler

    def prime(**kw):
        eng = make_engine(n_slots, **kw)
        lm = MarkovLM(vocab=eng.cfg.vocab, k=8, seed=3)
        sched = Scheduler(eng)
        for i in range(n_slots):
            p = lm.sample(1, prompt_len, seed=50 + i)[0, :prompt_len].tolist()
            sched.enqueue(p, max_new=eng.max_len)
        for _ in range(2):  # admit + compile + settle the fused step
            sched.step()
        return eng, sched

    engines = {"on": prime(tracer=True), "off": prime(metrics=False)}
    eng_on, eng_off = engines["on"][0], engines["off"][0]
    # the attempts share each engine's decode headroom
    rounds = max(1, min(steps, (eng_on.max_len - prompt_len - 3) // attempts))

    def measure():
        # collector off during the timed window: allocation-triggered gen-0
        # sweeps walk the whole bench process's heap and land on arbitrary
        # steps, which is this process's garbage bill, not telemetry's
        walls = {k: [] for k in engines}
        order = list(engines)
        gc.collect()
        gc.disable()
        try:
            for i in range(rounds):
                for k in order[i % 2:] + order[:i % 2]:
                    sched = engines[k][1]
                    t0 = time.perf_counter()
                    sched.step()
                    walls[k].append(time.perf_counter() - t0)
        finally:
            gc.enable()
        return {k: sorted(w)[len(w) // 2] for k, w in walls.items()}

    meds = [measure() for _ in range(attempts)]
    best = min(meds, key=lambda m: m["on"] / m["off"])
    assert eng_on.active.sum() == eng_off.active.sum() == n_slots, \
        "a slot finished mid-measurement"
    # overhead_s_per_step is the scale-free number: telemetry's absolute
    # per-step cost (~tens of us) is fixed, so its *fraction* depends on the
    # measured engine's step time — CI judges it against the tracked
    # full-bench engine's step wall, not this smoke-sized one
    return {"n_slots": n_slots, "steps": rounds, "attempts": attempts,
            "tok_s_telemetry_on": round(n_slots / best["on"], 2),
            "tok_s_telemetry_off": round(n_slots / best["off"], 2),
            "overhead_s_per_step": round(best["on"] - best["off"], 7),
            "overhead_frac": round(best["on"] / best["off"] - 1.0, 4)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-bounded: tiny model, few steps")
    ap.add_argument("--steps", type=int, default=None,
                    help="timed decode steps (default 3 smoke / 20 full; "
                         "clamped to the KV-cache headroom)")
    args = ap.parse_args()

    from repro import core
    from repro.configs import get_arch
    from repro.configs.base import MoESpec, reduced_config
    from repro.models import api, flops
    from repro.serving.engine import ServingEngine

    if args.smoke:
        cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                             n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                             n_layers=2)
        cfg_moe = reduced_config(
            get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
            head_dim=16, vocab=64, n_layers=1,
            moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16,
                        capacity_factor=8.0))
        steps = 3 if args.steps is None else max(1, args.steps)
        warmup, prompt_len, max_len = 1, 8, 64
    else:
        cfg = reduced_config(get_arch("olmo-1b"))
        cfg_moe = reduced_config(
            get_arch("mixtral-8x22b"), d_model=64, n_heads=4, n_kv_heads=4,
            head_dim=16, vocab=256, n_layers=2,
            moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=32,
                        capacity_factor=8.0))
        steps = 20 if args.steps is None else max(1, args.steps)
        warmup, prompt_len, max_len = 3, 16, 256

    comp_cfg = core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                      max_share_rel_err=0.06)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    artifact = api.compress_model(params, cfg, comp_cfg, include="ffn.")
    artifact_all = api.compress_model(params, cfg, comp_cfg)  # + attention
    params_moe = api.init_params(jax.random.PRNGKey(1), cfg_moe)
    artifact_moe = api.compress_model(params_moe, cfg_moe, comp_cfg)

    makers = {
        "dense": lambda n, **kw: ServingEngine(params, cfg, n_slots=n,
                                               max_len=max_len, **kw),
        "compressed": lambda n, **kw: ServingEngine(artifact=artifact,
                                                    n_slots=n, max_len=max_len,
                                                    **kw),
        "compressed+attn": lambda n, **kw: ServingEngine(artifact=artifact_all,
                                                         n_slots=n,
                                                         max_len=max_len, **kw),
    }

    results = []

    def run(mode, make, n_slots, *, arch):
        t0 = time.time()
        row = {"mode": mode, "arch": arch, **bench_engine(
            make, n_slots=n_slots, prompt_len=prompt_len,
            steps=steps, warmup=warmup)}
        row["wall_s"] = round(time.time() - t0, 2)
        results.append(row)
        print(f"{arch:>12} {mode:>16} n_slots={n_slots}: "
              f"{row['decode_tok_s']:>8} tok/s decode, "
              f"{row['prefill_ms']:>7} ms prefill")

    for n_slots in (1, 8):
        for mode, make in makers.items():
            run(mode, make, n_slots, arch=cfg.name)

    # Poisson arrival trace through the continuous-batching scheduler
    n_req, trace_new = (10, 6) if args.smoke else (32, 12)
    poisson = []
    for mode in ("dense", "compressed"):
        row = {"mode": mode, **bench_poisson(
            makers[mode], n_slots=8, n_requests=n_req,
            prompt_len=prompt_len, max_new=trace_new)}
        poisson.append(row)
        print(f"{cfg.name:>12} {mode:>16} poisson: "
              f"{row['sustained_req_s']} req/s sustained "
              f"(offered {row['offered_req_s']}), "
              f"p50 {row['latency_p50_ms']} ms, p99 {row['latency_p99_ms']} ms, "
              f"{row['continuous_admissions']} continuous admissions")

    # prefix cache: repeated prompt prefills from cached blocks
    prefix = bench_prefix(makers["dense"], prompt_len=4 * prompt_len)
    if prefix:
        print(f"{cfg.name:>12} {'prefix-cache':>16}: "
              f"cold {prefix['cold_prefill_ms']} ms -> "
              f"warm {prefix['warm_prefill_ms']} ms "
              f"({prefix['speedup']}x)")
    # MoE: all experts of a layer apply their chains in ONE grouped dispatch
    for mode, make in (
            ("dense", lambda n: ServingEngine(params_moe, cfg_moe, n_slots=n,
                                              max_len=max_len)),
            ("compressed", lambda n: ServingEngine(artifact=artifact_moe,
                                                   n_slots=n,
                                                   max_len=max_len))):
        run(mode, make, 8, arch=cfg_moe.name)

    # telemetry overhead A/B: full metrics + tracing vs everything off.
    # A dedicated factory with a deep KV budget keeps the measurement
    # windows long enough (hundreds of steps) that noise stays below the
    # few-percent overhead being measured, even at smoke scale.
    obs_overhead = bench_obs_overhead(
        lambda n, **kw: ServingEngine(artifact=artifact, n_slots=n,
                                      max_len=256, **kw),
        n_slots=8, prompt_len=prompt_len, steps=60)
    print(f"{cfg.name:>12} {'obs-overhead':>16}: "
          f"{obs_overhead['tok_s_telemetry_on']} tok/s on vs "
          f"{obs_overhead['tok_s_telemetry_off']} off "
          f"({obs_overhead['overhead_frac']:+.1%} at this scale, "
          f"{obs_overhead['overhead_s_per_step'] * 1e6:+.0f} us/step)")

    # Roofline: per-site shift-add cost against the throughput each artifact
    # actually achieved, so adds-vs-tok/s gaps are visible per PR.  The same
    # obs.roofline function feeds launch/serve's live-engine table.
    from repro.obs import roofline as obs_roofline

    def roofline_section(art, mode, arch):
        row8 = next((r for r in results
                     if r["mode"] == mode and r["arch"] == arch
                     and r["n_slots"] == 8), None)
        return obs_roofline(
            art, row8["decode_tok_s"] if row8 else None,
            pallas_launches=row8["pallas_launches"] if row8 else None,
            n_layer_plans=row8["n_layer_plans"] if row8 else None,
            mode=mode, arch=arch)

    roofline = [roofline_section(artifact, "compressed", cfg.name),
                roofline_section(artifact_all, "compressed+attn", cfg.name),
                roofline_section(artifact_moe, "compressed", cfg_moe.name)]

    # Segment-packed gather layout: per-stage run-length percentiles before
    # vs after the pack-time repack (recorded when each plan is built)
    segment_layout = {}
    for art, arch in ((artifact, cfg.name), (artifact_all, cfg.name + "+attn"),
                      (artifact_moe, cfg_moe.name)):
        seg = (getattr(art, "pipeline_stats", None) or {}).get(
            "segment_layout", {})
        for stage, st in seg.items():
            segment_layout[f"{arch}.{stage}"] = st

    report = {
        "bench": "serving",
        "arch": cfg.name,
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.machine(),
        "steps_requested": steps,
        "compression": {"algorithm": "fp",
                        "ratio_lcc": round(artifact.report.ratio("lcc"), 2)},
        "adds": {
            "ffn_only": flops.compressed_adds(cfg, artifact),
            "ffn+attn": flops.compressed_adds(cfg, artifact_all),
            "moe": flops.compressed_adds(cfg_moe, artifact_moe),
        },
        "results": results,
        "roofline": roofline,
        "poisson": poisson,
        "prefix_cache": prefix,
        "obs_overhead": obs_overhead,
        "segment_layout": segment_layout,
    }

    # cross-PR history: append a dated summary entry, carrying forward any
    # entries already recorded in the previous report at the same path
    history = []
    try:
        with open(args.out) as f:
            history = json.load(f).get("history", [])
    except (OSError, ValueError):
        pass

    def _tok(mode, arch, n):
        r = next((r for r in results if r["mode"] == mode and r["arch"] == arch
                  and r["n_slots"] == n), None)
        return r["decode_tok_s"] if r else None

    history.append({
        "date": time.strftime("%Y-%m-%d"),
        "smoke": args.smoke,
        "dense_tok_s_n8": _tok("dense", cfg.name, 8),
        "compressed_tok_s_n8": _tok("compressed", cfg.name, 8),
        "moe_dense_tok_s_n8": _tok("dense", cfg_moe.name, 8),
        "moe_compressed_tok_s_n8": _tok("compressed", cfg_moe.name, 8),
    })
    report["history"] = history
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
