"""Accuracy-vs-adds Pareto: the paper's full training loop vs compress-only.

Three pipelines on the MLP + mnist_like task, each evaluated at three global
adds budgets (fractions of the unregularized model's unbudgeted LCC cost):

  compress-only          plain SGD(momentum) training -> budgeted compression
  regularized            ProxSGD on adapter-derived groups -> budgeted
                         compression (dead groups become 0-add skips)
  regularized+recovery   + post-compression recovery fine-tuning; the dense
                         residual's CSD adds are counted against the total,
                         so the comparison stays honest

Emits machine-readable ``BENCH_train.json``.  The tracked claim: the
regularized+recovery point Pareto-dominates compress-only — strictly fewer
adds at equal-or-better held-out accuracy — at >= 1 budget point.

    PYTHONPATH=src python benchmarks/bench_train.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import tempfile
import time


def train_mlp(cfg, data, *, lam: float, epochs: int, seed: int = 0):
    """(params, dead_fraction): ProxSGD when lam > 0, else plain momentum."""
    import jax
    import jax.numpy as jnp

    from repro.data.synthetic import batches
    from repro.models.mlp import init_mlp, mlp_loss
    from repro.optim.optimizers import prox_sgd, step_decay
    from repro.training import regularize

    (xs, ys), _ = data
    params = init_mlp(jax.random.PRNGKey(seed), hidden=cfg.hidden)
    specs = regularize.site_group_specs(params, cfg, lam, include="fc1") \
        if lam > 0 else ()
    opt = prox_sgd(momentum=0.9, specs=specs)
    state = opt.init(params)
    lr = step_decay(0.08, 0.95, 3)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    for ep in range(epochs):
        for xb, yb in batches(xs, ys, 128, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
    dead = regularize.dead_group_fraction(
        regularize.sparsity_report(params, specs)) if specs else 0.0
    return params, dead


def accuracy(params, data) -> float:
    import jax.numpy as jnp

    from repro.models.mlp import mlp_accuracy

    _, (xte, yte) = data
    return float(mlp_accuracy(params, jnp.asarray(xte), jnp.asarray(yte)))


def compress_at(params, cfg, comp, budget, cache_dir):
    from repro.models import api

    t0 = time.time()
    art = api.compress_model(params, cfg, comp, n_workers=2,
                             budget_adds=budget, cache_dir=cache_dir)
    return art, round(time.time() - t0, 2)


def recover(art, *, steps: int, batch: int = 128, lr: float = 1e-3,
            seed: int = 2):
    """Recovery fine-tuning on a *fresh* procedural stream.

    ``mnist_like`` is a generator, so recovery draws new samples from the
    training distribution (seed disjoint from both the train and test
    streams) rather than recycling the small train split — cycling a
    1-2k-sample split overfits the residual and *lowers* held-out accuracy.
    """
    import jax.numpy as jnp

    from repro.data.mnist_like import mnist_like
    from repro.models.mlp import mlp_loss
    from repro.training.recover import recover_artifact

    xs, ys = mnist_like(steps * batch, seed=seed)

    def loss_fn(p, b):
        return mlp_loss(p, b[0], b[1])

    def rec_batches():
        for i in range(steps):
            yield (jnp.asarray(xs[i * batch:(i + 1) * batch]),
                   jnp.asarray(ys[i * batch:(i + 1) * batch]))

    res = recover_artifact(art, loss_fn, rec_batches(), lr=lr)
    return sum(u.get("recover_adds", 0) for u in res["units"].values())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-bounded: small model, 2 budget points")
    ap.add_argument("--lam", type=float, default=0.05)
    args = ap.parse_args()

    from repro.core import CompressionConfig
    from repro.data.mnist_like import train_test
    from repro.models.mlp import MLPConfig

    hidden = 100 if args.smoke else 300
    epochs = 6 if args.smoke else 30
    rec_steps = 30 if args.smoke else 150
    fracs = (0.4, 1.0) if args.smoke else (0.3, 0.5, 1.0)
    cfg = MLPConfig(hidden=hidden)
    # small train split + large held-out test split: the Pareto claim is
    # about held-out accuracy, and a tight train set is where regularization
    # and fresh-stream recovery actually have something to win
    data = train_test(2000 if args.smoke else 1500,
                      500 if args.smoke else 2000, seed=0)
    comp = CompressionConfig(algorithm="fp", weight_sharing=False,
                             prune_tol=-1e-6, snr_offset_db=-6.0)

    t0 = time.time()
    plain, _ = train_mlp(cfg, data, lam=0.0, epochs=epochs)
    reg, dead = train_mlp(cfg, data, lam=args.lam, epochs=epochs)
    acc_plain, acc_reg = accuracy(plain, data), accuracy(reg, data)
    print(f"trained: plain acc {acc_plain:.3f}; regularized acc {acc_reg:.3f} "
          f"({dead:.1%} dead groups) in {time.time() - t0:.1f}s", flush=True)

    with tempfile.TemporaryDirectory() as scratch:
        # reference cost: the unregularized model, unbudgeted, at the base plan
        base_art, _ = compress_at(plain, cfg, comp, None,
                                  os.path.join(scratch, "plain"))
        base_adds = int(base_art.report.total_stage("lcc"))
        print(f"base (compress-only, no budget): {base_adds} adds", flush=True)

        points = []
        for frac in fracs:
            budget = int(frac * base_adds)
            row = {"budget_frac": frac, "budget_adds": budget}
            for mode, params in (("compress_only", plain),
                                 ("regularized", reg)):
                art, wall = compress_at(params, cfg, comp, budget,
                                        os.path.join(scratch, mode[:5]))
                lcc = int(art.report.total_stage("lcc"))
                row[mode] = {"adds": lcc,
                             "accuracy": round(accuracy(art.params, data), 4),
                             "dead_groups": int(
                                 art.pipeline_stats.get("dead_groups", 0)),
                             "skipped_jobs": int(
                                 art.pipeline_stats.get("skipped_jobs", 0)),
                             "wall_s": wall}
                if mode == "regularized":
                    residual = recover(art, steps=rec_steps)
                    row["regularized_recovery"] = {
                        "adds": lcc + int(residual),
                        "residual_adds": int(residual),
                        "accuracy": round(accuracy(art.params, data), 4)}
            rr, co = row["regularized_recovery"], row["compress_only"]
            row["pareto_dominates"] = bool(
                rr["adds"] < co["adds"] and rr["accuracy"] >= co["accuracy"])
            points.append(row)
            print(f"budget {frac:.0%} ({budget}): compress-only "
                  f"{co['adds']} adds @ {co['accuracy']:.3f}; "
                  f"reg+recovery {rr['adds']} adds @ {rr['accuracy']:.3f}"
                  f"{'  << dominates' if row['pareto_dominates'] else ''}",
                  flush=True)

    out = {
        "bench": "train_compress_recover_pareto",
        "platform": {"machine": platform.machine(),
                     "python": platform.python_version()},
        "task": {"arch": "mlp", "hidden": hidden, "epochs": epochs,
                 "lam": args.lam, "recover_steps": rec_steps,
                 "data": "mnist_like", "compression": {
                     "algorithm": comp.algorithm,
                     "weight_sharing": comp.weight_sharing,
                     "prune_tol": comp.prune_tol,
                     "snr_offset_db": comp.snr_offset_db}},
        "dense_accuracy": {"plain": round(acc_plain, 4),
                           "regularized": round(acc_reg, 4)},
        "dead_group_fraction": round(dead, 4),
        "base_adds": base_adds,
        "points": points,
        "pareto_dominates_anywhere": any(p["pareto_dominates"]
                                         for p in points),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"-> {args.out}  (dominates at >=1 point: "
          f"{out['pareto_dominates_anywhere']})")


if __name__ == "__main__":
    main()
