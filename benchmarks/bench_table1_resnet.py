"""Paper Table I: ResNet compression — FK vs PK kernel representation x
FP vs FS LCC algorithm, after group-lasso regularized training.

CPU-scale protocol (DESIGN.md): a reduced pre-act ResNet is trained on the
procedural-textures stand-in with group-lasso prox on the eq.-(11) kernel
groups; every conv layer is then decomposed all four ways.  The paper's
qualitative claims checked here: FS >= FP (esp. for small equivalent
matrices), both >= reg-training-only, PK taller than FK.  The full ResNet-34
config is also instantiated (random init) and a sampled subset of its conv
matrices decomposed to show scale behaviour.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import CompressionConfig, compress_conv_kernel
from repro.core.cost import ModelCostReport
from repro.core.group_lasso import group_prox_rows_np
from repro.data.synthetic import batches, textures_like
from repro.models.resnet import (conv_kernels, init_resnet, resnet34_config,
                                 resnet_forward, resnet_loss, resnet_small_config)
from repro.optim.optimizers import sgd


def train_small(epochs: int = 12, lam: float = 8e-3):
    cfg = resnet_small_config(classes=6)
    xs, ys = textures_like(512, size=24, classes=6, seed=0)
    xte, yte = textures_like(128, size=24, classes=6, seed=1)
    params = init_resnet(jax.random.PRNGKey(0), cfg)
    opt = sgd(momentum=0.9)
    state = opt.init(params)
    grad = jax.jit(jax.value_and_grad(resnet_loss))
    lr = 0.05

    def prox_convs(params, thresh):
        # eq. (11): groups = kernel rows of the per-input-channel matrices
        for blk in params["blocks"]:
            for name in ("conv1", "conv2"):
                k = np.asarray(blk[name], np.float64)  # [N, K, O, O]
                n, kk, o, _ = k.shape
                g = k.transpose(1, 0, 2, 3).reshape(kk * n, o * o)
                g = group_prox_rows_np(g, thresh)
                blk[name] = jnp.asarray(
                    g.reshape(kk, n, o, o).transpose(1, 0, 2, 3), jnp.float32)
        return params

    losses = []
    for ep in range(epochs):
        for xb, yb in batches(xs, ys, 64, seed=ep):
            loss, g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = opt.update(g, state, params, lr)
            params = prox_convs(params, lr * lam)
            losses.append(float(loss))
    logits = resnet_forward(params, jnp.asarray(xte))
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean())
    return params, acc


def run(csv_rows: list[str]) -> None:
    t0 = time.time()
    params, acc = train_small()
    kernels = conv_kernels(params)[1:]  # skip the 3-channel stem
    for conv_method in ("fk", "pk"):
        for alg in ("fp", "fs"):
            rep = ModelCostReport()
            for name, k in kernels:
                compress_conv_kernel(name, np.asarray(k, np.float64),
                                     CompressionConfig(algorithm=alg,
                                                       conv_method=conv_method,
                                                       weight_sharing=False),
                                     rep)
            row = (f"table1_resnet,small,method={conv_method},alg={alg},"
                   f"acc={acc:.3f},ratio_regtrain={rep.ratio('pruned'):.2f},"
                   f"ratio_lcc={rep.ratio('lcc'):.2f}")
            print(row, flush=True)
            csv_rows.append(row)
    # scale demonstration: ResNet-34 (random init), sampled channels
    cfg34 = resnet34_config()
    p34 = init_resnet(jax.random.PRNGKey(1), cfg34)
    big = [kv for kv in conv_kernels(p34) if kv[1].shape[1] >= 64][:2]
    for conv_method in ("fk", "pk"):
        rep = ModelCostReport()
        for name, k in big:
            compress_conv_kernel(name, np.asarray(k, np.float64),
                                 CompressionConfig(algorithm="fs",
                                                   conv_method=conv_method,
                                                   weight_sharing=False),
                                 rep, channel_subsample=16)
        row = (f"table1_resnet,resnet34_sampled,method={conv_method},alg=fs,"
               f"ratio_lcc={rep.ratio('lcc'):.2f}")
        print(row, flush=True)
        csv_rows.append(row)
    csv_rows.append(f"table1_wall_s,{time.time() - t0:.1f},")


if __name__ == "__main__":
    run([])
