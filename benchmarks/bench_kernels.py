"""Kernel microbench: us_per_call of Pallas kernels (interpret mode on this
CPU container — wall times validate plumbing, not TPU perf; the TPU-side
value proposition is the HBM-byte reduction quantified in the derived column)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lcc import lcc_decompose
from repro.kernels import ops
from repro.kernels.group_prox import group_prox
from repro.kernels.lcc_matmul import lcc_factor_matmul
from repro.kernels.ref import group_prox_ref, lcc_factor_matmul_ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6


def run(csv_rows: list[str]) -> None:
    rng = np.random.default_rng(0)
    n, k, b, s = 256, 128, 128, 2
    idx = jnp.asarray(rng.integers(0, k, (n, s)), jnp.int32)
    exp = jnp.asarray(rng.integers(-8, 8, (n, s)), jnp.int8)
    sign = jnp.asarray(rng.choice([-1, 1], (n, s)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)

    us_kernel = _time(lambda: lcc_factor_matmul(idx, exp, sign, x))
    us_ref = _time(lambda: lcc_factor_matmul_ref(idx, exp, sign, x))
    compact_bytes = int(3 * n * s)
    dense_bytes = 2 * n * k
    csv_rows.append(f"lcc_factor_matmul_interp,{us_kernel:.0f},"
                    f"hbm_bytes_ratio={dense_bytes / compact_bytes:.1f}x_smaller")
    csv_rows.append(f"lcc_factor_matmul_ref,{us_ref:.0f},oracle")

    # whole-chain apply on a decomposed matrix
    w = rng.standard_normal((256, 16))
    dec = lcc_decompose(w, algorithm="fp", frac_bits=8)
    packed = ops.pack_decomposition(dec)
    xs = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    us_chain = _time(lambda: ops.apply_packed_decomposition(packed, xs))
    csv_rows.append(
        f"lcc_chain_apply,{us_chain:.0f},"
        f"stored_bytes={dec.storage_bytes()}_vs_dense_bf16={2 * 256 * 16}")

    # fused whole-chain launch vs the legacy per-factor pallas_call loop vs a
    # plain dense matmul, on a >=512-row FP decomposition (the acceptance
    # shape).  One launch holds every intermediate in VMEM scratch; the loop
    # round-trips each one through HBM (and per-launch overhead, in interpret
    # mode the dominant cost it models).
    w5 = rng.standard_normal((512, 16))
    dec5 = lcc_decompose(w5, algorithm="fp", frac_bits=8)
    packed5 = ops.pack_decomposition(dec5)
    x5 = jnp.asarray(rng.standard_normal((16, 128)), jnp.float32)
    w5_dense = jnp.asarray(dec5.to_dense(), jnp.float32)
    us_fused = _time(lambda: ops.apply_packed_decomposition(packed5, x5))
    us_loop = _time(lambda: ops.apply_packed_decomposition(packed5, x5, fused=False))
    us_dense = _time(lambda: w5_dense @ x5)
    n_factors = sum(len(s.factors) for s in dec5.slices)
    csv_rows.append(f"lcc_chain_fused_512,{us_fused:.0f},"
                    f"one_launch_{len(dec5.col_slices)}slices_{n_factors}factors")
    csv_rows.append(f"lcc_chain_perfactor_512,{us_loop:.0f},"
                    f"speedup_from_fusion={us_loop / us_fused:.1f}x")
    csv_rows.append(f"lcc_chain_dense_matmul_512,{us_dense:.0f},"
                    f"xla_oracle_stored_bytes={dec5.storage_bytes()}"
                    f"_vs_{2 * 512 * 16}")
    err = float(np.abs(np.asarray(ops.apply_packed_decomposition(packed5, x5))
                       - dec5.apply(np.asarray(x5, np.float64))).max())
    csv_rows.append(f"lcc_chain_fused_max_err,{err:.2e},vs_numpy_reference")

    a = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    us_prox = _time(lambda: group_prox(a, 0.5))
    us_prox_ref = _time(lambda: group_prox_ref(a, 0.5))
    csv_rows.append(f"group_prox_interp,{us_prox:.0f},fused_1read_1write")
    csv_rows.append(f"group_prox_ref,{us_prox_ref:.0f},oracle")

    labels = jnp.asarray(rng.integers(0, 64, 256), jnp.int32)
    cents = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    xx = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    us_sm = _time(lambda: ops.shared_matmul_tpu(cents, labels, xx))
    csv_rows.append(f"shared_matmul_interp,{us_sm:.0f},K256->C64_flop_ratio=4.0x")

    # engine prefill: ONE bulk api.prefill forward vs the legacy per-token
    # decode loop (the pre-PR-2 submit path), same 48-token prompt
    from repro.configs import get_arch
    from repro.configs.base import reduced_config
    from repro.models import api
    from repro.serving.engine import ServingEngine

    cfg = reduced_config(get_arch("olmo-1b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompt = list(rng.integers(0, cfg.vocab, 48))

    def prefill_us(bulk: bool) -> float:
        eng = ServingEngine(params, cfg, n_slots=2, max_len=64,
                            bulk_prefill=bulk)
        eng.submit(prompt)  # warm-up: compiles the prefill/decode fns
        t0 = time.time()
        eng.submit(prompt)
        jax.block_until_ready(jax.tree.leaves(eng.state)[0])
        return (time.time() - t0) * 1e6

    us_tokenwise = prefill_us(False)
    us_bulk = prefill_us(True)
    csv_rows.append(f"engine_prefill_tokenwise_48tok,{us_tokenwise:.0f},"
                    f"one_decode_launch_per_token")
    csv_rows.append(f"engine_prefill_bulk_48tok,{us_bulk:.0f},"
                    f"speedup={us_tokenwise / us_bulk:.1f}x_single_forward")
    for r in csv_rows[-12:]:
        print(r, flush=True)


if __name__ == "__main__":
    run([])
