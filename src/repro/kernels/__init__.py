"""Pallas TPU kernels for the paper's compute hot spots.

``interpret`` defaults to auto-detection everywhere: compiled Mosaic on TPU,
the Pallas interpreter on CPU/GPU (validated in CI) — see ``dispatch``.
"""
from .dispatch import default_interpret  # noqa: F401
from .group_prox import group_prox  # noqa: F401
from .lcc_chain_matmul import lcc_chain_matmul  # noqa: F401
from .lcc_group_matmul import lcc_group_matmul  # noqa: F401
from .lcc_matmul import lcc_factor_matmul  # noqa: F401
from .shared_matmul import cluster_segment_sum  # noqa: F401
