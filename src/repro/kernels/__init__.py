"""Pallas TPU kernels for the paper's compute hot spots (validated interpret=True)."""
from .group_prox import group_prox  # noqa: F401
from .lcc_matmul import lcc_factor_matmul  # noqa: F401
from .shared_matmul import cluster_segment_sum  # noqa: F401
