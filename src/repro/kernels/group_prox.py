"""Pallas TPU kernel: fused group-lasso proximal operator (paper eq. (8)).

Row-wise block soft threshold:  out_i = max(1 - t / ||a_i||_2, 0) * a_i.
Fusing the norm reduction with the rescale keeps the weight tile resident in
VMEM — one HBM read + one write per weight, instead of read(norm) + read+write
(scale) when expressed as two XLA ops.  Runs every ProxSGD step over every
regularized weight matrix, so it is on the training hot path.

Grid over row blocks; the full row (group) must fit one block — groups are
matrix rows/columns (<= a few x 10^4 elements), comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret

__all__ = ["group_prox"]


def _kernel(a_ref, t_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # [bg, M] — full groups
    t = t_ref[0]
    norm = jnp.sqrt(jnp.sum(a * a, axis=1, keepdims=True))
    # zero-norm rows (structurally pruned groups, or grid padding) map to
    # exactly 0 — same guard as core.group_lasso.group_prox_rows
    scale = jnp.where(norm > 0.0,
                      jnp.maximum(1.0 - t / jnp.maximum(norm, 1e-12), 0.0),
                      0.0)
    o_ref[...] = (scale * a).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_g", "interpret"))
def group_prox(
    a: jnp.ndarray,
    thresh: jnp.ndarray | float,
    block_g: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Block soft threshold over rows of ``a`` [G, M] with threshold ``thresh``.

    ``G`` need not tile by ``block_g``: extra rows are zero-padded to the next
    block multiple (safe because the zero-norm guard maps zero rows to exactly
    zero) and sliced off the output — the caller sees [G, M] in / [G, M] out
    for any G, which is what ``optim.prox_sgd`` needs for arbitrary layers.
    """
    g, m = a.shape
    block_g = min(block_g, g)
    g_pad = ((g + block_g - 1) // block_g) * block_g
    ap = jnp.pad(a, ((0, g_pad - g), (0, 0))) if g_pad != g else a
    t = jnp.asarray(thresh, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _kernel,
        grid=(g_pad // block_g,),
        in_specs=[
            pl.BlockSpec((block_g, m), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_g, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g_pad, m), a.dtype),
        interpret=resolve_interpret(interpret),
    )(ap, t)
    return out[:g] if g_pad != g else out
