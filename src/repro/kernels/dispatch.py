"""Kernel dispatch policy: compiled Pallas on TPU, interpreter elsewhere.

Every kernel in this package takes ``interpret: bool | None = None``.  ``None``
resolves through :func:`default_interpret` at trace time — compiled Mosaic
when the default jax backend is TPU, the Pallas interpreter on CPU/GPU — so
one call site runs correctly on the production accelerator and in local/CI
containers alike.  Pass an explicit bool to override (e.g. ``interpret=True``
on TPU to debug a kernel numerically).
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret", "record_launch",
           "launch_count", "reset_launch_count"]


def default_interpret() -> bool:
    """True when the default backend cannot run compiled Pallas TPU kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# launch accounting
# ---------------------------------------------------------------------------
#
# Every decode-path kernel wrapper calls ``record_launch()`` from plain Python
# *before* entering its jitted implementation, so the counter advances once
# per ``pallas_call`` that a trace emits (inner jit caches never hide a call
# site: the un-jitted wrapper body runs on every trace-time invocation).
# Within one jitted decode step the trace-time count equals the runtime
# launches per step — the number the serving bench reports as
# ``pallas_launches`` and the 1-launch-per-layer claim is measured against.

_launch_count = 0
_launch_metric = None  # lazily resolved obs counter (process-global registry)


def record_launch(n: int = 1) -> None:
    """Count ``n`` Pallas launches emitted by the current (trace-time) call.

    Also published as the live ``pallas_launches_total`` counter in the
    process-global :mod:`repro.obs` registry (resolved lazily so importing
    this module stays free of any obs setup cost)."""
    global _launch_count, _launch_metric
    _launch_count += n
    if _launch_metric is None:
        from repro.obs import get_global
        _launch_metric = get_global().counter(
            "pallas_launches_total",
            "Pallas launches recorded at trace time, process-wide")
    _launch_metric.inc(n)


def launch_count() -> int:
    """Cumulative launches recorded since import (or the last reset)."""
    return _launch_count


def reset_launch_count() -> None:
    global _launch_count
    _launch_count = 0
