"""Kernel dispatch policy: compiled Pallas on TPU, interpreter elsewhere.

Every kernel in this package takes ``interpret: bool | None = None``.  ``None``
resolves through :func:`default_interpret` at trace time — compiled Mosaic
when the default jax backend is TPU, the Pallas interpreter on CPU/GPU — so
one call site runs correctly on the production accelerator and in local/CI
containers alike.  Pass an explicit bool to override (e.g. ``interpret=True``
on TPU to debug a kernel numerically).
"""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """True when the default backend cannot run compiled Pallas TPU kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
