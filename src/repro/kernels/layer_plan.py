"""Pallas kernels for layer plans: ONE launch per decode step / MoE layer.

The per-region runtime (``lcc_group_matmul`` per q/k/v, per gate/up, per down,
plus segment-sum and per-site launches) still pays many dispatches per layer.
On the measured CPU-interpreter floor each dispatch unrolls into its own chunk
of XLA ops, so dispatch count — not arithmetic — dominates decode wall-clock.
These kernels collapse the whole transformer decode step into a single
``pallas_call``: every layer of the stacked ``[L, …]`` plan buffers executes
in sequence inside one kernel body (pre-norm, fused q+k+v, rope, KV merge,
attention, o-proj, post-norm, fused gate+up, SwiGLU, down, residuals), with
the running hidden state ``x`` carried as a kernel-local value; only token
embeddings, the KV cache view and the new K/V rows cross the boundary.  The
layer loop lives *inside* the kernel rather than on a ``grid=(L,)``: the
interpreter materializes every operand block per grid step, which measures
~1.5x slower than slicing the stacked buffers in-kernel.

Inside a stage the inner loop is specialized to the ternary/CSD structure
(``core/csd.py``): factor rows are ``sum_s sign * 2^exp * prev[idx]``, i.e. a
sign gather + shift-add — evaluated directly from the packed (idx, exp, sign)
streams of :class:`repro.kernels.ops.PackedStage` with no sign-padded dense
tiles and no per-site slab padding.  Pack time fuses adjacent CSD levels
pairwise (``ops._fuse_csd_levels``) — exponents add, signs multiply — so the
kernel walks half the sequential depth at the same add count.  FS-program
slices and uncovered sites ride along as baked dense blocks so the stage
always emits the layer's full output.

These kernels are gather/scatter-shaped and target the *interpreter* path
(the environment this repo benches on); compiled Mosaic keeps the per-region
grouped kernels, whose one-hot/MXU formulation it is built for.  The
executor gates plan construction on ``resolve_interpret``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .dispatch import record_launch, resolve_interpret
from .ops import PackedStage

__all__ = ["step_plan_matmul", "moe_plan_matmul", "stage_matmul"]

_NEG = -1e30


def _stage_apply(ps: PackedStage, ops_l, src, layer: int = 0):
    """Evaluate one stage for one layer: src [D_src, B] -> [O, B].

    ``ops_l`` holds the stage's operand arrays in :meth:`PackedStage.operands`
    order, already sliced to this layer (leading layer axis stripped).
    Segment-packed stages (``ps.segs is not None``) evaluate through the
    specialized path below: the descriptors statically trim and restructure
    how the traced operands are consumed.
    """
    if ps.eff is not None:
        return _stage_apply_eff(ps, ops_l, src, layer)
    if ps.segs is not None:
        return _stage_apply_seg(ps, ops_l, src, layer)
    cur = [0]

    def nxt():
        a = ops_l[cur[0]]
        cur[0] += 1
        return a

    b = src.shape[1]
    out = jnp.zeros((ps.out_dim, b), jnp.float32)
    inbuf = None
    if ps.has_prep:
        psrc, ptgt = nxt(), nxt()
        # kept-column gather + weight-sharing segment-sum in one scatter-add;
        # padding pairs add src[0] into the dead row k_alloc-1 (never read)
        inbuf = jnp.zeros((ps.k_alloc, b), jnp.float32).at[ptgt].add(src[psrc])
    if ps.has_fp:
        gidx, gcoef, outg = nxt(), nxt(), nxt()
        n_lv, r_rows, s_terms = gidx.shape
        work = None
        for p in range(n_lv):  # CSD shift-add: sum_s sign * 2^exp * prev[idx]
            buf = inbuf if p == 0 else work
            g = buf[gidx[p].reshape(-1)].reshape(r_rows, s_terms, b)
            # einsum: XLA lowers the S-contraction to a batched dot, which
            # vectorizes ~2.5x better on CPU than broadcast-multiply-sum
            work = jnp.einsum("rs,rsb->rb", gcoef[p], g)
        wext = jnp.concatenate([work, jnp.zeros((1, b), jnp.float32)], axis=0)
        n_j = outg.shape[0]
        out = out + wext[outg.reshape(-1)].reshape(n_j, ps.out_dim, b).sum(axis=0)
    if ps.fs_mat is not None:
        out = out + nxt() @ inbuf
    if ps.dw_mat is not None:
        out = out + nxt() @ src
    if ps.bias is not None:
        out = out + nxt()[:, None]
    return out


def _stage_apply_eff(ps: PackedStage, ops_l, src, layer: int):
    """Folded-effective stage evaluation: src [D_src, B] -> [O, B].

    One GEMM against the stage's composed effective matrix
    (:attr:`PackedStage.eff`) — the minimum-dispatch lowering for the
    interpreter path, where per-op dispatch and batch-scaled gather traffic,
    not arithmetic, bound the decode step."""
    out = ops_l[0] @ src
    if ps.bias is not None:
        if np.any(ps.bias[layer]):
            out = out + ops_l[1][:, None]
    return out


def _stage_apply_seg(ps: PackedStage, ops_l, src, layer: int):
    """Segment-packed stage evaluation: src [D_src, B] -> [O, B].

    Index *values* come from the traced operands (Pallas forbids closed-over
    constants), but the ``segs`` descriptors and the numpy mirrors on ``ps``
    are static, so the structure specializes at trace time: the per-level
    gather shrinks to the run-length-sorted active prefix at its live term
    width, pure-identity levels are skipped, ended chains continue as a
    contiguous slice copy instead of an identity gather, contiguous output
    windows lower to ``lax.slice``, and all-zero dense blocks drop out.
    """
    cur = [0]

    def nxt():
        a = ops_l[cur[0]]
        cur[0] += 1
        return a

    b = src.shape[1]
    out = jnp.zeros((ps.out_dim, b), jnp.float32)
    inbuf = None
    if ps.has_prep:
        psrc_t, ptgt_t = nxt(), nxt()
        tgt = ps.prep_tgt[layer].astype(np.int64)
        real = tgt < ps.k_alloc - 1  # padding pairs target the dead row
        k_used = int(tgt[real].max()) + 1 if real.any() else 0
        if (k_used == int(real.sum())
                and np.array_equal(tgt[:k_used], np.arange(k_used))):
            # no weight sharing and pairs laid out in target order: the
            # scatter-add collapses to a gather (+ zero-fill when padded)
            inbuf = src[psrc_t[:k_used]]
            if k_used < ps.k_alloc:
                inbuf = jnp.concatenate(
                    [inbuf,
                     jnp.zeros((ps.k_alloc - k_used, b), jnp.float32)])
        else:
            inbuf = jnp.zeros((ps.k_alloc, b), jnp.float32) \
                .at[ptgt_t].add(src[psrc_t])
    if ps.has_fp:
        gidx_t, gcoef_t, outg_t = nxt(), nxt(), nxt()
        r_max = ps.gidx.shape[2]
        work = None
        for p in range(ps.gidx.shape[1]):
            a_end, r_used, s_live = (int(v) for v in ps.segs[layer, p])
            buf = inbuf if p == 0 else work
            if p > 0 and a_end == 0:
                continue  # every chain already ended: identity level (rows
                # past r_used are already zero in the carried work buffer)
            s_l = max(s_live, 1)  # identity rows still read term column 0
            # two lowerings of the same level, chosen by gather volume:
            # the row-segmented form saves the einsum over the identity run
            # and zero tail but costs extra slice/concat ops — on the
            # op-overhead-dominated interpreter that only pays off once the
            # rows saved carry enough data; otherwise keep the 2-op full-row
            # einsum, column-trimmed to the live term width.
            seg_rows = (r_used > a_end and p > 0) or r_max > r_used
            if seg_rows and (r_max - a_end) * s_l * b >= 65536:
                pieces = []
                if a_end:
                    g = buf[gidx_t[p, :a_end, :s_l].reshape(-1)] \
                        .reshape(a_end, s_l, b)
                    pieces.append(
                        jnp.einsum("rs,rsb->rb", gcoef_t[p, :a_end, :s_l], g))
                if r_used > a_end:  # ended chains: contiguous identity run
                    if p == 0:  # 0-depth chains gather their own inbuf rows
                        pieces.append(buf[gidx_t[p, a_end:r_used, 0]])
                    else:
                        pieces.append(buf[a_end:r_used])
                if r_max > r_used:
                    pieces.append(jnp.zeros((r_max - r_used, b), jnp.float32))
                work = (pieces[0] if len(pieces) == 1
                        else jnp.concatenate(pieces))
            else:
                g = buf[gidx_t[p, :, :s_l].reshape(-1)].reshape(r_max, s_l, b)
                work = jnp.einsum("rs,rsb->rb", gcoef_t[p, :, :s_l], g)
        arange_o = np.arange(ps.out_dim)
        outg_np = ps.outg[layer].astype(np.int64)
        kept = [j for j in range(outg_np.shape[0])
                if not np.all(outg_np[j] == r_max)]  # drop all-padding rows
        if len(kept) == 1 and np.array_equal(
                outg_np[kept[0]], arange_o + outg_np[kept[0], 0]):
            # single contiguous window: one slice, no padding row needed
            out = out + jax.lax.slice_in_dim(
                work, int(outg_np[kept[0], 0]),
                int(outg_np[kept[0], 0]) + ps.out_dim, axis=0)
        elif kept:
            src_buf = work
            if any(np.any(outg_np[j] == r_max) for j in kept):
                # padded entries read the appended zero row
                src_buf = jnp.concatenate(
                    [work, jnp.zeros((1, b), jnp.float32)], axis=0)
            idx = outg_t[np.asarray(kept)] if len(kept) < outg_np.shape[0] \
                else outg_t
            out = out + src_buf[idx.reshape(-1)] \
                .reshape(len(kept), ps.out_dim, b).sum(axis=0)
    if ps.fs_mat is not None:
        m = nxt()
        if np.any(ps.fs_mat[layer]):
            out = out + m @ inbuf
    if ps.dw_mat is not None:
        m = nxt()
        if np.any(ps.dw_mat[layer]):
            out = out + m @ src
    if ps.bias is not None:
        v = nxt()
        if np.any(ps.bias[layer]):
            out = out + v[:, None]
    return out


def _load_refs(refs):
    """Read operand refs once; per-layer slices are taken off the values."""
    return [r[...] for r in refs]


def step_plan_matmul(stages: dict[str, PackedStage], *, n_heads: int,
                     n_kv_heads: int, head_dim: int, d_ff: int, norm: str,
                     rope: bool, x0, pos, cos, sin, ln1, ln2, kc, vc, kpos,
                     moe: dict | None = None, window: int | None = None,
                     interpret: bool | None = None):
    """Whole decode step in ONE launch for all L identical layers.

      x0   [d, B] f32    embedded tokens (feature-major)
      pos  [B] int32     decode positions (-1 = inactive slot)
      cos/sin [B, hd/2]  rope tables for ``pos`` (None when rope=False)
      ln1/ln2 [L, d]     rms weights (None when norm == "nonparam")
      kc/vc [L, B, S, Hkv, hd], kpos [L, B, S]   KV cache view

    ``moe`` (whole-step MoE plans): replaces the dense FFN with the full
    routed block *in-kernel* — router logits/softmax/top-k, capacity-bounded
    rank-and-scatter dispatch, the two expert super-stages ("eg" fused
    gate+up over all experts e-major, SwiGLU, "ed" downs) and the gated
    combine — so an MoE layer costs zero extra launches.  Keys: ``router``
    [L, d, E] f32 numpy, ``n_experts``, ``top_k``, ``capacity_factor``,
    ``norm_topk``, ``min_capacity``, ``d_ff`` (= E * d_ff_expert).  The
    routing math mirrors ``models.moe.moe_ffn`` exactly (the capacity is the
    same static function of B), so plan and fallback decode agree.

    Returns (y [d, B] f32, k_new [L, B, Hkv, hd] f32, v_new …): the final
    hidden state and the per-layer K/V rows for the caller to scatter back
    into the cache (contiguous or paged) outside the kernel.
    """
    if not resolve_interpret(interpret):
        raise NotImplementedError(
            "step plans target the interpreter path; compiled TPU uses the "
            "per-region grouped kernels")
    record_launch()  # the whole step is ONE pallas_call
    n_layers, b, smax, n_kv, hd = kc.shape
    d = x0.shape[0]
    half = hd // 2
    stage_order = ("qkv", "o", "eg", "ed") if moe is not None \
        else ("qkv", "o", "gu", "dn")
    if moe is not None:
        n_exp, top_k = moe["n_experts"], moe["top_k"]
        cap = int(max(moe.get("min_capacity", 4),
                      round(b * top_k * moe["capacity_factor"] / n_exp)))
        eff_total = moe["d_ff"]  # E * d_ff_expert

    inputs = [x0.astype(jnp.float32), pos.astype(jnp.int32)]
    if rope:
        inputs += [cos.astype(jnp.float32), sin.astype(jnp.float32)]
    if norm == "rms":
        inputs += [jnp.asarray(ln1, jnp.float32), jnp.asarray(ln2, jnp.float32)]
    inputs += [kc.astype(jnp.float32), vc.astype(jnp.float32),
               kpos.astype(jnp.int32)]
    if moe is not None:
        inputs.append(jnp.asarray(moe["router"], jnp.float32))  # [L, d, E]
    counts = []
    for name in stage_order:
        ops_ = stages[name].operands()
        counts.append(len(ops_))
        inputs += [jnp.asarray(a) for a in ops_]

    def kernel(*refs):
        i = [0]

        def take(n=1):
            r = refs[i[0]: i[0] + n]
            i[0] += n
            return r if n > 1 else r[0]

        x0_ref, pos_ref = take(), take()
        cos_ref = sin_ref = None
        if rope:
            cos_ref, sin_ref = take(), take()
        ln1_ref = ln2_ref = None
        if norm == "rms":
            ln1_ref, ln2_ref = take(), take()
        kc_ref, vc_ref, kp_ref = take(), take(), take()
        router_ref = take() if moe is not None else None
        stage_refs = {}
        for name, n in zip(stage_order, counts):
            stage_refs[name] = refs[i[0]: i[0] + n]
            i[0] += n
        y_ref, kn_ref, vn_ref = refs[i[0]:]

        def norm_fn(v, w):
            if norm == "rms":
                var = jnp.mean(v * v, axis=0, keepdims=True)
                return v * jax.lax.rsqrt(var + 1e-6) * w[:, None]
            mu = jnp.mean(v, axis=0, keepdims=True)
            var = jnp.mean((v - mu) ** 2, axis=0, keepdims=True)
            return (v - mu) * jax.lax.rsqrt(var + 1e-5)

        pos_v = pos_ref[...]
        cos_v = cos_ref[...][:, None, :] if rope else None
        sin_v = sin_ref[...][:, None, :] if rope else None
        kc_v, vc_v, kp_v = kc_ref[...], vc_ref[...], kp_ref[...]
        ln1_v = ln1_ref[...] if norm == "rms" else None
        ln2_v = ln2_ref[...] if norm == "rms" else None
        sidx = jax.lax.broadcasted_iota(jnp.int32, (b, smax), 1)
        # sliding window: the cache is a ring buffer (slot = pos % smax) and
        # keys older than the window are masked, matching attention_decode
        slot_v = (jnp.where(pos_v >= 0, pos_v % smax, -1)
                  if window is not None else pos_v)
        hit = sidx == slot_v[:, None]
        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
        nq = n_heads

        router_v = router_ref[...] if moe is not None else None

        def moe_block(layer, sops, h2):
            """Routed FFN in-kernel: h2 [d, B] -> [d, B] (moe_ffn's math)."""
            router = router_v[layer]  # [d, E]
            xt = h2.T  # [B, d] token-major, matching moe_ffn's layout
            logits = xt @ router  # [B, E]
            probs = jax.nn.softmax(logits, axis=-1)
            gates, sel = jax.lax.top_k(probs, top_k)  # [B, k]
            if moe["norm_topk"]:
                gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
            sel_oh = jax.nn.one_hot(sel, n_exp, dtype=jnp.int32)  # [B, k, E]
            flat_oh = sel_oh.reshape(b * top_k, n_exp)
            ranks = (jnp.cumsum(flat_oh, axis=0) - flat_oh) \
                .reshape(b, top_k, n_exp)
            rank = jnp.sum(ranks * sel_oh, axis=-1)  # [B, k]
            keep = rank < cap
            slot = sel * cap + jnp.minimum(rank, cap - 1)
            slot = jnp.where(keep, slot, n_exp * cap)  # OOB => dropped
            buf = jnp.zeros((n_exp * cap, d), jnp.float32)
            for j in range(top_k):
                buf = buf.at[slot[:, j]].add(xt, mode="drop")
            # e-major flatten for the expert super-stages: [E*d, C]
            src = buf.reshape(n_exp, cap, d).transpose(0, 2, 1) \
                .reshape(n_exp * d, cap)
            eg = _stage_apply(stages["eg"], sops["eg"], src, layer)
            hf = jax.nn.silu(eg[:eff_total]) * eg[eff_total:]
            ob = _stage_apply(stages["ed"], sops["ed"], hf, layer)
            out_buf = ob.reshape(n_exp, d, cap).transpose(0, 2, 1) \
                .reshape(n_exp * cap, d)
            y = jnp.zeros((b, d), jnp.float32)
            for j in range(top_k):
                g = jnp.take(out_buf,
                             jnp.minimum(slot[:, j], n_exp * cap - 1), axis=0)
                y = y + (gates[:, j] * keep[:, j])[:, None] * g
            return y.T

        stage_vals = {name: _load_refs(stage_refs[name])
                      for name in stage_order}
        x = x0_ref[...]  # [d, B], carried across the in-kernel layer loop
        for layer in range(n_layers):
            sops = {name: [v[layer] for v in stage_vals[name]]
                    for name in stage_order}
            h = norm_fn(x, ln1_v[layer] if ln1_v is not None else None)
            qkv = _stage_apply(stages["qkv"], sops["qkv"], h, layer)
            qb = qkv[: nq * hd].reshape(nq, hd, b).transpose(2, 0, 1)
            kb = qkv[nq * hd: (nq + n_kv) * hd] \
                .reshape(n_kv, hd, b).transpose(2, 0, 1)
            vb = qkv[(nq + n_kv) * hd:].reshape(n_kv, hd, b).transpose(2, 0, 1)
            if rope:
                def rot(v):
                    v1, v2 = v[..., :half], v[..., half:]
                    return jnp.concatenate([v1 * cos_v - v2 * sin_v,
                                            v2 * cos_v + v1 * sin_v], axis=-1)

                qb, kb = rot(qb), rot(kb)
            kn_ref[layer] = kb
            vn_ref[layer] = vb
            # score the stale cache and patch the current token's column in
            # score space: merging the new K/V row into a [B, S, Hkv, hd]
            # cache copy per layer costs more memory traffic than the whole
            # einsum, and the hit column is one-hot so the patch is exact
            qg = qb.reshape(b, n_kv, nq // n_kv, hd)
            scores = jnp.einsum("bhgd,bshd->bhgs", qg, kc_v[layer])
            s_new = jnp.einsum("bhgd,bhd->bhg", qg, kb)
            scores = jnp.where(hit[:, None, None, :], s_new[..., None], scores)
            ok = (kp_v[layer] >= 0) & (kp_v[layer] <= pos_v[:, None])
            if window is not None:
                ok = ok & (kp_v[layer] > pos_v[:, None] - window)
            valid = jnp.where(hit, pos_v[:, None] >= 0, ok)
            mask = jnp.where(valid, 0.0, _NEG)
            probs = jax.nn.softmax(scores * scale + mask[:, None, None, :],
                                   axis=-1)
            hitf = hit.astype(jnp.float32)[:, None, None, :]
            p_hit = jnp.sum(probs * hitf, axis=-1)  # weight on the new row
            att = jnp.einsum("bhgs,bshd->bhgd", probs * (1.0 - hitf),
                             vc_v[layer]) + p_hit[..., None] * vb[:, :, None, :]
            x = x + _stage_apply(stages["o"], sops["o"],
                                 att.reshape(b, nq * hd).T, layer)
            h2 = norm_fn(x, ln2_v[layer] if ln2_v is not None else None)
            if moe is not None:
                x = x + moe_block(layer, sops, h2)
            else:
                gu = _stage_apply(stages["gu"], sops["gu"], h2, layer)
                hf = jax.nn.silu(gu[:d_ff]) * gu[d_ff:]
                x = x + _stage_apply(stages["dn"], sops["dn"], hf, layer)
        y_ref[...] = x

    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((d, b), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, b, n_kv, hd), jnp.float32),
            jax.ShapeDtypeStruct((n_layers, b, n_kv, hd), jnp.float32),
        ],
        interpret=True,
    )(*inputs)


def moe_plan_matmul(stage_a: PackedStage, stage_b: PackedStage, *,
                    d_ff_total: int, src, interpret: bool | None = None):
    """One MoE layer's expert FFNs in ONE launch: src [E*d, C] -> [E*d, C].

    Stage A emits all experts' gates at rows [0, E*dff) and ups at
    [E*dff, 2*E*dff) (e-major); SwiGLU runs in-kernel; stage B applies the
    down projections.  Replaces the three grouped ``expert_mm`` dispatches.
    """
    if not resolve_interpret(interpret):
        raise NotImplementedError(
            "MoE plans target the interpreter path; compiled TPU uses the "
            "per-region grouped kernels")
    record_launch()
    d_src, c = src.shape
    n_a = len(stage_a.operands())
    inputs = [src.astype(jnp.float32)]
    for ps in (stage_a, stage_b):
        inputs += [jnp.asarray(a) for a in ps.operands()]

    def kernel(*refs):
        src_ref = refs[0]
        a_ops = [v[0] for v in _load_refs(refs[1: 1 + n_a])]
        b_ops = [v[0] for v in _load_refs(refs[1 + n_a: -1])]
        out_ref = refs[-1]
        h = _stage_apply(stage_a, a_ops, src_ref[...])
        hf = jax.nn.silu(h[:d_ff_total]) * h[d_ff_total:]
        out_ref[...] = _stage_apply(stage_b, b_ops, hf)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((stage_b.out_dim, c), jnp.float32),
        interpret=True,
    )(*inputs)


def stage_matmul(ps: PackedStage, src, *, interpret: bool | None = None):
    """Apply one stage standalone: src [L, D_src, B] -> [L, O, B].

    Unit-test surface for the stage contract (and a building block for
    plans over non-transformer families).
    """
    if not resolve_interpret(interpret):
        raise NotImplementedError("stage plans target the interpreter path")
    record_launch()
    n_layers, d_src, b = src.shape
    inputs = [src.astype(jnp.float32)] + [jnp.asarray(a) for a in ps.operands()]

    def kernel(*refs):
        src_v = refs[0][...]
        vals = _load_refs(refs[1:-1])
        for layer in range(n_layers):
            refs[-1][layer] = _stage_apply(ps, [v[layer] for v in vals],
                                           src_v[layer], layer)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_layers, ps.out_dim, b), jnp.float32),
        interpret=True,
    )(*inputs)
