"""Pallas TPU kernel: LCC factor application  y = F @ x.

TPU adaptation of the paper's shift-add evaluation (DESIGN.md Sec. 2): the
factor F (rows = at most S signed powers of two) is *stored compactly* in HBM
as (idx, exp, sign) streams — ~S*(2+1) bytes/row instead of 2*K bytes/row
dense bf16.  Each grid step decompresses one (bn x bk) tile of F into VMEM via
a vectorized one-hot * 2^exp construction and feeds the MXU.  Compute stays
systolic; HBM traffic drops — exactly what matters for memory-bound decode.

Layout:
  idx  [N, S] int32   column index of term s of row n
  exp  [N, S] int8    exponent (power of two)
  sign [N, S] int8    {-1, 0, +1}; 0 marks an unused slot
  x    [K, B]         activations (features major so y = F x is a plain dot)
  out  [N, B] f32

Grid (n_blocks, k_blocks, b_blocks); K is the contraction axis — the output
tile is revisited across k and accumulated in place.

``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere (see
``repro.kernels.dispatch``).  Whole FP chains should prefer the fused
``lcc_chain_matmul`` — one launch for every factor of every slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret

__all__ = ["lcc_factor_matmul"]


def _kernel(idx_ref, exp_ref, sign_ref, x_ref, o_ref, *, block_k: int, s_terms: int):
    k_blk = pl.program_id(1)
    k0 = k_blk * block_k

    @pl.when(k_blk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]  # [bn, S] int32 (global column ids)
    exp = exp_ref[...].astype(jnp.float32)
    sign = sign_ref[...].astype(jnp.float32)
    bn = idx.shape[0]

    # decompress: dense [bn, bk] tile of F restricted to this k block
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, block_k), 1) + k0
    tile = jnp.zeros((bn, block_k), jnp.float32)
    for s in range(s_terms):
        val = sign[:, s] * jnp.exp2(exp[:, s])  # 2^e exact in f32
        hit = (idx[:, s][:, None] == cols).astype(jnp.float32)
        tile = tile + hit * val[:, None]

    x = x_ref[...].astype(jnp.float32)  # [bk, bb]
    o_ref[...] += jnp.dot(tile, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "block_b", "interpret"))
def lcc_factor_matmul(
    idx: jnp.ndarray,
    exp: jnp.ndarray,
    sign: jnp.ndarray,
    x: jnp.ndarray,
    block_n: int = 128,
    block_k: int = 128,
    block_b: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y[N, B] = F @ x where F is the compact LCC factor (idx, exp, sign)."""
    n, s_terms = idx.shape
    k, b = x.shape
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    block_b = min(block_b, b)
    if n % block_n or k % block_k or b % block_b:
        raise ValueError(f"shapes ({n},{k},{b}) must tile by ({block_n},{block_k},{block_b})")
    grid = (n // block_n, k // block_k, b // block_b)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, s_terms=s_terms),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, s_terms), lambda i, j, p: (i, 0)),
            pl.BlockSpec((block_n, s_terms), lambda i, j, p: (i, 0)),
            pl.BlockSpec((block_n, s_terms), lambda i, j, p: (i, 0)),
            pl.BlockSpec((block_k, block_b), lambda i, j, p: (j, p)),
        ],
        out_specs=pl.BlockSpec((block_n, block_b), lambda i, j, p: (i, p)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(idx, exp, sign, x)
