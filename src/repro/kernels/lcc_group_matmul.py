"""Pallas TPU kernel: grouped fused LCC evaluation — G decompositions, ONE launch.

``lcc_chain_matmul`` fuses every factor of every slice of *one* decomposition
into a single launch.  A decode step, however, touches many decompositions at
once: the experts of an MoE layer (each token's top-k experts apply their own
chains), the q/k/v projections of an attention layer (same input, three
compressed maps), the r/k/v/g time-mix projections of RWKV-6.  Launching one
``pallas_call`` per site brings back exactly the per-launch overhead the fused
chain kernel removed — so this kernel adds a leading *group* axis and applies
G whole decompositions in one dispatch:

  idx  [G, E, P, N_pad, S] int32   term column index (slice e of group g)
  exp  [G, E, P, N_pad, S] int8    power-of-two exponent
  sign [G, E, P, N_pad, S] int8    {-1, 0, +1}; 0 = unused slot / padding
  x    [G, E, D_pad, B_pad] f32    per-group slice inputs, zero-padded
  out  [G, N_pad, B_pad] f32       group g's output, accumulated over its e

Groups are padded to common (E, P, N_pad, S, D_pad) by
``repro.kernels.ops.pack_group``: missing slices carry sign == 0 everywhere
(they decompress to a zero factor and contribute nothing), short chains are
right-padded with identity factors, and narrow groups ride the shared D_pad
with zero rows — the same invariants ``lcc_chain_matmul`` already relies on.

Grid (G, b_blocks, E): slices innermost, so group g's output tile is revisited
across e and accumulated in place; the chain-evaluation body is shared with
``lcc_chain_matmul`` (``slice_axis=2``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import record_launch, resolve_interpret
from .lcc_chain_matmul import _kernel

__all__ = ["lcc_group_matmul"]


def lcc_group_matmul(
    idx: jnp.ndarray,
    exp: jnp.ndarray,
    sign: jnp.ndarray,
    x: jnp.ndarray,
    block_b: int = 128,
    first_width: int | None = None,
    interpret: bool | None = None,
    use_gather: bool | None = None,
) -> jnp.ndarray:
    """y[G, N_pad, B_pad] = per-group sum_e chain_{g,e}(x[g, e]) — one launch.

    Same contract as :func:`~repro.kernels.lcc_chain_matmul.lcc_chain_matmul`
    per group; ``first_width`` is shared across groups (the max padded slice
    width — narrower groups read zero-padded columns, which contribute 0).
    """
    record_launch()  # un-jitted: counts once per pallas_call a trace emits
    return _lcc_group_matmul(idx, exp, sign, x, block_b=block_b,
                             first_width=first_width, interpret=interpret,
                             use_gather=use_gather)


@functools.partial(jax.jit, static_argnames=("block_b", "first_width",
                                             "interpret", "use_gather"))
def _lcc_group_matmul(
    idx: jnp.ndarray,
    exp: jnp.ndarray,
    sign: jnp.ndarray,
    x: jnp.ndarray,
    block_b: int = 128,
    first_width: int | None = None,
    interpret: bool | None = None,
    use_gather: bool | None = None,
) -> jnp.ndarray:
    g_groups, e_slices, p_factors, n_pad, s_terms = idx.shape
    xg, xe, d_pad, b_pad = x.shape
    if (xg, xe) != (g_groups, e_slices):
        raise ValueError(f"group/slice mismatch: idx has {(g_groups, e_slices)},"
                         f" x has {(xg, xe)}")
    if d_pad < n_pad:
        raise ValueError(f"D_pad={d_pad} must cover N_pad={n_pad}")
    first_width = d_pad if first_width is None else min(first_width, d_pad)
    block_b = min(block_b, b_pad)
    if b_pad % block_b:
        raise ValueError(f"B_pad={b_pad} must tile by block_b={block_b}")
    run_interpret = resolve_interpret(interpret)
    if use_gather is None:
        use_gather = run_interpret
    grid = (g_groups, b_pad // block_b, e_slices)
    return pl.pallas_call(
        functools.partial(_kernel, p_factors=p_factors, s_terms=s_terms,
                          n_pad=n_pad, d_pad=d_pad, first_width=first_width,
                          use_gather=use_gather, slice_axis=2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, p_factors, n_pad, s_terms),
                         lambda g, b, e: (g, e, 0, 0, 0)),
            pl.BlockSpec((None, None, p_factors, n_pad, s_terms),
                         lambda g, b, e: (g, e, 0, 0, 0)),
            pl.BlockSpec((None, None, p_factors, n_pad, s_terms),
                         lambda g, b, e: (g, e, 0, 0, 0)),
            pl.BlockSpec((None, None, d_pad, block_b),
                         lambda g, b, e: (g, e, 0, b)),
        ],
        out_specs=pl.BlockSpec((None, n_pad, block_b), lambda g, b, e: (g, 0, b)),
        out_shape=jax.ShapeDtypeStruct((g_groups, n_pad, b_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_pad, block_b), jnp.float32)],
        interpret=run_interpret,
    )(idx, exp, sign, x.astype(jnp.float32))
