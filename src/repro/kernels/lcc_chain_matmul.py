"""Pallas TPU kernel: fused whole-chain LCC evaluation  y = sum_e (F_P ... F_1) x_e.

The paper's value proposition is that an LCC factor chain is *cheaper than the
dense matmul it replaces* — but launching one ``pallas_call`` per factor (the
pre-fusion runtime) round-trips every intermediate through HBM, exactly the
memory traffic that dominates compressed-inference cost.  This kernel applies
an entire FP decomposition — every factor of every vertical slice (paper
eq. (3)) — in ONE launch, holding the running vector in VMEM scratch the whole
way; only the inputs' compact (idx, exp, sign) streams and the final output
tile touch HBM.

Packed multi-slice layout (built by ``repro.kernels.ops.pack_decomposition``):

  idx  [E, P, N_pad, S] int32  column index of term s of row n, factor p, slice e
  exp  [E, P, N_pad, S] int8   exponent (power of two)
  sign [E, P, N_pad, S] int8   {-1, 0, +1}; 0 marks an unused slot / padded row
  x    [E, D_pad, B_pad] f32   slice inputs, zero-padded rows
  out  [N_pad, B_pad] f32      accumulated over slices e

with ``D_pad = max(N_pad, max slice width, padded)`` the width of the running
vector carried in scratch.  Chains shorter than P are right-padded with
identity factors (idx[n] = n, sign = [1, 0, ...]); rows beyond a factor's true
``out_dim`` carry sign == 0 everywhere, so they decompress to zero rows and
stay exactly zero through the chain.

Grid (b_blocks, E): slices are the fastest axis, so the output tile for a
given b block is revisited across e and accumulated in place (same revisit
pattern as the contraction axis of ``lcc_matmul``).  Per grid step, compiled
mode decompresses each factor into a dense [N_pad, width] VMEM tile via the
vectorized one-hot * 2^exp construction and feeds the MXU — compute stays
systolic, intermediates never leave the chip.  Interpreter (CPU/GPU) mode
evaluates the same chain by direct term gather (S reads per row) instead,
since there is no systolic array to amortize the dense tile — both paths
compute the identical sum_s sign * 2^exp * prev[idx].

``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere (see
``repro.kernels.dispatch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .dispatch import record_launch, resolve_interpret

__all__ = ["lcc_chain_matmul"]


def _kernel(idx_ref, exp_ref, sign_ref, x_ref, o_ref, cur_ref, *,
            p_factors: int, s_terms: int, n_pad: int, d_pad: int,
            first_width: int, use_gather: bool, slice_axis: int = 1):
    """Shared chain-evaluation body; ``slice_axis`` names the grid axis that
    walks the decomposition's slices (1 here, 2 for the grouped launch of
    ``lcc_group_matmul`` which prepends a group axis)."""
    e = pl.program_id(slice_axis)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cur_ref[...] = x_ref[...]  # [D_pad, bb] slice input, zero-padded rows
    for p in range(p_factors):
        idx = idx_ref[p]  # [N_pad, S]
        val = sign_ref[p].astype(jnp.float32) * \
            jnp.exp2(exp_ref[p].astype(jnp.float32))  # 2^e exact in f32
        # factor p reads only the live prefix of the running vector: the slice
        # width for p == 0 (sign==0 padding guarantees idx < first_width
        # there), the previous factor's rows afterwards
        width = first_width if p == 0 else n_pad
        if use_gather:
            # interpreter path: direct term gather — S reads/row, no dense tile
            g = cur_ref[...][idx.reshape(-1)]  # [N_pad*S, bb]
            y = jnp.sum(val.reshape(n_pad, s_terms, 1)
                        * g.reshape(n_pad, s_terms, -1), axis=1)
        else:
            # compiled path: one-hot decompress into a dense [N_pad, width]
            # VMEM tile and feed the MXU — compute stays systolic
            cols = jax.lax.broadcasted_iota(jnp.int32, (n_pad, width), 1)
            tile = jnp.zeros((n_pad, width), jnp.float32)
            for s in range(s_terms):
                hit = (idx[:, s][:, None] == cols).astype(jnp.float32)
                tile = tile + hit * val[:, s][:, None]
            y = jnp.dot(tile, cur_ref[0:width, :],
                        preferred_element_type=jnp.float32)
        cur_ref[0:n_pad, :] = y  # intermediate stays resident in VMEM
        if d_pad > n_pad:
            cur_ref[n_pad:d_pad, :] = jnp.zeros((d_pad - n_pad, y.shape[1]),
                                                jnp.float32)
    o_ref[...] += cur_ref[0:n_pad, :]


def lcc_chain_matmul(
    idx: jnp.ndarray,
    exp: jnp.ndarray,
    sign: jnp.ndarray,
    x: jnp.ndarray,
    block_b: int = 128,
    first_width: int | None = None,
    interpret: bool | None = None,
    use_gather: bool | None = None,
) -> jnp.ndarray:
    """y[N_pad, B_pad] = sum_e chain_e(x[e]) — whole decomposition, one launch.

    ``first_width``: padded max slice width (columns the first factor of any
    chain can address); defaults to D_pad.  Tightening it shrinks the first
    factor's decompress tile from [N_pad, D_pad] to [N_pad, first_width].
    ``use_gather``: force the decompress formulation (default: gather when
    interpreting, one-hot/MXU when compiled); exposed so the compiled
    formulation stays testable under the interpreter.
    """
    record_launch()  # un-jitted: counts once per pallas_call a trace emits
    return _lcc_chain_matmul(idx, exp, sign, x, block_b=block_b,
                             first_width=first_width, interpret=interpret,
                             use_gather=use_gather)


@functools.partial(jax.jit, static_argnames=("block_b", "first_width",
                                             "interpret", "use_gather"))
def _lcc_chain_matmul(
    idx: jnp.ndarray,
    exp: jnp.ndarray,
    sign: jnp.ndarray,
    x: jnp.ndarray,
    block_b: int = 128,
    first_width: int | None = None,
    interpret: bool | None = None,
    use_gather: bool | None = None,
) -> jnp.ndarray:
    e_slices, p_factors, n_pad, s_terms = idx.shape
    xe, d_pad, b_pad = x.shape
    if xe != e_slices:
        raise ValueError(f"slice count mismatch: idx has {e_slices}, x has {xe}")
    if d_pad < n_pad:
        raise ValueError(f"D_pad={d_pad} must cover N_pad={n_pad}")
    first_width = d_pad if first_width is None else min(first_width, d_pad)
    block_b = min(block_b, b_pad)
    if b_pad % block_b:
        raise ValueError(f"B_pad={b_pad} must tile by block_b={block_b}")
    run_interpret = resolve_interpret(interpret)
    if use_gather is None:
        use_gather = run_interpret
    grid = (b_pad // block_b, e_slices)
    return pl.pallas_call(
        functools.partial(_kernel, p_factors=p_factors, s_terms=s_terms,
                          n_pad=n_pad, d_pad=d_pad, first_width=first_width,
                          use_gather=use_gather),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, p_factors, n_pad, s_terms), lambda b, e: (e, 0, 0, 0)),
            pl.BlockSpec((None, p_factors, n_pad, s_terms), lambda b, e: (e, 0, 0, 0)),
            pl.BlockSpec((None, p_factors, n_pad, s_terms), lambda b, e: (e, 0, 0, 0)),
            pl.BlockSpec((None, d_pad, block_b), lambda b, e: (e, 0, b)),
        ],
        out_specs=pl.BlockSpec((n_pad, block_b), lambda b, e: (0, b)),
        out_shape=jax.ShapeDtypeStruct((n_pad, b_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_pad, block_b), jnp.float32)],
        interpret=run_interpret,
    )(idx, exp, sign, x.astype(jnp.float32))
