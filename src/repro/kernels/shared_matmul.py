"""Pallas TPU kernel: weight-sharing input pre-aggregation (paper eq. (10)).

``agg[c, b] = sum_{j: labels[j]==c} x[j, b]`` — the per-cluster scalar sums
that let the centroid matrix replace the full weight matrix.  On TPU the
segment sum is realized as a one-hot(labels) x contraction so it runs on the
MXU; the one-hot tile is built in VMEM from an iota comparison (never
materialized in HBM).

Grid (c_blocks, k_blocks, b_blocks); K is contracted, accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import resolve_interpret

__all__ = ["cluster_segment_sum"]


def _kernel(labels_ref, x_ref, o_ref, *, block_c: int):
    c_blk = pl.program_id(0)
    k_blk = pl.program_id(1)
    c0 = c_blk * block_c

    @pl.when(k_blk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    labels = labels_ref[...]  # [bk] int32
    bk = labels.shape[0]
    clusters = jax.lax.broadcasted_iota(jnp.int32, (block_c, bk), 0) + c0
    onehot = (labels[None, :] == clusters).astype(jnp.float32)  # [bc, bk]
    x = x_ref[...].astype(jnp.float32)  # [bk, bb]
    o_ref[...] += jnp.dot(onehot, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_clusters", "block_c", "block_k", "block_b", "interpret"))
def cluster_segment_sum(
    labels: jnp.ndarray,
    x: jnp.ndarray,
    num_clusters: int,
    block_c: int = 128,
    block_k: int = 128,
    block_b: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """agg[C, B] = segment_sum(x[K, B], labels[K])."""
    k, b = x.shape
    c = num_clusters
    block_c = min(block_c, c)
    block_k = min(block_k, k)
    block_b = min(block_b, b)
    if c % block_c or k % block_k or b % block_b:
        raise ValueError(f"shapes (C={c},K={k},B={b}) must tile by ({block_c},{block_k},{block_b})")
    grid = (c // block_c, k // block_k, b // block_b)
    return pl.pallas_call(
        functools.partial(_kernel, block_c=block_c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k,), lambda i, j, p: (j,)),
            pl.BlockSpec((block_k, block_b), lambda i, j, p: (j, p)),
        ],
        out_specs=pl.BlockSpec((block_c, block_b), lambda i, j, p: (i, p)),
        out_shape=jax.ShapeDtypeStruct((c, b), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(labels, x)
