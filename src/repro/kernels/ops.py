"""jit'd public wrappers around the Pallas kernels.

Bridges ``repro.core.lcc`` decomposition objects (numpy, offline) to the TPU
runtime format: pads factors to block multiples, packs (idx, exp, sign) into
the stacked whole-chain layout of ``lcc_chain_matmul``, applies chains /
decompositions fused (one launch per decomposition), and evaluates
weight-shared layers (paper eq. (10)) as segment-sum + centroid matmul.

Packed layout (see ``lcc_chain_matmul``'s module docstring for the kernel-side
contract): all FP slices of a decomposition stack into [E, P, N_pad, S]
streams; chains shorter than P are right-padded with identity factors, unused
term slots and padded rows carry sign == 0.  FS programs have no factor-chain
form — they fall back to their dense equivalent (an offline/storage format;
DESIGN.md Sec. 2) and are combined outside the fused launch.

Every ``interpret`` parameter defaults to ``None`` = auto-detect: compiled
Pallas on TPU, interpreter on CPU/GPU (``repro.kernels.dispatch``).
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.lcc import LCCChain, LCCDecomposition

from .dispatch import record_launch
from .group_prox import group_prox
from .lcc_chain_matmul import lcc_chain_matmul
from .lcc_group_matmul import lcc_group_matmul
from .lcc_matmul import lcc_factor_matmul
from .shared_matmul import cluster_segment_sum

__all__ = [
    "PackedChain",
    "PackedDecomposition",
    "PackedGroup",
    "PackedStage",
    "pack_chain",
    "pack_decomposition",
    "pack_group",
    "pack_stage",
    "pack_layer",
    "apply_packed_chain",
    "apply_packed_decomposition",
    "apply_packed_group",
    "segment_sum_tpu",
    "shared_matmul_tpu",
    "group_prox",
]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_dim(n: int, block: int) -> int:
    """Seed padding convention: multiples of min(block, n) — small dims stay
    small (interpret mode), dims >= block become block multiples (TPU tiling)."""
    return _round_up(n, min(block, max(n, 1)))


@dataclass(frozen=True)
class PackedChain:
    """One FP chain in the stacked kernel layout: factor axis leading."""

    idx: jnp.ndarray  # [P, N_pad, S] int32
    exp: jnp.ndarray  # [P, N_pad, S] int8
    sign: jnp.ndarray  # [P, N_pad, S] int8
    in_dim: int  # unpadded
    out_dim: int  # unpadded
    d_pad: int  # width of the running vector the kernel carries
    first_width: int  # padded input width addressable by the first factor
    n_factors: int  # real (un-padded) chain length

    @property
    def compact_bytes(self) -> int:
        """HBM bytes in the deployment stream format (int16 idx + int8 code)."""
        return int(3 * int(np.asarray(self.sign != 0).sum()))


@dataclass(frozen=True)
class PackedDecomposition:
    """Whole decomposition: FP slices stacked for one fused launch + dense rest."""

    idx: jnp.ndarray  # [E, P, N_pad, S] int32
    exp: jnp.ndarray  # [E, P, N_pad, S] int8
    sign: jnp.ndarray  # [E, P, N_pad, S] int8
    col_slices: tuple[tuple[int, int], ...]  # E entries (FP slices only)
    dense: tuple[tuple[tuple[int, int], jnp.ndarray], ...]  # non-FP fallback
    in_dim: int
    out_dim: int
    d_pad: int
    first_width: int  # padded max slice width (first-factor column span)
    chain_lengths: tuple[int, ...]  # real factor count per FP slice


def _stack_chain(chain: LCCChain, n_pad: int, s_max: int, p_max: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack one chain's factors into [P, N_pad, S]; identity-pad to p_max."""
    idx = np.zeros((p_max, n_pad, s_max), np.int32)
    exp = np.zeros((p_max, n_pad, s_max), np.int8)
    sgn = np.zeros((p_max, n_pad, s_max), np.int8)
    for p, f in enumerate(chain.factors):
        idx[p, : f.out_dim, : f.s_terms] = f.idx
        exp[p, : f.out_dim, : f.s_terms] = f.exp
        sgn[p, : f.out_dim, : f.s_terms] = f.sign
    for p in range(len(chain.factors), p_max):  # identity wiring: y = prev
        idx[p, :, 0] = np.arange(n_pad)
        sgn[p, :, 0] = 1
    return idx, exp, sgn


def pack_chain(chain: LCCChain, block: int = 128) -> PackedChain:
    """Pack one FP chain into the stacked fused-kernel layout."""
    out_dim = chain.factors[-1].out_dim if chain.factors else chain.in_dim
    n_pad = _pad_dim(max([f.out_dim for f in chain.factors] or [chain.in_dim]),
                     block)
    s_max = max([f.s_terms for f in chain.factors] or [1])
    p_max = max(len(chain.factors), 1)
    k_pad = _pad_dim(chain.in_dim, block)
    d_pad = max(n_pad, k_pad)
    # an empty chain packs as one identity factor whose rows span n_pad
    first_width = k_pad if chain.factors else n_pad
    idx, exp, sgn = _stack_chain(chain, n_pad, s_max, p_max)
    return PackedChain(jnp.asarray(idx), jnp.asarray(exp), jnp.asarray(sgn),
                       in_dim=chain.in_dim, out_dim=out_dim, d_pad=d_pad,
                       first_width=first_width,
                       n_factors=max(len(chain.factors), 1))


def pack_decomposition(dec: LCCDecomposition, block: int = 128
                       ) -> PackedDecomposition:
    """Pack every FP slice chain into ONE stacked multi-slice layout."""
    fp = [((c0, c1), s) for (c0, c1), s in zip(dec.col_slices, dec.slices)
          if isinstance(s, LCCChain)]
    dense = tuple(((c0, c1), jnp.asarray(s.to_dense(), jnp.float32))
                  for (c0, c1), s in zip(dec.col_slices, dec.slices)
                  if not isinstance(s, LCCChain))
    n, k = dec.shape
    if not fp:
        return PackedDecomposition(
            jnp.zeros((0, 1, 1, 1), jnp.int32), jnp.zeros((0, 1, 1, 1), jnp.int8),
            jnp.zeros((0, 1, 1, 1), jnp.int8), (), dense,
            in_dim=k, out_dim=n, d_pad=1, first_width=1, chain_lengths=())
    all_factors = [f for _, ch in fp for f in ch.factors]
    n_pad = _pad_dim(max([f.out_dim for f in all_factors] or [n]), block)
    s_max = max([f.s_terms for f in all_factors] or [1])
    p_max = max(max(len(ch.factors) for _, ch in fp), 1)
    w_pad = _pad_dim(max(c1 - c0 for (c0, c1), _ in fp), block)
    d_pad = max(n_pad, w_pad)
    stacked = [_stack_chain(ch, n_pad, s_max, p_max) for _, ch in fp]
    return PackedDecomposition(
        idx=jnp.asarray(np.stack([s[0] for s in stacked])),
        exp=jnp.asarray(np.stack([s[1] for s in stacked])),
        sign=jnp.asarray(np.stack([s[2] for s in stacked])),
        col_slices=tuple(cs for cs, _ in fp),
        dense=dense, in_dim=k, out_dim=n, d_pad=d_pad, first_width=w_pad,
        chain_lengths=tuple(max(len(ch.factors), 1) for _, ch in fp))


def _pad_batch(b: int, block: int) -> tuple[int, int]:
    bb = min(block, b)
    return bb, _round_up(b, bb)


@dataclass(frozen=True)
class PackedGroup:
    """G packed decompositions re-padded to common dims for ONE grouped launch.

    ``members`` keeps each decomposition's original packing metadata
    (col_slices over its own input, FS dense fallbacks, true in/out dims);
    the stacked (idx, exp, sign) carry the shared-padded factor streams that
    :func:`~repro.kernels.lcc_group_matmul.lcc_group_matmul` consumes.  The
    streams are kept as *numpy* arrays: groups are assembled lazily — often
    inside an active jit trace — and cached numpy constants embed per-trace
    instead of leaking tracers.
    """

    idx: np.ndarray  # [G, E, P, N_pad, S] int32
    exp: np.ndarray  # [G, E, P, N_pad, S] int8
    sign: np.ndarray  # [G, E, P, N_pad, S] int8
    members: tuple[PackedDecomposition, ...]
    d_pad: int
    first_width: int
    waste: dict | None = None  # padding-waste fractions (see pack_group)

    @property
    def n_groups(self) -> int:
        return len(self.members)


def pack_group(members: list[PackedDecomposition]) -> PackedGroup:
    """Re-pad G packed decompositions to common (E, P, N, S, D) dims.

    Padding preserves the kernel invariants: extra term slots and extra rows
    carry sign == 0 (decompress to zero), chains are right-extended with
    identity factors over the shared N_pad, and whole missing slices are
    all-zero-sign (a zero factor chain on zero input — contributes nothing).
    """
    if not members:
        raise ValueError("pack_group needs at least one member")
    e_max = max([m.idx.shape[0] for m in members] + [1])
    p_max = max([m.idx.shape[1] for m in members if m.idx.shape[0]] + [1])
    n_max = max([m.idx.shape[2] for m in members if m.idx.shape[0]] + [1])
    s_max = max([m.idx.shape[3] for m in members if m.idx.shape[0]] + [1])
    d_pad = max([m.d_pad for m in members if m.idx.shape[0]] + [n_max])
    first_width = max([m.first_width for m in members if m.idx.shape[0]] + [1])
    gi = np.zeros((len(members), e_max, p_max, n_max, s_max), np.int32)
    ge = np.zeros(gi.shape, np.int8)
    gs = np.zeros(gi.shape, np.int8)
    ident = np.arange(n_max, dtype=np.int32)
    for g, m in enumerate(members):
        e, p, n, s = m.idx.shape
        if e == 0:
            continue  # FS-only member: dense fallback handles everything
        gi[g, :e, :p, :n, :s] = np.asarray(m.idx)
        ge[g, :e, :p, :n, :s] = np.asarray(m.exp)
        gs[g, :e, :p, :n, :s] = np.asarray(m.sign)
        # chains shorter than the group max continue as identity factors
        gi[g, :e, p:, :, 0] = ident
        gs[g, :e, p:, :, 0] = 1
    # padding-waste accounting: a (slice, factor, row) slot whose sign terms
    # are all zero does no work but is still streamed and iterated — report
    # the fraction per group so badly-matched group members are visible
    zero_rows = (gs == 0).all(axis=-1)  # [G, E, P, N]
    zero_slices = zero_rows.all(axis=(2, 3))  # [G, E]
    row_frac = zero_rows.reshape(len(members), -1).mean(axis=1)
    slice_frac = zero_slices.mean(axis=1)
    waste = {
        "row_waste": [float(f) for f in row_frac],
        "slice_waste": [float(f) for f in slice_frac],
        "mean_row_waste": float(row_frac.mean()),
        "shape": list(gi.shape),
    }
    if waste["mean_row_waste"] > 0.5:
        warnings.warn(
            f"pack_group: {waste['mean_row_waste']:.0%} of padded rows carry "
            f"sign==0 across {len(members)} members (shape {gi.shape}) — "
            "group members are badly matched; consider splitting the group",
            stacklevel=2)
    return PackedGroup(idx=gi, exp=ge, sign=gs, members=tuple(members),
                       d_pad=d_pad, first_width=first_width, waste=waste)


def apply_packed_group(pg: PackedGroup, xs, *, block: int = 128,
                       interpret: bool | None = None) -> list[jnp.ndarray]:
    """y_g = W_hat_g @ xs[g] for every group member — ONE fused launch.

    ``xs`` is a per-member list of [K_g, B] inputs (all the same B; K_g is the
    member's own in_dim — members need not agree on input width because each
    slices/pads its own columns).  FS dense-fallback slices are added per
    member outside the launch, exactly like :func:`apply_packed_decomposition`.
    """
    if len(xs) != len(pg.members):
        raise ValueError(f"{len(pg.members)} group members, {len(xs)} inputs")
    b = xs[0].shape[1]
    bb, b_pad = _pad_batch(b, block)
    e_max = pg.idx.shape[1]
    any_fp = any(m.col_slices for m in pg.members)
    y = None
    if any_fp:
        stacks = []
        for m, x in zip(pg.members, xs):
            if x.shape[0] != m.in_dim:
                raise ValueError(f"x has {x.shape[0]} rows, member expects "
                                 f"in_dim={m.in_dim}")
            slabs = [jnp.pad(x[c0:c1].astype(jnp.float32),
                             ((0, pg.d_pad - (c1 - c0)), (0, b_pad - b)))
                     for c0, c1 in m.col_slices]
            slabs += [jnp.zeros((pg.d_pad, b_pad), jnp.float32)
                      ] * (e_max - len(slabs))
            stacks.append(jnp.stack(slabs))
        y = lcc_group_matmul(pg.idx, pg.exp, pg.sign, jnp.stack(stacks),
                             block_b=bb, first_width=pg.first_width,
                             interpret=interpret)  # [G, N_pad, B_pad]
    outs = []
    for g, (m, x) in enumerate(zip(pg.members, xs)):
        yg = y[g, : m.out_dim, :b] if (y is not None and m.col_slices) else None
        for (c0, c1), w in m.dense:
            part = w @ x[c0:c1].astype(jnp.float32)
            yg = part if yg is None else yg + part
        if yg is None:
            raise ValueError("empty decomposition in group: no FP or dense slices")
        outs.append(yg)
    return outs


def _apply_stacked_per_factor(idx, exp, sign, x_pad, chain_lengths, *,
                              block: int, interpret: bool | None):
    """Per-factor launch loop over the stacked layout — the pre-fusion runtime,
    kept as the fused kernel's wall-clock baseline (benchmarks) and as an
    independent second implementation for equivalence tests.  Launches only
    each chain's REAL factors (identity padding exists for the fused stack's
    benefit; a pre-fusion runtime never ran it)."""
    e_slices, _, n_pad, _ = idx.shape
    _, d_pad, b_pad = x_pad.shape
    y = jnp.zeros((n_pad, b_pad), jnp.float32)
    bb = min(block, b_pad)
    for e in range(e_slices):
        cur = x_pad[e]
        for p in range(chain_lengths[e]):
            record_launch()  # one pallas_call per (slice, factor)
            out = lcc_factor_matmul(idx[e, p], exp[e, p], sign[e, p], cur,
                                    block_n=min(block, n_pad),
                                    block_k=min(block, d_pad),
                                    block_b=bb, interpret=interpret)
            cur = jnp.pad(out, ((0, d_pad - n_pad), (0, 0)))
        y = y + cur[:n_pad]
    return y


def apply_packed_chain(pc: PackedChain, x: jnp.ndarray, *, block: int = 128,
                       interpret: bool | None = None,
                       fused: bool = True) -> jnp.ndarray:
    """y[N, B] = (F_P ... F_1) @ x[K, B] — the whole chain in one fused launch.

    Padded rows carry sign==0 slots (value 0) so they stay exactly zero through
    the chain; the final slice recovers the true output dim.
    """
    k, b = x.shape
    if k != pc.in_dim:
        raise ValueError(f"x has {k} rows, chain expects in_dim={pc.in_dim}")
    bb, b_pad = _pad_batch(b, block)
    x_pad = jnp.pad(x.astype(jnp.float32),
                    ((0, pc.d_pad - k), (0, b_pad - b)))[None]
    if fused:
        y = lcc_chain_matmul(pc.idx[None], pc.exp[None], pc.sign[None], x_pad,
                             block_b=bb, first_width=pc.first_width,
                             interpret=interpret)
    else:
        y = _apply_stacked_per_factor(pc.idx[None], pc.exp[None], pc.sign[None],
                                      x_pad, (pc.n_factors,), block=block,
                                      interpret=interpret)
    return y[: pc.out_dim, :b]


def apply_packed_decomposition(packed: PackedDecomposition, x: jnp.ndarray, *,
                               block: int = 128, interpret: bool | None = None,
                               fused: bool = True) -> jnp.ndarray:
    """y = W_hat @ x for a packed decomposition; x [K, B] (or [K] vector).

    All FP slices run in a single ``lcc_chain_matmul`` launch (``fused=True``,
    the default); ``fused=False`` runs the legacy one-``pallas_call``-per-factor
    loop for comparison.  Dense-fallback slices (FS programs) are added on top.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    k, b = x.shape
    if k != packed.in_dim:
        raise ValueError(f"x has {k} rows, decomposition expects "
                         f"in_dim={packed.in_dim}")
    y = None
    e_slices = len(packed.col_slices)
    if e_slices:
        bb, b_pad = _pad_batch(b, block)
        x_pad = jnp.stack([
            jnp.pad(x[c0:c1].astype(jnp.float32),
                    ((0, packed.d_pad - (c1 - c0)), (0, b_pad - b)))
            for c0, c1 in packed.col_slices])
        if fused:
            y = lcc_chain_matmul(packed.idx, packed.exp, packed.sign, x_pad,
                                 block_b=bb, first_width=packed.first_width,
                                 interpret=interpret)
        else:
            y = _apply_stacked_per_factor(packed.idx, packed.exp, packed.sign,
                                          x_pad, packed.chain_lengths,
                                          block=block, interpret=interpret)
        y = y[: packed.out_dim, :b]
    for (c0, c1), w in packed.dense:
        part = w @ x[c0:c1].astype(jnp.float32)
        y = part if y is None else y + part
    if y is None:
        raise ValueError("empty decomposition: no FP or dense slices to apply")
    return y[:, 0] if squeeze else y


def segment_sum_tpu(labels: jnp.ndarray, x: jnp.ndarray, num_clusters: int,
                    *, interpret: bool | None = None) -> jnp.ndarray:
    """Kernel segment-sum with ragged (K, C, B) padded to block multiples.

    Padded K rows are labeled c_pad - 1; when num_clusters is already a block
    multiple that id aliases the last *real* cluster, which stays correct only
    because the padded x rows are zero — keep that invariant when changing the
    padding.
    """
    record_launch()  # cluster_segment_sum is one pallas_call
    k, b = x.shape
    bc = min(128, num_clusters)
    c_pad = _round_up(num_clusters, bc)
    bk = min(128, k)
    k_pad = _round_up(k, bk)
    bb = min(128, b)
    b_pad = _round_up(b, bb)
    lab = jnp.pad(labels.astype(jnp.int32), (0, k_pad - k), constant_values=c_pad - 1) \
        if k_pad != k else labels.astype(jnp.int32)
    xp = jnp.pad(x, ((0, k_pad - k), (0, b_pad - b))) if (k_pad != k or b_pad != b) else x
    agg = cluster_segment_sum(lab, xp, num_clusters=c_pad,
                              block_c=bc, block_k=bk, block_b=bb, interpret=interpret)
    return agg[:num_clusters, :b]


def shared_matmul_tpu(centroids: jnp.ndarray, labels: jnp.ndarray, x: jnp.ndarray,
                      *, interpret: bool | None = None) -> jnp.ndarray:
    """Eq. (10) on TPU: kernel segment-sum then centroid matmul. x [K, B] -> [N, B]."""
    agg = segment_sum_tpu(labels, x, centroids.shape[1], interpret=interpret)
    return centroids.astype(jnp.float32) @ agg


# ---------------------------------------------------------------------------
# layer plans: every compressed site of a layer stage in ONE buffer
# ---------------------------------------------------------------------------
#
# ``pack_group`` still pays one launch per *region* (q/k/v, gate/up, ...).  A
# layer plan goes further: all sites that consume the same activation are
# flattened into a single gather/shift-add *stage*, and all L identical layers
# stack along a leading axis so one ``pallas_call`` with grid (L,) executes the
# whole decode step.  The stage representation is specialized to the ternary /
# CSD structure (core/csd.py): a row is sum_s sign * 2^exp * prev[idx], so the
# kernel needs only integer gathers + shift-adds — no sign-padded dense tiles.
#
# Per stage, for layer l:
#
#   prep_src/prep_tgt [L, M]     scatter-add pairs building the stage input
#                                buffer: inbuf[tgt] += src[src'] implements
#                                both kept-column gather and weight-sharing
#                                segment-sum (tgt = cluster label).  Padding
#                                pairs are (0, K_alloc - 1): they add into a
#                                dead row that nothing downstream reads.
#   gidx/gexp/gsgn [L, P, R, S]  every FP slice of every site, concatenated
#                                along the row axis R; level 0 reads inbuf,
#                                levels >= 1 read the running work buffer.
#                                sign == 0 marks unused slots (rows decompress
#                                to zero); short chains continue as identity.
#   outg [L, J, O]               output gather: out[o] = sum_j work[outg[j,o]]
#                                (J = max FP-slice count of any site; padded
#                                entries point at the all-zero row R).
#   fs_mat [L, O, K_alloc]       FS-program dense fallback applied to inbuf
#                                (column K_alloc - 1, the dead row, is zero).
#   dw_mat [L, O, D_src]         uncovered sites' dense weights (w.T) baked in
#                                so the stage still produces the full output.
#   bias [L, O]                  site biases, summed at their output offsets.


# per-level gather volume (P * R * S instruction slots) above which a stage
# decodes through its folded effective matrix instead of the segment path:
# on the interpreter host per-op dispatch and gather traffic (which scales
# with batch), not arithmetic, bound decode wall-clock, so past this size one
# GEMM per stage-layer wins; below it the segment path is already cheap and
# stays the exercised representation
EFF_GATHER_CUTOFF = 32_768


@dataclass(frozen=True)
class PackedStage:
    """One layer stage (e.g. fused q+k+v) stacked over L layers.

    All arrays are numpy: stages are trace-time constants (they embed in the
    jitted step) and must survive artifact save/load round trips.

    ``segs`` (segment-packed layout, optional): per (layer, level) the row
    space is run-length sorted at pack time — instructions laid out by
    descending chain depth so every level splits into a contiguous *active*
    prefix (rows with a real CSD level, the short irregular gather) followed
    by a contiguous *identity* run (rows whose chains already ended: a plain
    slice copy) and a zero tail.  ``segs[l, p] = (active_end, rows_used,
    live_terms)``.  The descriptors are static: the kernel slices its traced
    operands to the active prefix at the live term width, skips pure-identity
    levels entirely, and lowers contiguous output windows to ``lax.slice``.
    Stages without it (PR 8-era artifacts) evaluate through the original
    full-gather operand path, bit-for-bit unchanged.
    """

    prep_src: np.ndarray | None  # [L, M] int32
    prep_tgt: np.ndarray | None  # [L, M] int32
    gidx: np.ndarray | None  # [L, P, R, S] int32
    gexp: np.ndarray | None  # [L, P, R, S] int8
    gsgn: np.ndarray | None  # [L, P, R, S] int8
    outg: np.ndarray | None  # [L, J, O] int32
    fs_mat: np.ndarray | None  # [L, O, K_alloc] f32
    dw_mat: np.ndarray | None  # [L, O, D_src] f32
    bias: np.ndarray | None  # [L, O] f32
    k_alloc: int  # inbuf rows incl. trailing dead row
    d_src: int  # stage input rows
    out_dim: int  # stage output rows O
    n_layers: int
    site_names: tuple[str, ...]  # compressed sites this stage covers
    segs: np.ndarray | None = None  # [L, P, 3] int32 segment descriptors
    seg_stats: dict | None = None  # run-length stats (not persisted)
    waste: dict | None = None  # padding-waste report (not persisted)

    @property
    def has_prep(self) -> bool:
        return self.prep_src is not None

    @property
    def has_fp(self) -> bool:
        return self.gidx is not None

    @functools.cached_property
    def gcoef(self) -> np.ndarray:
        """``sign * 2**exp`` as f32 [L, P, R, S] — precomputed (exactly: a
        signed power of two is exact in f32) so the kernel pays a single load
        per term instead of two int8 converts, an exp2 and a multiply."""
        return (self.gsgn.astype(np.float32)
                * np.exp2(self.gexp.astype(np.float32)))

    @functools.cached_property
    def _prep_mats(self) -> np.ndarray | None:
        """Prep scatter-add pairs as selection matrices [L, K_alloc, D_src]
        (kept-column gather + weight-sharing segment-sum, dead row zero)."""
        if not self.has_prep:
            return None
        mats = np.zeros((self.n_layers, self.k_alloc, self.d_src), np.float32)
        for l in range(self.n_layers):
            tgt = self.prep_tgt[l].astype(np.int64)
            src = self.prep_src[l].astype(np.int64)
            real = tgt < self.k_alloc - 1  # padding pairs hit the dead row
            np.add.at(mats[l], (tgt[real], src[real]), 1.0)
        return mats

    @functools.cached_property
    def eff(self) -> np.ndarray | None:
        """Whole-stage folded effective matrix [L, O, D_src], or ``None``.

        A decode stage is a fixed linear map: prep scatter-add, P shift-add
        levels (each row ``sum_s sign * 2**exp * prev[idx]``), output gather,
        plus the FS / uncovered-dense fallbacks.  Composing those maps at
        pack time — applying each level's instruction stream to a running
        ``[rows, D_src]`` matrix via gathers, never materializing the
        ``[R, R]`` per-level map — yields one matrix per layer, so the plan
        kernel spends ONE matmul where the segment path spends ~2P gathers
        and einsums whose traffic scales with batch.  The chains stay the
        artifact's source of truth (per-region kernels, roofline, hardware
        export); this is a dispatch-for-memory trade for the interpreter
        host, taken only when the per-level gather volume exceeds
        ``EFF_GATHER_CUTOFF`` so small stages keep exercising the segment
        layout."""
        if not self.has_fp:
            return None
        n_l, n_p, r_max, s = self.gidx.shape
        if n_p * r_max * s <= EFF_GATHER_CUTOFF:
            return None
        w = np.zeros((n_l, self.out_dim, self.d_src), np.float32)
        chunk = 4096  # bounds the [rows, S, D_src] gather transient
        for l in range(n_l):
            m = (self._prep_mats[l] if self.has_prep
                 else np.eye(self.d_src, dtype=np.float32))
            for p in range(n_p):
                idx = self.gidx[l, p].astype(np.int64)
                coef = (self.gcoef[l, p]
                        * (self.gsgn[l, p] != 0)
                        * (idx < m.shape[0]))
                safe = np.clip(idx, 0, m.shape[0] - 1)
                nxt = np.empty((r_max, m.shape[1]), np.float32)
                for r0 in range(0, r_max, chunk):
                    r1 = min(r0 + chunk, r_max)
                    nxt[r0:r1] = np.einsum(
                        "rsd,rs->rd", m[safe[r0:r1]], coef[r0:r1])
                m = nxt
            e = self.outg[l].astype(np.int64)  # [J, O]
            valid = e < r_max  # padded entries read the zero row
            w[l] = np.einsum("jod,jo->od",
                             m[np.clip(e, 0, r_max - 1)],
                             valid.astype(np.float32))
        if self.fold_dense is not None:
            w += self.fold_dense
        return w

    @functools.cached_property
    def fold_dense(self) -> np.ndarray | None:
        """FS fallback (re-based from inbuf to the stage input) + uncovered
        dense weights as one [L, O, D_src] block, folded into ``eff``."""
        if self.fs_mat is None and self.dw_mat is None:
            return None
        d = np.zeros((self.n_layers, self.out_dim, self.d_src), np.float32)
        if self.fs_mat is not None:
            for l in range(self.n_layers):
                d[l] += self.fs_mat[l] @ self._prep_mats[l]
        if self.dw_mat is not None:
            d += self.dw_mat
        return d

    def operands(self) -> list[np.ndarray]:
        """Kernel operands in canonical order (mirrored by layer_plan)."""
        if self.eff is not None:
            ops_ = [self.eff]
            if self.bias is not None:
                ops_.append(self.bias)
            return ops_
        ops_ = []
        if self.has_prep:
            ops_ += [self.prep_src, self.prep_tgt]
        if self.has_fp:
            ops_ += [self.gidx, self.gcoef, self.outg]
        if self.fs_mat is not None:
            ops_.append(self.fs_mat)
        if self.dw_mat is not None:
            ops_.append(self.dw_mat)
        if self.bias is not None:
            ops_.append(self.bias)
        return ops_


def _fuse_csd_levels(idx: np.ndarray, exp: np.ndarray, sgn: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fuse adjacent CSD levels pairwise: two 2-term shift-add levels become
    one 4-term level (``exp`` summed, signs multiplied — still exact signed
    powers of two), halving the sequential depth at an identical add count.
    A term whose parent row is all-dead composes to sign 0, exactly matching
    the sequential evaluation (the parent row decompresses to zero).  An odd
    trailing level rides along unfused.  Arrays are [P, rows, S]."""
    pm, rows, s = idx.shape
    if pm < 2:
        return (idx.astype(np.int64), exp.astype(np.int32),
                sgn.astype(np.int32))
    out_i, out_e, out_s = [], [], []
    p = 0
    while p < pm:
        if p + 1 == pm:
            out_i.append(idx[p].astype(np.int64))
            out_e.append(exp[p].astype(np.int32))
            out_s.append(sgn[p].astype(np.int32))
            break
        a_i, a_e, a_s = (idx[p].astype(np.int64), exp[p].astype(np.int32),
                         sgn[p].astype(np.int32))
        b_i, b_e, b_s = (idx[p + 1].astype(np.int64),
                         exp[p + 1].astype(np.int32),
                         sgn[p + 1].astype(np.int32))
        j = np.clip(b_i, 0, rows - 1)  # dead terms may carry junk indices
        ci = a_i[j]  # [rows, S, S]
        ce = b_e[:, :, None] + a_e[j]
        cs = b_s[:, :, None] * a_s[j]
        live = cs != 0
        out_i.append(np.where(live, ci, 0).reshape(rows, s * s))
        out_e.append(np.where(live, ce, 0).reshape(rows, s * s))
        out_s.append(cs.reshape(rows, s * s))
        p += 2
    s_new = max(a.shape[1] for a in out_i)
    fi = np.zeros((len(out_i), rows, s_new), np.int64)
    fe = np.zeros((len(out_i), rows, s_new), np.int32)
    fs = np.zeros((len(out_i), rows, s_new), np.int32)
    for q, (ai, ae, as_) in enumerate(zip(out_i, out_e, out_s)):
        fi[q, :, : ai.shape[1]] = ai
        fe[q, :, : ae.shape[1]] = ae
        fs[q, :, : as_.shape[1]] = as_
    return fi, fe, fs


def pack_stage(layer_sites: list[list[dict]], *, d_src: int, out_dim: int
               ) -> PackedStage:
    """Flatten per-layer site lists into one stacked stage.

    ``layer_sites[l]`` is the sites of layer l, each a dict:

      {"kind": "lcc", "name", "out_off", "src_off", "kept" [ints],
       "labels" [ints]|None, "n_clusters" int, "packed" PackedDecomposition,
       "bias" [out]|None}
      {"kind": "dense", "out_off", "src_off", "w" [in, out], "bias"|None}

    Sites write disjoint [out_off, out_off + site_out) row ranges of the
    stage output and read [src_off, ...) of the shared stage input.
    """
    n_layers = len(layer_sites)
    built = []  # per-layer dict of intermediate layout
    any_bias = any_fs = any_dw = False
    names: list[str] = []
    for sites in layer_sites:
        in_off = 0
        prep_pairs: list[tuple[np.ndarray, np.ndarray]] = []
        insts: list[dict] = []  # one per FP slice, in site order
        site_slices: list[tuple[int, int, list[int]]] = []  # (out_off, odim, inst ids)
        fs_entries: list[tuple[int, int, int, np.ndarray]] = []
        dw_entries: list[tuple[int, int, np.ndarray]] = []
        bias_vec = None
        for st in sites:
            b = st.get("bias")
            if b is not None:
                any_bias = True
                if bias_vec is None:
                    bias_vec = np.zeros(out_dim, np.float32)
                b = np.asarray(b, np.float32)
                bias_vec[st["out_off"]: st["out_off"] + b.size] += b
            if st["kind"] == "dense":
                any_dw = True
                w = np.asarray(st["w"], np.float32)
                dw_entries.append((st["out_off"], st["src_off"], w.T))
                continue
            names.append(st["name"])
            kept = np.asarray(st["kept"], np.int64)
            labels = st.get("labels")
            packed = st["packed"]
            tgt = (np.asarray(labels, np.int64) if labels is not None
                   else np.arange(kept.size))
            n_in = int(st["n_clusters"]) if labels is not None else kept.size
            if packed.in_dim != n_in:
                raise ValueError(f"{st['name']}: packed.in_dim={packed.in_dim}"
                                 f" != aggregated input {n_in}")
            prep_pairs.append((st["src_off"] + kept, in_off + tgt))
            idx = np.asarray(packed.idx)
            exp = np.asarray(packed.exp)
            sgn = np.asarray(packed.sign)
            ids = []
            for e, (c0, c1) in enumerate(packed.col_slices):
                # one pairwise pass only: deeper fusion squares the terms per
                # row, and the wider gathers cost more than the saved levels
                fi, fe, fsg = _fuse_csd_levels(idx[e], exp[e], sgn[e])
                ids.append(len(insts))
                insts.append({"in0": in_off + c0, "width": c1 - c0,
                              "idx": fi, "exp": fe, "sgn": fsg,
                              "n_pad": idx.shape[2]})
            site_slices.append((st["out_off"], packed.out_dim, ids))
            for (c0, c1), w in packed.dense:
                any_fs = True
                fs_entries.append((st["out_off"], packed.out_dim,
                                   in_off + c0, np.asarray(w, np.float32)))
            in_off += n_in
        built.append({"k_used": in_off, "prep": prep_pairs, "insts": insts,
                      "site_slices": site_slices, "fs": fs_entries,
                      "dw": dw_entries, "bias": bias_vec})

    has_prep = any(bl["k_used"] for bl in built)
    has_fp = any(bl["insts"] for bl in built)
    k_alloc = (max(bl["k_used"] for bl in built) + 1) if has_prep else 0
    m_max = max([sum(p[0].size for p in bl["prep"]) for bl in built] + [1])
    r_max = max([sum(i["n_pad"] for i in bl["insts"]) for bl in built] + [1])
    p_max = max([i["idx"].shape[0] for bl in built for i in bl["insts"]] + [1])
    s_max = max([i["idx"].shape[2] for bl in built for i in bl["insts"]] + [1])
    j_max = max([len(ids) for bl in built for _, _, ids in bl["site_slices"]]
                + [1])

    prep_src = prep_tgt = gidx = gexp = gsgn = outg = None
    fs_mat = dw_mat = bias = None
    if has_prep:
        prep_src = np.zeros((n_layers, m_max), np.int32)
        prep_tgt = np.full((n_layers, m_max), k_alloc - 1, np.int32)
    if has_fp:
        gidx = np.zeros((n_layers, p_max, r_max, s_max), np.int32)
        gexp = np.zeros((n_layers, p_max, r_max, s_max), np.int8)
        gsgn = np.zeros((n_layers, p_max, r_max, s_max), np.int8)
        outg = np.full((n_layers, j_max, out_dim), r_max, np.int32)
    if any_fs:
        fs_mat = np.zeros((n_layers, out_dim, k_alloc), np.float32)
    if any_dw:
        dw_mat = np.zeros((n_layers, out_dim, d_src), np.float32)
    if any_bias:
        bias = np.zeros((n_layers, out_dim), np.float32)

    segs = np.zeros((n_layers, max(p_max, 1), 3), np.int32)
    runs_before: list[int] = []  # active-run lengths, original site order
    runs_after: list[int] = []  # active-run lengths after depth sorting
    for l, bl in enumerate(built):
        if bl["prep"]:
            src = np.concatenate([p[0] for p in bl["prep"]])
            tgt = np.concatenate([p[1] for p in bl["prep"]])
            prep_src[l, : src.size] = src
            prep_tgt[l, : tgt.size] = tgt
        # segment packing: lay instructions out by descending (fused) chain
        # depth so at every level the rows with a real CSD level form ONE
        # contiguous prefix and the ended chains one contiguous identity run
        order = sorted(range(len(bl["insts"])),
                       key=lambda i: (-bl["insts"][i]["idx"].shape[0], i))
        work_offs: dict[int, int] = {}
        wo = 0
        for inst_id in order:
            inst = bl["insts"][inst_id]
            work_offs[inst_id] = wo
            np_, sm = inst["n_pad"], inst["idx"].shape[2]
            pm = inst["idx"].shape[0]
            for p in range(p_max):
                if p < pm:
                    ii = inst["idx"][p].astype(np.int64)
                    ss = inst["sgn"][p]
                    ee = inst["exp"][p]
                    if p == 0:
                        # level 0 reads inbuf at the slice's column window;
                        # identity-padded level-0 rows of 0-factor chains can
                        # span n_pad > width — mask them so they never read a
                        # neighbouring site's region (the zero-padded-slab
                        # semantics of the per-region kernels)
                        live = (ss != 0) & (ii < inst["width"])
                        comp, safe = inst["in0"] + ii, inst["in0"]
                    else:
                        live = ss != 0
                        comp, safe = wo + ii, wo
                    gidx[l, p, wo: wo + np_, :sm] = np.where(live, comp, safe)
                    gsgn[l, p, wo: wo + np_, :sm] = np.where(live, ss, 0)
                    gexp[l, p, wo: wo + np_, :sm] = np.where(live, ee, 0)
                else:  # identity continuation over the stage's extra levels
                    gidx[l, p, wo: wo + np_, 0] = wo + np.arange(np_)
                    gsgn[l, p, wo: wo + np_, 0] = 1
            wo += np_
        r_used = wo
        depths = [inst["idx"].shape[0] for inst in bl["insts"]]
        pads = [inst["n_pad"] for inst in bl["insts"]]
        for p in range(max(p_max, 1)):
            a_end = sum(pads[i] for i in order if depths[i] > p)
            s_live = 1
            if has_fp and a_end:
                nz = np.nonzero(gsgn[l, p, :a_end, :])[1]
                s_live = int(nz.max()) + 1 if nz.size else 1
            segs[l, p] = (a_end, r_used, s_live)
            runs_after.extend(_active_runs(
                [depths[i] > p for i in order], [pads[i] for i in order]))
            runs_before.extend(_active_runs(
                [d > p for d in depths], pads))
        for out_off, odim, ids in bl["site_slices"]:
            for j, inst_id in enumerate(ids):
                outg[l, j, out_off: out_off + odim] = \
                    work_offs[inst_id] + np.arange(odim)
        for out_off, odim, i0, w in bl["fs"]:
            fs_mat[l, out_off: out_off + odim, i0: i0 + w.shape[1]] = w
        for out_off, src_off, wt in bl["dw"]:
            dw_mat[l, out_off: out_off + wt.shape[0],
                   src_off: src_off + wt.shape[1]] = wt
        if bl["bias"] is not None:
            bias[l] = bl["bias"]

    seg_stats = _segment_stats(runs_before, runs_after, gsgn, segs) \
        if has_fp else None
    waste = _stage_waste(gsgn, segs, prep_tgt, k_alloc) if has_fp else None
    return PackedStage(prep_src=prep_src, prep_tgt=prep_tgt, gidx=gidx,
                       gexp=gexp, gsgn=gsgn, outg=outg, fs_mat=fs_mat,
                       dw_mat=dw_mat, bias=bias, k_alloc=k_alloc, d_src=d_src,
                       out_dim=out_dim, n_layers=n_layers,
                       site_names=tuple(names), segs=segs,
                       seg_stats=seg_stats, waste=waste)


def _active_runs(active: list[bool], pads: list[int]) -> list[int]:
    """Maximal contiguous runs (in rows) of instructions with a live level."""
    runs, cur = [], 0
    for a, n in zip(active, pads):
        if a:
            cur += n
        elif cur:
            runs.append(cur)
            cur = 0
    if cur:
        runs.append(cur)
    return runs


def _pct(xs: list[int], q: float) -> int:
    return int(np.percentile(np.asarray(xs), q)) if xs else 0


def _segment_stats(runs_before, runs_after, gsgn, segs) -> dict:
    """Gather run-length telemetry: how contiguous the per-level active row
    space is before vs after depth sorting, and what the packed layout skips."""
    n_layers, p_max = gsgn.shape[0], gsgn.shape[1]
    r_max = gsgn.shape[2]
    total = n_layers * p_max * r_max
    active = int(sum(int(segs[l, p, 0]) for l in range(n_layers)
                     for p in range(p_max)))
    return {
        "p50_run_before": _pct(runs_before, 50),
        "p99_run_before": _pct(runs_before, 99),
        "p50_run_after": _pct(runs_after, 50),
        "p99_run_after": _pct(runs_after, 99),
        "n_runs_before": len(runs_before),
        "n_runs_after": len(runs_after),
        "gathered_rows": active,
        "total_rows": total,
        "gather_frac": round(active / total, 4) if total else 0.0,
    }


def _stage_waste(gsgn, segs, prep_tgt, k_alloc) -> dict:
    """Per-stage padding-waste report (mirrors ``pack_group``'s keys): the
    fraction of gather rows that are pure identity/zero padding and the dead
    terms inside the active region — what re-padding to the stacked stage
    layout costs relative to its live CSD work."""
    n_layers, p_max, r_max, _ = gsgn.shape
    total_rows = n_layers * p_max * r_max
    active_rows = int(sum(int(segs[l, p, 0]) for l in range(n_layers)
                          for p in range(p_max)))
    live = dead = 0
    for l in range(n_layers):
        for p in range(p_max):
            a_end, _, s_live = segs[l, p]
            blk = gsgn[l, p, :a_end, :s_live]
            live += int(np.count_nonzero(blk))
            dead += int(blk.size - np.count_nonzero(blk))
    slots = live + dead
    prep_pad = 0.0
    if prep_tgt is not None and prep_tgt.size:
        prep_pad = float(np.mean(prep_tgt == k_alloc - 1))
    return {
        "row_waste": round(1.0 - active_rows / total_rows, 4) if total_rows
        else 0.0,
        "slice_waste": round(dead / slots, 4) if slots else 0.0,
        "mean_row_waste": round(prep_pad, 4),
        "shape": tuple(int(s) for s in gsgn.shape),
    }


def pack_layer(stage_specs: dict[str, tuple[list[list[dict]], int, int]]
               ) -> dict[str, PackedStage]:
    """Pack every stage of a layer plan: name -> (layer_sites, d_src, out_dim)."""
    return {name: pack_stage(sites, d_src=d_src, out_dim=out_dim)
            for name, (sites, d_src, out_dim) in stage_specs.items()}


# ---------------------------------------------------------------------------
# deployment byte-stream format (what actually sits in HBM)
# ---------------------------------------------------------------------------


def factor_to_stream(f) -> bytes:
    """Serialize one LCC factor to the compact deployment stream.

    Per nonzero term: int16 column index + int8 code (sign bit << 7 | (exp+32)).
    Row boundaries via a uint8 per-row term count (rows have <= S terms).
    This is the byte count the roofline's weight-streaming term uses.
    """
    import struct

    idx = np.asarray(f.idx)
    exp = np.asarray(f.exp)
    sgn = np.asarray(f.sign)
    out = bytearray()
    out += struct.pack("<III", f.out_dim, f.in_dim, idx.shape[1])
    for r in range(f.out_dim):
        nz = np.nonzero(sgn[r])[0]
        out.append(len(nz))
        for s in nz:
            out += struct.pack("<h", int(idx[r, s]))
            code = (128 if sgn[r, s] < 0 else 0) | (int(exp[r, s]) + 32)
            out += struct.pack("<B", code)
    return bytes(out)


def stream_to_factor(data: bytes):
    """Inverse of factor_to_stream -> core.lcc.LCCFactor."""
    import struct

    from repro.core.lcc import LCCFactor

    out_dim, in_dim, s_terms = struct.unpack_from("<III", data, 0)
    off = 12
    idx = np.zeros((out_dim, s_terms), np.int32)
    exp = np.zeros((out_dim, s_terms), np.int8)
    sgn = np.zeros((out_dim, s_terms), np.int8)
    for r in range(out_dim):
        n = data[off]
        off += 1
        for s in range(n):
            (col,) = struct.unpack_from("<h", data, off)
            code = data[off + 2]
            off += 3
            idx[r, s] = col
            sgn[r, s] = -1 if code & 128 else 1
            exp[r, s] = (code & 127) - 32
    return LCCFactor(idx=idx, exp=exp, sign=sgn, in_dim=in_dim)
