"""jit'd public wrappers around the Pallas kernels.

Bridges ``repro.core.lcc`` decomposition objects (numpy, offline) to the TPU
runtime format: pads factors to block multiples, packs (idx, exp, sign)
arrays, applies whole chains / decompositions, and evaluates weight-shared
layers (paper eq. (10)) as segment-sum + centroid matmul.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.lcc import LCCChain, LCCDecomposition

from .group_prox import group_prox
from .lcc_matmul import lcc_factor_matmul
from .shared_matmul import cluster_segment_sum

__all__ = [
    "PackedFactor",
    "PackedChain",
    "pack_chain",
    "pack_decomposition",
    "apply_packed_chain",
    "apply_packed_decomposition",
    "shared_matmul_tpu",
    "group_prox",
]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class PackedFactor:
    idx: jnp.ndarray  # [N_pad, S] int32
    exp: jnp.ndarray  # [N_pad, S] int8
    sign: jnp.ndarray  # [N_pad, S] int8
    in_dim: int  # unpadded
    out_dim: int  # unpadded

    @property
    def compact_bytes(self) -> int:
        """HBM bytes in the deployment stream format (int16 idx + int8 code)."""
        return int(3 * int(np.asarray(self.sign != 0).sum()))


@dataclass(frozen=True)
class PackedChain:
    factors: tuple[PackedFactor, ...]
    in_dim: int
    out_dim: int


def pack_chain(chain: LCCChain, block: int = 128) -> PackedChain:
    """Pad every factor of an FP chain to block multiples for the kernel."""
    packed = []
    prev_dim = chain.in_dim
    for f in chain.factors:
        n_pad = _round_up(f.out_dim, min(block, max(f.out_dim, 1)))
        idx = np.zeros((n_pad, f.s_terms), np.int32)
        exp = np.zeros((n_pad, f.s_terms), np.int8)
        sgn = np.zeros((n_pad, f.s_terms), np.int8)
        idx[: f.out_dim] = f.idx
        exp[: f.out_dim] = f.exp
        sgn[: f.out_dim] = f.sign
        packed.append(
            PackedFactor(jnp.asarray(idx), jnp.asarray(exp), jnp.asarray(sgn),
                         in_dim=prev_dim, out_dim=f.out_dim)
        )
        prev_dim = f.out_dim
    return PackedChain(tuple(packed), in_dim=chain.in_dim, out_dim=prev_dim)


def apply_packed_chain(pc: PackedChain, x: jnp.ndarray, *, block: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """y[N, B] = (F_P ... F_1) @ x[K, B] running every factor on the kernel.

    Padded rows carry sign==0 slots (value 0) so they stay exactly zero through
    the chain; the final slice recovers the true output dim.
    """
    k, b = x.shape
    assert k == pc.in_dim, (k, pc.in_dim)
    bb = min(block, b)
    b_pad = _round_up(b, bb)
    if b_pad != b:
        x = jnp.pad(x, ((0, 0), (0, b_pad - b)))
    for pf in pc.factors:
        bk = min(block, pf.idx.shape[0] if x.shape[0] == 0 else x.shape[0])
        k_pad = _round_up(x.shape[0], bk)
        if k_pad != x.shape[0]:
            x = jnp.pad(x, ((0, k_pad - x.shape[0]), (0, 0)))
        bn = min(block, pf.idx.shape[0])
        x = lcc_factor_matmul(pf.idx, pf.exp, pf.sign, x,
                              block_n=bn, block_k=min(bk, x.shape[0]),
                              block_b=bb, interpret=interpret)
    return x[: pc.out_dim, :b]


def pack_decomposition(dec: LCCDecomposition, block: int = 128):
    """Pack every FP slice chain. (FS programs run via their dense equivalent —
    the FS DAG is an offline/storage format; see DESIGN.md Sec. 2.)"""
    out = []
    for (c0, c1), s in zip(dec.col_slices, dec.slices):
        if isinstance(s, LCCChain):
            out.append(((c0, c1), pack_chain(s, block)))
        else:
            out.append(((c0, c1), jnp.asarray(s.to_dense(), jnp.float32)))
    return out


def apply_packed_decomposition(packed, x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """y = W_hat @ x for a packed decomposition; x [K, B]."""
    y = None
    for (c0, c1), item in packed:
        xs = x[c0:c1]
        if isinstance(item, PackedChain):
            part = apply_packed_chain(item, xs, interpret=interpret)
        else:
            part = item @ xs.astype(jnp.float32)
        y = part if y is None else y + part
    return y


def shared_matmul_tpu(centroids: jnp.ndarray, labels: jnp.ndarray, x: jnp.ndarray,
                      *, interpret: bool = True) -> jnp.ndarray:
    """Eq. (10) on TPU: kernel segment-sum then centroid matmul. x [K, B] -> [N, B]."""
    n, c = centroids.shape
    k, b = x.shape
    bc = min(128, c)
    c_pad = _round_up(c, bc)
    bk = min(128, k)
    k_pad = _round_up(k, bk)
    bb = min(128, b)
    b_pad = _round_up(b, bb)
    lab = jnp.pad(labels.astype(jnp.int32), (0, k_pad - k), constant_values=c_pad - 1) \
        if k_pad != k else labels.astype(jnp.int32)
    xp = jnp.pad(x, ((0, k_pad - k), (0, b_pad - b))) if (k_pad != k or b_pad != b) else x
    agg = cluster_segment_sum(lab, xp, num_clusters=c_pad,
                              block_c=bc, block_k=bk, block_b=bb, interpret=interpret)
    agg = agg[:c, :b]
    return centroids.astype(jnp.float32) @ agg


# ---------------------------------------------------------------------------
# deployment byte-stream format (what actually sits in HBM)
# ---------------------------------------------------------------------------


def factor_to_stream(f) -> bytes:
    """Serialize one LCC factor to the compact deployment stream.

    Per nonzero term: int16 column index + int8 code (sign bit << 7 | (exp+32)).
    Row boundaries via a uint8 per-row term count (rows have <= S terms).
    This is the byte count the roofline's weight-streaming term uses.
    """
    import struct

    idx = np.asarray(f.idx)
    exp = np.asarray(f.exp)
    sgn = np.asarray(f.sign)
    out = bytearray()
    out += struct.pack("<III", f.out_dim, f.in_dim, idx.shape[1])
    for r in range(f.out_dim):
        nz = np.nonzero(sgn[r])[0]
        out.append(len(nz))
        for s in nz:
            out += struct.pack("<h", int(idx[r, s]))
            code = (128 if sgn[r, s] < 0 else 0) | (int(exp[r, s]) + 32)
            out += struct.pack("<B", code)
    return bytes(out)


def stream_to_factor(data: bytes):
    """Inverse of factor_to_stream -> core.lcc.LCCFactor."""
    import struct

    from repro.core.lcc import LCCFactor

    out_dim, in_dim, s_terms = struct.unpack_from("<III", data, 0)
    off = 12
    idx = np.zeros((out_dim, s_terms), np.int32)
    exp = np.zeros((out_dim, s_terms), np.int8)
    sgn = np.zeros((out_dim, s_terms), np.int8)
    for r in range(out_dim):
        n = data[off]
        off += 1
        for s in range(n):
            (col,) = struct.unpack_from("<h", data, off)
            code = data[off + 2]
            off += 3
            idx[r, s] = col
            sgn[r, s] = -1 if code & 128 else 1
            exp[r, s] = (code & 127) - 32
    return LCCFactor(idx=idx, exp=exp, sign=sgn, in_dim=in_dim)
