"""jit'd public wrappers around the Pallas kernels.

Bridges ``repro.core.lcc`` decomposition objects (numpy, offline) to the TPU
runtime format: pads factors to block multiples, packs (idx, exp, sign) into
the stacked whole-chain layout of ``lcc_chain_matmul``, applies chains /
decompositions fused (one launch per decomposition), and evaluates
weight-shared layers (paper eq. (10)) as segment-sum + centroid matmul.

Packed layout (see ``lcc_chain_matmul``'s module docstring for the kernel-side
contract): all FP slices of a decomposition stack into [E, P, N_pad, S]
streams; chains shorter than P are right-padded with identity factors, unused
term slots and padded rows carry sign == 0.  FS programs have no factor-chain
form — they fall back to their dense equivalent (an offline/storage format;
DESIGN.md Sec. 2) and are combined outside the fused launch.

Every ``interpret`` parameter defaults to ``None`` = auto-detect: compiled
Pallas on TPU, interpreter on CPU/GPU (``repro.kernels.dispatch``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.lcc import LCCChain, LCCDecomposition

from .group_prox import group_prox
from .lcc_chain_matmul import lcc_chain_matmul
from .lcc_group_matmul import lcc_group_matmul
from .lcc_matmul import lcc_factor_matmul
from .shared_matmul import cluster_segment_sum

__all__ = [
    "PackedChain",
    "PackedDecomposition",
    "PackedGroup",
    "pack_chain",
    "pack_decomposition",
    "pack_group",
    "apply_packed_chain",
    "apply_packed_decomposition",
    "apply_packed_group",
    "segment_sum_tpu",
    "shared_matmul_tpu",
    "group_prox",
]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_dim(n: int, block: int) -> int:
    """Seed padding convention: multiples of min(block, n) — small dims stay
    small (interpret mode), dims >= block become block multiples (TPU tiling)."""
    return _round_up(n, min(block, max(n, 1)))


@dataclass(frozen=True)
class PackedChain:
    """One FP chain in the stacked kernel layout: factor axis leading."""

    idx: jnp.ndarray  # [P, N_pad, S] int32
    exp: jnp.ndarray  # [P, N_pad, S] int8
    sign: jnp.ndarray  # [P, N_pad, S] int8
    in_dim: int  # unpadded
    out_dim: int  # unpadded
    d_pad: int  # width of the running vector the kernel carries
    first_width: int  # padded input width addressable by the first factor
    n_factors: int  # real (un-padded) chain length

    @property
    def compact_bytes(self) -> int:
        """HBM bytes in the deployment stream format (int16 idx + int8 code)."""
        return int(3 * int(np.asarray(self.sign != 0).sum()))


@dataclass(frozen=True)
class PackedDecomposition:
    """Whole decomposition: FP slices stacked for one fused launch + dense rest."""

    idx: jnp.ndarray  # [E, P, N_pad, S] int32
    exp: jnp.ndarray  # [E, P, N_pad, S] int8
    sign: jnp.ndarray  # [E, P, N_pad, S] int8
    col_slices: tuple[tuple[int, int], ...]  # E entries (FP slices only)
    dense: tuple[tuple[tuple[int, int], jnp.ndarray], ...]  # non-FP fallback
    in_dim: int
    out_dim: int
    d_pad: int
    first_width: int  # padded max slice width (first-factor column span)
    chain_lengths: tuple[int, ...]  # real factor count per FP slice


def _stack_chain(chain: LCCChain, n_pad: int, s_max: int, p_max: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack one chain's factors into [P, N_pad, S]; identity-pad to p_max."""
    idx = np.zeros((p_max, n_pad, s_max), np.int32)
    exp = np.zeros((p_max, n_pad, s_max), np.int8)
    sgn = np.zeros((p_max, n_pad, s_max), np.int8)
    for p, f in enumerate(chain.factors):
        idx[p, : f.out_dim, : f.s_terms] = f.idx
        exp[p, : f.out_dim, : f.s_terms] = f.exp
        sgn[p, : f.out_dim, : f.s_terms] = f.sign
    for p in range(len(chain.factors), p_max):  # identity wiring: y = prev
        idx[p, :, 0] = np.arange(n_pad)
        sgn[p, :, 0] = 1
    return idx, exp, sgn


def pack_chain(chain: LCCChain, block: int = 128) -> PackedChain:
    """Pack one FP chain into the stacked fused-kernel layout."""
    out_dim = chain.factors[-1].out_dim if chain.factors else chain.in_dim
    n_pad = _pad_dim(max([f.out_dim for f in chain.factors] or [chain.in_dim]),
                     block)
    s_max = max([f.s_terms for f in chain.factors] or [1])
    p_max = max(len(chain.factors), 1)
    k_pad = _pad_dim(chain.in_dim, block)
    d_pad = max(n_pad, k_pad)
    # an empty chain packs as one identity factor whose rows span n_pad
    first_width = k_pad if chain.factors else n_pad
    idx, exp, sgn = _stack_chain(chain, n_pad, s_max, p_max)
    return PackedChain(jnp.asarray(idx), jnp.asarray(exp), jnp.asarray(sgn),
                       in_dim=chain.in_dim, out_dim=out_dim, d_pad=d_pad,
                       first_width=first_width,
                       n_factors=max(len(chain.factors), 1))


def pack_decomposition(dec: LCCDecomposition, block: int = 128
                       ) -> PackedDecomposition:
    """Pack every FP slice chain into ONE stacked multi-slice layout."""
    fp = [((c0, c1), s) for (c0, c1), s in zip(dec.col_slices, dec.slices)
          if isinstance(s, LCCChain)]
    dense = tuple(((c0, c1), jnp.asarray(s.to_dense(), jnp.float32))
                  for (c0, c1), s in zip(dec.col_slices, dec.slices)
                  if not isinstance(s, LCCChain))
    n, k = dec.shape
    if not fp:
        return PackedDecomposition(
            jnp.zeros((0, 1, 1, 1), jnp.int32), jnp.zeros((0, 1, 1, 1), jnp.int8),
            jnp.zeros((0, 1, 1, 1), jnp.int8), (), dense,
            in_dim=k, out_dim=n, d_pad=1, first_width=1, chain_lengths=())
    all_factors = [f for _, ch in fp for f in ch.factors]
    n_pad = _pad_dim(max([f.out_dim for f in all_factors] or [n]), block)
    s_max = max([f.s_terms for f in all_factors] or [1])
    p_max = max(max(len(ch.factors) for _, ch in fp), 1)
    w_pad = _pad_dim(max(c1 - c0 for (c0, c1), _ in fp), block)
    d_pad = max(n_pad, w_pad)
    stacked = [_stack_chain(ch, n_pad, s_max, p_max) for _, ch in fp]
    return PackedDecomposition(
        idx=jnp.asarray(np.stack([s[0] for s in stacked])),
        exp=jnp.asarray(np.stack([s[1] for s in stacked])),
        sign=jnp.asarray(np.stack([s[2] for s in stacked])),
        col_slices=tuple(cs for cs, _ in fp),
        dense=dense, in_dim=k, out_dim=n, d_pad=d_pad, first_width=w_pad,
        chain_lengths=tuple(max(len(ch.factors), 1) for _, ch in fp))


def _pad_batch(b: int, block: int) -> tuple[int, int]:
    bb = min(block, b)
    return bb, _round_up(b, bb)


@dataclass(frozen=True)
class PackedGroup:
    """G packed decompositions re-padded to common dims for ONE grouped launch.

    ``members`` keeps each decomposition's original packing metadata
    (col_slices over its own input, FS dense fallbacks, true in/out dims);
    the stacked (idx, exp, sign) carry the shared-padded factor streams that
    :func:`~repro.kernels.lcc_group_matmul.lcc_group_matmul` consumes.  The
    streams are kept as *numpy* arrays: groups are assembled lazily — often
    inside an active jit trace — and cached numpy constants embed per-trace
    instead of leaking tracers.
    """

    idx: np.ndarray  # [G, E, P, N_pad, S] int32
    exp: np.ndarray  # [G, E, P, N_pad, S] int8
    sign: np.ndarray  # [G, E, P, N_pad, S] int8
    members: tuple[PackedDecomposition, ...]
    d_pad: int
    first_width: int

    @property
    def n_groups(self) -> int:
        return len(self.members)


def pack_group(members: list[PackedDecomposition]) -> PackedGroup:
    """Re-pad G packed decompositions to common (E, P, N, S, D) dims.

    Padding preserves the kernel invariants: extra term slots and extra rows
    carry sign == 0 (decompress to zero), chains are right-extended with
    identity factors over the shared N_pad, and whole missing slices are
    all-zero-sign (a zero factor chain on zero input — contributes nothing).
    """
    if not members:
        raise ValueError("pack_group needs at least one member")
    e_max = max([m.idx.shape[0] for m in members] + [1])
    p_max = max([m.idx.shape[1] for m in members if m.idx.shape[0]] + [1])
    n_max = max([m.idx.shape[2] for m in members if m.idx.shape[0]] + [1])
    s_max = max([m.idx.shape[3] for m in members if m.idx.shape[0]] + [1])
    d_pad = max([m.d_pad for m in members if m.idx.shape[0]] + [n_max])
    first_width = max([m.first_width for m in members if m.idx.shape[0]] + [1])
    gi = np.zeros((len(members), e_max, p_max, n_max, s_max), np.int32)
    ge = np.zeros(gi.shape, np.int8)
    gs = np.zeros(gi.shape, np.int8)
    ident = np.arange(n_max, dtype=np.int32)
    for g, m in enumerate(members):
        e, p, n, s = m.idx.shape
        if e == 0:
            continue  # FS-only member: dense fallback handles everything
        gi[g, :e, :p, :n, :s] = np.asarray(m.idx)
        ge[g, :e, :p, :n, :s] = np.asarray(m.exp)
        gs[g, :e, :p, :n, :s] = np.asarray(m.sign)
        # chains shorter than the group max continue as identity factors
        gi[g, :e, p:, :, 0] = ident
        gs[g, :e, p:, :, 0] = 1
    return PackedGroup(idx=gi, exp=ge, sign=gs, members=tuple(members),
                       d_pad=d_pad, first_width=first_width)


def apply_packed_group(pg: PackedGroup, xs, *, block: int = 128,
                       interpret: bool | None = None) -> list[jnp.ndarray]:
    """y_g = W_hat_g @ xs[g] for every group member — ONE fused launch.

    ``xs`` is a per-member list of [K_g, B] inputs (all the same B; K_g is the
    member's own in_dim — members need not agree on input width because each
    slices/pads its own columns).  FS dense-fallback slices are added per
    member outside the launch, exactly like :func:`apply_packed_decomposition`.
    """
    if len(xs) != len(pg.members):
        raise ValueError(f"{len(pg.members)} group members, {len(xs)} inputs")
    b = xs[0].shape[1]
    bb, b_pad = _pad_batch(b, block)
    e_max = pg.idx.shape[1]
    any_fp = any(m.col_slices for m in pg.members)
    y = None
    if any_fp:
        stacks = []
        for m, x in zip(pg.members, xs):
            if x.shape[0] != m.in_dim:
                raise ValueError(f"x has {x.shape[0]} rows, member expects "
                                 f"in_dim={m.in_dim}")
            slabs = [jnp.pad(x[c0:c1].astype(jnp.float32),
                             ((0, pg.d_pad - (c1 - c0)), (0, b_pad - b)))
                     for c0, c1 in m.col_slices]
            slabs += [jnp.zeros((pg.d_pad, b_pad), jnp.float32)
                      ] * (e_max - len(slabs))
            stacks.append(jnp.stack(slabs))
        y = lcc_group_matmul(pg.idx, pg.exp, pg.sign, jnp.stack(stacks),
                             block_b=bb, first_width=pg.first_width,
                             interpret=interpret)  # [G, N_pad, B_pad]
    outs = []
    for g, (m, x) in enumerate(zip(pg.members, xs)):
        yg = y[g, : m.out_dim, :b] if (y is not None and m.col_slices) else None
        for (c0, c1), w in m.dense:
            part = w @ x[c0:c1].astype(jnp.float32)
            yg = part if yg is None else yg + part
        if yg is None:
            raise ValueError("empty decomposition in group: no FP or dense slices")
        outs.append(yg)
    return outs


def _apply_stacked_per_factor(idx, exp, sign, x_pad, chain_lengths, *,
                              block: int, interpret: bool | None):
    """Per-factor launch loop over the stacked layout — the pre-fusion runtime,
    kept as the fused kernel's wall-clock baseline (benchmarks) and as an
    independent second implementation for equivalence tests.  Launches only
    each chain's REAL factors (identity padding exists for the fused stack's
    benefit; a pre-fusion runtime never ran it)."""
    e_slices, _, n_pad, _ = idx.shape
    _, d_pad, b_pad = x_pad.shape
    y = jnp.zeros((n_pad, b_pad), jnp.float32)
    bb = min(block, b_pad)
    for e in range(e_slices):
        cur = x_pad[e]
        for p in range(chain_lengths[e]):
            out = lcc_factor_matmul(idx[e, p], exp[e, p], sign[e, p], cur,
                                    block_n=min(block, n_pad),
                                    block_k=min(block, d_pad),
                                    block_b=bb, interpret=interpret)
            cur = jnp.pad(out, ((0, d_pad - n_pad), (0, 0)))
        y = y + cur[:n_pad]
    return y


def apply_packed_chain(pc: PackedChain, x: jnp.ndarray, *, block: int = 128,
                       interpret: bool | None = None,
                       fused: bool = True) -> jnp.ndarray:
    """y[N, B] = (F_P ... F_1) @ x[K, B] — the whole chain in one fused launch.

    Padded rows carry sign==0 slots (value 0) so they stay exactly zero through
    the chain; the final slice recovers the true output dim.
    """
    k, b = x.shape
    if k != pc.in_dim:
        raise ValueError(f"x has {k} rows, chain expects in_dim={pc.in_dim}")
    bb, b_pad = _pad_batch(b, block)
    x_pad = jnp.pad(x.astype(jnp.float32),
                    ((0, pc.d_pad - k), (0, b_pad - b)))[None]
    if fused:
        y = lcc_chain_matmul(pc.idx[None], pc.exp[None], pc.sign[None], x_pad,
                             block_b=bb, first_width=pc.first_width,
                             interpret=interpret)
    else:
        y = _apply_stacked_per_factor(pc.idx[None], pc.exp[None], pc.sign[None],
                                      x_pad, (pc.n_factors,), block=block,
                                      interpret=interpret)
    return y[: pc.out_dim, :b]


def apply_packed_decomposition(packed: PackedDecomposition, x: jnp.ndarray, *,
                               block: int = 128, interpret: bool | None = None,
                               fused: bool = True) -> jnp.ndarray:
    """y = W_hat @ x for a packed decomposition; x [K, B] (or [K] vector).

    All FP slices run in a single ``lcc_chain_matmul`` launch (``fused=True``,
    the default); ``fused=False`` runs the legacy one-``pallas_call``-per-factor
    loop for comparison.  Dense-fallback slices (FS programs) are added on top.
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    k, b = x.shape
    if k != packed.in_dim:
        raise ValueError(f"x has {k} rows, decomposition expects "
                         f"in_dim={packed.in_dim}")
    y = None
    e_slices = len(packed.col_slices)
    if e_slices:
        bb, b_pad = _pad_batch(b, block)
        x_pad = jnp.stack([
            jnp.pad(x[c0:c1].astype(jnp.float32),
                    ((0, packed.d_pad - (c1 - c0)), (0, b_pad - b)))
            for c0, c1 in packed.col_slices])
        if fused:
            y = lcc_chain_matmul(packed.idx, packed.exp, packed.sign, x_pad,
                                 block_b=bb, first_width=packed.first_width,
                                 interpret=interpret)
        else:
            y = _apply_stacked_per_factor(packed.idx, packed.exp, packed.sign,
                                          x_pad, packed.chain_lengths,
                                          block=block, interpret=interpret)
        y = y[: packed.out_dim, :b]
    for (c0, c1), w in packed.dense:
        part = w @ x[c0:c1].astype(jnp.float32)
        y = part if y is None else y + part
    if y is None:
        raise ValueError("empty decomposition: no FP or dense slices to apply")
    return y[:, 0] if squeeze else y


def segment_sum_tpu(labels: jnp.ndarray, x: jnp.ndarray, num_clusters: int,
                    *, interpret: bool | None = None) -> jnp.ndarray:
    """Kernel segment-sum with ragged (K, C, B) padded to block multiples.

    Padded K rows are labeled c_pad - 1; when num_clusters is already a block
    multiple that id aliases the last *real* cluster, which stays correct only
    because the padded x rows are zero — keep that invariant when changing the
    padding.
    """
    k, b = x.shape
    bc = min(128, num_clusters)
    c_pad = _round_up(num_clusters, bc)
    bk = min(128, k)
    k_pad = _round_up(k, bk)
    bb = min(128, b)
    b_pad = _round_up(b, bb)
    lab = jnp.pad(labels.astype(jnp.int32), (0, k_pad - k), constant_values=c_pad - 1) \
        if k_pad != k else labels.astype(jnp.int32)
    xp = jnp.pad(x, ((0, k_pad - k), (0, b_pad - b))) if (k_pad != k or b_pad != b) else x
    agg = cluster_segment_sum(lab, xp, num_clusters=c_pad,
                              block_c=bc, block_k=bk, block_b=bb, interpret=interpret)
    return agg[:num_clusters, :b]


def shared_matmul_tpu(centroids: jnp.ndarray, labels: jnp.ndarray, x: jnp.ndarray,
                      *, interpret: bool | None = None) -> jnp.ndarray:
    """Eq. (10) on TPU: kernel segment-sum then centroid matmul. x [K, B] -> [N, B]."""
    agg = segment_sum_tpu(labels, x, centroids.shape[1], interpret=interpret)
    return centroids.astype(jnp.float32) @ agg


# ---------------------------------------------------------------------------
# deployment byte-stream format (what actually sits in HBM)
# ---------------------------------------------------------------------------


def factor_to_stream(f) -> bytes:
    """Serialize one LCC factor to the compact deployment stream.

    Per nonzero term: int16 column index + int8 code (sign bit << 7 | (exp+32)).
    Row boundaries via a uint8 per-row term count (rows have <= S terms).
    This is the byte count the roofline's weight-streaming term uses.
    """
    import struct

    idx = np.asarray(f.idx)
    exp = np.asarray(f.exp)
    sgn = np.asarray(f.sign)
    out = bytearray()
    out += struct.pack("<III", f.out_dim, f.in_dim, idx.shape[1])
    for r in range(f.out_dim):
        nz = np.nonzero(sgn[r])[0]
        out.append(len(nz))
        for s in nz:
            out += struct.pack("<h", int(idx[r, s]))
            code = (128 if sgn[r, s] < 0 else 0) | (int(exp[r, s]) + 32)
            out += struct.pack("<B", code)
    return bytes(out)


def stream_to_factor(data: bytes):
    """Inverse of factor_to_stream -> core.lcc.LCCFactor."""
    import struct

    from repro.core.lcc import LCCFactor

    out_dim, in_dim, s_terms = struct.unpack_from("<III", data, 0)
    off = 12
    idx = np.zeros((out_dim, s_terms), np.int32)
    exp = np.zeros((out_dim, s_terms), np.int8)
    sgn = np.zeros((out_dim, s_terms), np.int8)
    for r in range(out_dim):
        n = data[off]
        off += 1
        for s in range(n):
            (col,) = struct.unpack_from("<h", data, off)
            code = data[off + 2]
            off += 3
            idx[r, s] = col
            sgn[r, s] = -1 if code & 128 else 1
            exp[r, s] = (code & 127) - 32
    return LCCFactor(idx=idx, exp=exp, sign=sgn, in_dim=in_dim)
