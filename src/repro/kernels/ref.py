"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lcc_factor_matmul_ref", "cluster_segment_sum_ref", "group_prox_ref",
           "lcc_chain_apply_ref"]


def lcc_factor_dense_ref(idx: jnp.ndarray, exp: jnp.ndarray, sign: jnp.ndarray, in_dim: int) -> jnp.ndarray:
    """Densify a compact LCC factor: F[n, k] = sum_s sign*2^exp [idx==k]."""
    n, s = idx.shape
    val = sign.astype(jnp.float32) * jnp.exp2(exp.astype(jnp.float32))
    onehot = jax.nn.one_hot(idx, in_dim, dtype=jnp.float32)  # [N, S, K]
    return jnp.einsum("ns,nsk->nk", val, onehot)


def lcc_factor_matmul_ref(idx, exp, sign, x) -> jnp.ndarray:
    """y = F @ x via explicit densification (oracle for lcc_factor_matmul)."""
    f = lcc_factor_dense_ref(idx, exp, sign, x.shape[0])
    return f @ x.astype(jnp.float32)


def lcc_chain_apply_ref(factors, x) -> jnp.ndarray:
    """Apply a whole chain [(idx, exp, sign), ...] first-to-last."""
    for idx, exp, sign in factors:
        x = lcc_factor_matmul_ref(idx, exp, sign, x)
    return x


def cluster_segment_sum_ref(labels, x, num_clusters: int) -> jnp.ndarray:
    """agg[C, B] = segment_sum(x, labels) (oracle for cluster_segment_sum)."""
    return jax.ops.segment_sum(x.astype(jnp.float32), labels, num_segments=num_clusters)


def group_prox_ref(a, thresh) -> jnp.ndarray:
    """Row block soft threshold (oracle for group_prox)."""
    a32 = a.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(a32 * a32, axis=1, keepdims=True))
    scale = jnp.maximum(1.0 - thresh / jnp.maximum(norm, 1e-12), 0.0)
    return (scale * a32).astype(a.dtype)
