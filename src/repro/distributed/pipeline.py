"""GPipe-style pipeline parallelism over a "pipe" mesh axis via shard_map +
collective_permute.

Scale-out beyond DP x TP x FSDP (e.g. > 141B params or > 2 pods): layers are
split into S stages; microbatches stream through; each step every stage
processes one microbatch and permutes activations to its successor.  The
classic GPipe schedule (S + M - 1 ticks, bubble S-1/M) expressed as a single
lax.scan so it lowers to one compact while loop.

This module is deliberately self-contained (stage_fn in, stage_fn out) so any
of the scanned-layer models can be pipelined by giving their per-stage layer
stacks.  Exercised in tests on a small host-device mesh.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["gpipe_forward", "split_stages"]


def split_stages(stacked_params, n_stages: int):
    """[L, ...] layer stacks -> [S, L/S, ...] per-stage stacks."""
    def re(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(re, stacked_params)


def gpipe_forward(stage_params, x_microbatches, stage_fn, *, mesh: Mesh,
                  axis: str = "pipe"):
    """Run microbatches through pipeline stages.

    stage_params: pytree with leading stage axis [S, ...] (sharded over ``axis``)
    x_microbatches: [M, mb, ...] activations (replicated or data-sharded)
    stage_fn(params_slice, x) -> x  — applies one stage's layers.

    Returns [M, mb, ...] outputs (valid on the last stage; identical on all
    after the final gather).
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]

    def per_stage(params, xs):
        # params: this stage's slice [1, L/S, ...] ; xs: [M, mb, ...]
        params = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)
        ticks = n_stages + m - 1
        buf = jnp.zeros_like(xs)  # output collector (last stage writes)

        def tick(carry, t):
            inflight, buf = carry
            # stage 0 injects microbatch t (if any); others take permuted input
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            xin = jnp.where(stage_id == 0, inject, inflight)
            active = (t - stage_id >= 0) & (t - stage_id < m)
            y = stage_fn(params, xin)
            y = jnp.where(active, y, xin)
            # last stage writes its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            write = active & (stage_id == n_stages - 1)
            buf = jax.lax.cond(
                write,
                lambda b: jax.lax.dynamic_update_index_in_dim(b, y, out_idx, 0),
                lambda b: b, buf)
            # permute activations stage i -> i+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, buf), None

        (_, buf), _ = jax.lax.scan(tick, (jnp.zeros_like(xs[0]), buf),
                                   jnp.arange(ticks))
        # broadcast final outputs from the last stage to everyone (masked psum)
        buf = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, buf, jnp.zeros_like(buf)), axis)
        return buf

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = compat.shard_map(per_stage, mesh=mesh, in_specs=(spec_p, P()),
                          out_specs=P(), check_vma=False)
    return fn(stage_params, x_microbatches)
