"""Manually overlapped collective matmul (all-gather x matmul pipelining).

XLA's latency-hiding scheduler overlaps collectives opportunistically; this
module expresses the overlap *structurally*: a bidirectional ring ppermute
streams weight shards while the MXU consumes the previous shard, so the ICI
transfer of shard i+1 hides behind the matmul of shard i (the collective-
matmul technique from Wang et al., ASPLOS'23).  Opt-in replacement for
FSDP-style ``all-gather(W) @ x`` — one of the §Perf hillclimb levers for
collective-bound cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["overlapped_ag_matmul"]


def overlapped_ag_matmul(x, w_sharded, *, mesh: Mesh, axis: str = "model"):
    """y = x @ all_gather(w, axis) without materializing the gathered weight.

    x [.., K] replicated along ``axis``; w_sharded [K/n, N] (row-sharded).
    Each step multiplies the resident shard and ppermutes it along the ring:
    compute(shard_i) overlaps transfer(shard_{i+1}).
    """
    n = mesh.shape[axis]

    def inner(x, w):
        idx = jax.lax.axis_index(axis)
        k_shard = w.shape[0]

        def step(carry, i):
            acc, w_cur = carry
            # which global shard is resident here at step i (ring walk)
            src = (idx + i) % n
            x_slice = jax.lax.dynamic_slice_in_dim(x, src * k_shard, k_shard, axis=-1)
            acc = acc + jnp.einsum("...k,kn->...n", x_slice, w_cur)
            perm = [(j, (j - 1) % n) for j in range(n)]
            w_nxt = jax.lax.ppermute(w_cur, axis, perm)
            return (acc, w_nxt), None

        acc0 = jnp.zeros(x.shape[:-1] + (w.shape[1],),
                         jnp.promote_types(x.dtype, jnp.float32))
        (acc, _), _ = jax.lax.scan(step, (acc0, w), jnp.arange(n))
        return acc.astype(x.dtype)

    fn = compat.shard_map(inner, mesh=mesh, in_specs=(P(), P(axis, None)),
                          out_specs=P(), check_vma=False)
    return fn(x, w_sharded)
