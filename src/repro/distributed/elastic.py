"""Elastic scaling + failure handling at the launcher level.

Synchronous SPMD cannot drop a participant mid-step; the production recovery
path is: detect (heartbeat timeout / XLA error) -> shrink or remap the mesh ->
reshard the latest checkpoint -> continue.  This module implements the mesh
arithmetic and the resharding; ``launch/train.py --elastic`` drives it and
tests exercise a simulated pod loss on host devices.

Straggler policy (documented, launcher-side): persistent stragglers are
indistinguishable from slow failures under SPMD — the monitor treats a pod
whose heartbeat lags > ``straggler_factor`` x median as failed and triggers
the same remesh path (hot-spare pods can then be mapped in by the scheduler).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["MeshPlan", "plan_for_devices", "reshard_tree", "HeartbeatMonitor"]


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    def build(self, devices=None) -> Mesh:
        devs = np.asarray(devices if devices is not None else jax.devices())
        need = int(np.prod(self.shape))
        if devs.size < need:
            raise ValueError(f"need {need} devices, have {devs.size}")
        arr = devs[:need].reshape(self.shape)
        return Mesh(arr, self.axes)


def plan_for_devices(n_devices: int, *, model_parallel: int = 16,
                     multi_pod_threshold: int = 512) -> MeshPlan:
    """Largest mesh plan that fits the surviving device count.

    Keeps the model axis fixed (TP degree is an arch property); absorbs losses
    on the data/pod axes — the axes gradient-descent parallelism tolerates.
    """
    mp = min(model_parallel, n_devices)
    rest = n_devices // mp
    if n_devices >= multi_pod_threshold and rest % 2 == 0:
        return MeshPlan((2, rest // 2, mp), ("pod", "data", "model"))
    return MeshPlan((rest, mp), ("data", "model"))


def reshard_tree(tree, mesh: Mesh, pspecs):
    """Move a host/numpy or differently-sharded pytree onto ``mesh``."""
    from jax.sharding import NamedSharding

    def one(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    # primary tree drives traversal (arrays are leaves); the pspec tree is
    # flattened up to the same structure, so PartitionSpec leaves stay whole
    return jax.tree_util.tree_map(one, tree, pspecs)


class HeartbeatMonitor:
    """Tracks per-pod step-completion timestamps; flags failures/stragglers."""

    def __init__(self, n_pods: int, timeout_s: float = 300.0,
                 straggler_factor: float = 3.0):
        self.n_pods = n_pods
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.last_beat = {p: 0.0 for p in range(n_pods)}
        self.durations: dict[int, list[float]] = {p: [] for p in range(n_pods)}

    def beat(self, pod: int, t: float) -> None:
        prev = self.last_beat[pod]
        if prev:
            self.durations[pod].append(t - prev)
        self.last_beat[pod] = t

    def failed_pods(self, now: float) -> list[int]:
        out = [p for p, t in self.last_beat.items() if t and now - t > self.timeout_s]
        means = [np.mean(d[-5:]) for d in self.durations.values() if d]
        # reference pace = fastest pod (robust even when half the pods straggle)
        ref = min(means) if means else 0.0
        if ref > 0:
            for p, d in self.durations.items():
                if d and np.mean(d[-5:]) > self.straggler_factor * ref and p not in out:
                    out.append(p)  # persistent straggler == slow failure
        return sorted(out)

    def surviving_device_count(self, total: int, failed: list[int]) -> int:
        per_pod = total // self.n_pods
        return total - per_pod * len(failed)
