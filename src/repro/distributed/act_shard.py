"""Activation sharding constraints inside model code.

GSPMD propagates shardings from jit boundaries, but without internal anchors
it frequently replicates layer compute across the model axis (measured on this
repo: olmo-1b train_4k HLO FLOPs were 5x the TP-ideal before these constraints
— EXPERIMENTS.md §Perf iteration 1).  Models call ``constrain(x, ...)`` with
symbolic axes; it becomes a no-op when no mesh is configured (unit tests,
single-device runs), and silently drops any axis that does not divide.

Symbolic axes: "batch" -> ("pod","data") (whichever exist), "data", "model",
None.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

__all__ = ["set_mesh", "get_mesh", "constrain", "mesh_context"]

_MESH: Mesh | None = None


def set_mesh(mesh: Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


class mesh_context:
    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _MESH
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def constrain(x, *axes):
    """with_sharding_constraint with symbolic axes and divisibility fallback.

    constrain(h, "batch", None, "model") pins h's dim0 to the dp axes and
    dim2 to tp; any non-dividing axis silently becomes None.
    """
    mesh = _MESH
    if mesh is None:
        return x
    # axes already "manual" at this trace point (inside shard_map bodies, e.g.
    # the pod axis under compressed-gradient training) must not be referenced
    manual = compat.manual_axis_names()
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        if ax in manual:
            spec.append(None)
            continue
        if ax == "batch":
            cand = tuple(a for a in ("pod", "data")
                         if a in mesh.shape and a not in manual)
            if not cand:
                spec.append(None)
                continue
            if dim % _axis_size(mesh, cand) == 0:
                spec.append(cand if len(cand) > 1 else cand[0])
            elif dim % _axis_size(mesh, ("data",)) == 0 and "data" in mesh.shape:
                spec.append("data")
            else:
                spec.append(None)
        else:
            if ax in mesh.shape and dim % mesh.shape[ax] == 0 and dim >= mesh.shape[ax]:
                spec.append(ax)
            else:
                spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
