"""Distribution: sharding policy, gradient compression, pipeline, overlap, elastic."""
from .sharding import (  # noqa: F401
    batch_pspecs, decode_state_pspecs, named, param_pspec, params_pspecs,
)
from .compress_grads import compressed_psum, init_error_state  # noqa: F401
from .elastic import HeartbeatMonitor, MeshPlan, plan_for_devices, reshard_tree  # noqa: F401
