"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 2-pod scale the inter-pod (DCN/ICI) link is the scarcest bandwidth; the
classic fix is quantized all-reduce with error feedback (1-bit Adam lineage):

  q = quantize_int8(g + e);  g_hat = allreduce(q) / n_pods;  e' = (g + e) - q

The residual ``e`` lives in the train state (same sharding as grads), so the
compression bias vanishes over steps.  Per-block scales (block = last axis)
keep the quantization SNR high.  Used inside shard_map over the "pod" axis;
intra-pod reduction stays full precision (done by pjit as usual).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum", "init_error_state"]


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-row int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compressed_psum(grads, errors, axis_name: str):
    """Error-feedback int8 psum over ``axis_name``. Returns (mean grads, new errors).

    Must be called inside shard_map with ``axis_name`` bound (the "pod" axis).
    int8 payloads cut the inter-pod all-reduce bytes 4x vs f32 (2x vs bf16).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        v = g.astype(jnp.float32) + e
        flat = v.reshape(-1, v.shape[-1]) if v.ndim > 1 else v.reshape(1, -1)
        # shared scale: pmax of per-row amax (tiny payload) => exact int32 psum
        amax = jax.lax.pmax(jnp.max(jnp.abs(flat), axis=-1, keepdims=True), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        g_hat = qsum.astype(jnp.float32) * scale / n
        new_e = (flat - q.astype(jnp.float32) * scale).reshape(v.shape)
        return g_hat.reshape(g.shape).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, errors)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_errors = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_errors
