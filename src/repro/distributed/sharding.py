"""Sharding policy: derive a NamedSharding for every parameter / activation /
cache tensor from its pytree path and shape, with divisibility-checked
fallbacks so every assigned arch compiles on the fixed production mesh.

Strategy (DESIGN.md Sec. 5):
  * parameters: FSDP/ZeRO-3 storage — the largest dim divisible by |model|
    goes to "model"; then the largest remaining dim divisible by |data| goes
    to "data".  XLA re-gathers per-layer slices inside the layer scan, which
    is exactly the FSDP communication schedule.
  * MoE expert stacks: expert dim on "model" when divisible (EP), else the ff
    dim (TP-within-expert).
  * batch axes of inputs/activations/caches: ("pod", "data") when divisible,
    "data" when not, replicated as last resort; for batch-1 long-context the
    sequence axis takes "data" (sequence parallelism).
  * optimizer state mirrors parameter sharding (ZeRO-1/2 for free).

Everything returns PartitionSpec; mesh binding happens at the jit boundary.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["param_pspec", "params_pspecs", "batch_pspecs", "decode_state_pspecs",
           "named", "mesh_axis_size", "plan_batch_spec"]


def mesh_axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def _assign_axes(shape: tuple[int, ...], skip: set[int], mesh: Mesh,
                 want_data: bool = True) -> list:
    """Greedy: biggest dim % model == 0 -> 'model'; biggest remaining % data -> 'data'."""
    spec: list = [None] * len(shape)
    msize = mesh_axis_size(mesh, "model")
    dsize = mesh_axis_size(mesh, "data")
    order = sorted((i for i in range(len(shape)) if i not in skip),
                   key=lambda i: -shape[i])
    mi = next((i for i in order if shape[i] % msize == 0 and shape[i] >= msize), None)
    if mi is not None:
        spec[mi] = "model"
    if want_data:
        di = next((i for i in order if i != mi and shape[i] % dsize == 0
                   and shape[i] >= dsize), None)
        if di is not None:
            spec[di] = "data"
    return spec


def param_pspec(path: str, shape: tuple[int, ...], mesh: Mesh, *,
                fsdp: bool = True) -> P:
    """PartitionSpec for one parameter given its flattened path name."""
    if len(shape) <= 1:
        return P()  # norms / biases / small vectors: replicated
    skip: set[int] = set()
    # stacked-layer leading axis is never sharded (scan slices it)
    if any(k in path for k in ("blocks", "enc_blocks", "dec_blocks")):
        skip.add(0)
    if ("gate" in path or "up" in path or "down" in path) and len(shape) - len(skip) == 3:
        # MoE expert stack [L?, E, d, f]: prefer EP on the expert dim
        e_ax = min(i for i in range(len(shape)) if i not in skip)
        msize = mesh_axis_size(mesh, "model")
        if shape[e_ax] % msize == 0 and shape[e_ax] >= msize:
            spec = [None] * len(shape)
            spec[e_ax] = "model"
            if fsdp:
                rest = sorted((i for i in range(len(shape)) if i != e_ax and i not in skip),
                              key=lambda i: -shape[i])
                dsize = mesh_axis_size(mesh, "data")
                di = next((i for i in rest if shape[i] % dsize == 0), None)
                if di is not None:
                    spec[di] = "data"
            return P(*spec)
        skip.add(e_ax)  # TP-within-expert below
    return P(*_assign_axes(shape, skip, mesh, want_data=fsdp))


def params_pspecs(params_tree: Any, mesh: Mesh, *, fsdp: bool = True):
    """Map a (possibly abstract) params pytree -> pytree of PartitionSpec."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    flat = []
    for path, leaf in paths_leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat.append(param_pspec(name, tuple(leaf.shape), mesh, fsdp=fsdp))
    treedef = jax.tree_util.tree_structure(params_tree)
    return jax.tree_util.tree_unflatten(treedef, flat)


def _batch_axes(mesh: Mesh) -> tuple[str, ...] | str | None:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def plan_batch_spec(mesh: Mesh, b: int):
    """Mesh axis name(s) to split a layer plan's batch/slot axis over, or
    None (replicate).  Mirrors :func:`decode_state_pspecs`'s slot rule —
    ("pod","data") when the slot count divides the full extent, "data" alone
    when only that divides — so the plan's ``shard_map`` sees the same local
    slot partition the surrounding jitted step gives the KV cache."""
    baxes = _batch_axes(mesh)
    if baxes is None:
        return None
    bsize = int(np.prod([mesh_axis_size(mesh, a) for a in ("pod", "data")]))
    dsize = mesh_axis_size(mesh, "data")
    if bsize > 1 and b % bsize == 0 and b >= bsize:
        return baxes
    if dsize > 1 and b % dsize == 0 and b >= dsize:
        return "data"
    return None


def batch_pspecs(batch_tree: Any, mesh: Mesh):
    """Inputs: batch-major sharding over ("pod","data"); batch-1 long-context
    shards the sequence axis instead (SP)."""
    baxes = _batch_axes(mesh)
    bsize = int(np.prod([mesh_axis_size(mesh, a) for a in ("pod", "data")]))
    dsize = mesh_axis_size(mesh, "data")

    def one(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        spec: list = [None] * len(shape)
        # positions3 [3, B, S] style: batch is axis 1
        b_ax = 1 if (len(shape) >= 2 and shape[0] == 3) else 0
        if shape[b_ax] % bsize == 0 and shape[b_ax] >= bsize:
            spec[b_ax] = baxes
        elif shape[b_ax] % dsize == 0 and shape[b_ax] >= dsize:
            spec[b_ax] = "data"
        elif len(shape) > b_ax + 1 and shape[b_ax + 1] % dsize == 0:
            spec[b_ax + 1] = "data"  # SP fallback (e.g. long_500k batch=1)
        return P(*spec)

    return jax.tree_util.tree_map(one, batch_tree)


def decode_state_pspecs(state_tree: Any, mesh: Mesh):
    """KV caches / SSM states: batch (serving: slot) axis over ("pod","data")
    when divisible, head/feature dims over "model"; layer-stack leading axis
    skipped.

    The "model" pick prefers trailing head/feature axes (axis >= 3) over the
    sequence axis (axis 2): head-parallel attention keeps the per-shard cache
    contiguous in time, while a time-sharded cache forces a collective on
    every decode-step append.  Integer leaves (kpos-style position maps) stay
    replicated beyond the batch axis — they are tiny and feed mask math on
    every shard.

    Paged pools keep the same rule by construction: their layout is
    [L, n_blocks, bs, ...], so axis 1 — the pool axis, padded to a multiple
    of 8 — shards over ("pod","data") exactly the way slots do.  The shared
    ``block_tbl`` [B, view_blocks] is the one path-keyed exception: every
    shard's gather needs the full table, so it is replicated."""
    baxes = _batch_axes(mesh)
    bsize = int(np.prod([mesh_axis_size(mesh, a) for a in ("pod", "data")]))
    dsize = mesh_axis_size(mesh, "data")
    msize = mesh_axis_size(mesh, "model")

    def one(name, leaf):
        shape = tuple(leaf.shape)
        if "block_tbl" in name or len(shape) <= 1:
            return P()
        spec: list = [None] * len(shape)
        b_ax = 1  # [L, B, ...] / paged [L, Nb, ...] layout everywhere
        if shape[b_ax] % bsize == 0 and shape[b_ax] >= bsize:
            spec[b_ax] = baxes
        elif shape[b_ax] % dsize == 0 and shape[b_ax] >= dsize:
            spec[b_ax] = "data"
        if np.issubdtype(np.dtype(leaf.dtype), np.integer):
            return P(*spec)
        order = (sorted(range(3, len(shape)), key=lambda i: -shape[i])
                 + ([2] if len(shape) > 2 else []))
        mi = next((i for i in order if shape[i] % msize == 0 and shape[i] >= msize), None)
        if mi is not None:
            spec[mi] = "model"
        return P(*spec)

    paths_leaves = jax.tree_util.tree_flatten_with_path(state_tree)[0]
    flat = [one("/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path),
                leaf) for path, leaf in paths_leaves]
    treedef = jax.tree_util.tree_structure(state_tree)
    return jax.tree_util.tree_unflatten(treedef, flat)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))
