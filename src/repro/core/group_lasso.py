"""Group-lasso regularization via proximal gradients (paper Sec. III-B).

The regularizer (eq. (6)) penalizes the l2 norms of *groups* (rows of a
reshaped weight matrix), and training interleaves SGD steps with the proximal
operator (eq. (7)), which is row-wise block soft thresholding (eq. (8)):

    prox(A)_i = max(1 - eta*lambda / ||A_i||_2, 0) * A_i

Group layouts:
  * dense layers: groups = columns of W (input neurons)  => reshape = W^T
  * conv layers (FK/PK): groups = rows of the per-input-channel matrices,
    stacked as eq. (11).

Both numpy (offline) and jax (in-training, used by ``repro.optim.ProxSGD`` and
the ``group_prox`` Pallas kernel) implementations live here.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "group_prox_rows_np",
    "group_prox_rows",
    "group_lasso_penalty",
    "prox_dense_columns",
    "prox_dense_columns_np",
    "group_norms",
]

_EPS = 1e-12


def group_prox_rows_np(a: np.ndarray, thresh: float) -> np.ndarray:
    """Block soft threshold on rows (eq. (8)), numpy.

    ``||A_i|| = 0`` rows map to exactly 0: the prox of the zero group is the
    zero group for any threshold, and guarding explicitly (instead of an eps
    in the divisor) keeps the output free of NaN/Inf *and* of eps-scaled
    round-off for structurally-pruned rows.
    """
    a = np.asarray(a, dtype=np.float64)
    norms = np.linalg.norm(a, axis=-1, keepdims=True)
    scale = np.where(norms > 0.0,
                     np.maximum(1.0 - thresh / np.maximum(norms, _EPS), 0.0),
                     0.0)
    return scale * a


def group_prox_rows(a: jnp.ndarray, thresh: float | jnp.ndarray) -> jnp.ndarray:
    """Block soft threshold on rows (eq. (8)), jax. Rows are the last-1 axis
    groups.  Zero-norm rows map to exactly 0 (same guard as the numpy path)."""
    norms = jnp.sqrt(jnp.sum(a * a, axis=-1, keepdims=True))
    scale = jnp.where(norms > 0.0,
                      jnp.maximum(1.0 - thresh / jnp.maximum(norms, _EPS), 0.0),
                      0.0)
    return scale * a


def prox_dense_columns(w: jnp.ndarray, thresh: float | jnp.ndarray) -> jnp.ndarray:
    """Dense-layer prox: groups are *columns* (input neurons), i.e. rows of W^T."""
    return group_prox_rows(w.T, thresh).T


def prox_dense_columns_np(w: np.ndarray, thresh: float) -> np.ndarray:
    return group_prox_rows_np(w.T, thresh).T


def group_norms(w: np.ndarray | jnp.ndarray, axis: int = 0):
    """l2 norm per group where ``axis`` indexes *within* the group."""
    if isinstance(w, np.ndarray):
        return np.linalg.norm(w, axis=axis)
    return jnp.sqrt(jnp.sum(w * w, axis=axis))


def group_lasso_penalty(w, lam: float, groups_axis: int = 0) -> float:
    """R = lambda * sum_groups ||group||_2  (eq. (6)), for logging/objective."""
    return lam * group_norms(w, axis=groups_axis).sum()
