"""Conv -> CMVM reshaping: FK and PK methods (paper Sec. III-D).

Kernel layout [N, K, O, O] (out-channels, in-channels, kh, kw), inputs
[B, K, Z, Z] (NCHW).  Both methods view the conv as K per-input-channel
constant matrices, which is what LCC decomposes and what the group-lasso
groups (eq. (11)) are defined over.

* FK (full kernel):    W_k in R^{N x O^2},  rows = flattened kernels.
* PK (partial kernel): W_k in R^{NO x O},   rows = single kernel *columns*
  (footnote 4: columns are used for the numerics), row order (n, j) -> n*O+j.
  Taller matrices => better LCC. Column-products are shared across the O
  horizontal output positions that see the same input column; the O partial
  outputs per conv are summed afterwards.

Addition accounting is per output spatial position (the ratio in the paper is
invariant to the position count since baseline and compressed counts both
scale by it):

  FK:  sum_k adds(W_k) + N*(K_nz - 1)
  PK:  sum_k adds(W_k) + N*(O - 1) + N*(K_nz - 1)   [amortized: one new
       column-matvec per output position; O-1 partial combines per output]
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "conv_fk_matrices",
    "conv_pk_matrices",
    "fk_group_matrix",
    "pk_group_matrix",
    "conv_forward_reference",
    "conv_forward_fk",
    "conv_forward_pk",
    "conv_layer_adds",
    "same_pad_2d",
    "extract_patches",
    "extract_vert_windows",
]


def conv_fk_matrices(kernel: np.ndarray) -> np.ndarray:
    """[N, K, O, O] -> [K, N, O*O]."""
    n, k, o1, o2 = kernel.shape
    return np.transpose(kernel, (1, 0, 2, 3)).reshape(k, n, o1 * o2)


def conv_pk_matrices(kernel: np.ndarray) -> np.ndarray:
    """[N, K, O, O] -> [K, N*O, O]; row (n, j) = kernel[n, k, :, j] (a column)."""
    n, k, oh, ow = kernel.shape
    # [K, N, ow(j), oh(i)]: row block per n is its ow columns, each of length oh
    m = np.transpose(kernel, (1, 0, 3, 2))
    return m.reshape(k, n * ow, oh)


def fk_group_matrix(kernel: np.ndarray) -> np.ndarray:
    """Eq. (11): stack the FK matrices -> groups are rows (= whole kernels)."""
    mats = conv_fk_matrices(kernel)  # [K, N, O^2]
    return mats.reshape(-1, mats.shape[-1])


def pk_group_matrix(kernel: np.ndarray) -> np.ndarray:
    """Eq. (11) for PK: groups are single kernel columns."""
    mats = conv_pk_matrices(kernel)  # [K, N*O, O]
    return mats.reshape(-1, mats.shape[-1])


def conv_forward_reference(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Plain VALID / stride-1 conv (cross-correlation), NCHW/OIHW."""
    return lax.conv_general_dilated(
        x, kernel, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv_forward_fk(x: jnp.ndarray, fk_mats: jnp.ndarray) -> jnp.ndarray:
    """Conv evaluated through the FK matrices. fk_mats: [K, N, O^2]."""
    k, n, oo = fk_mats.shape
    o = int(round(np.sqrt(oo)))
    b, kk, z, _ = x.shape
    assert kk == k
    p = z - o + 1
    # im2col per channel: [B, K, P, P, O, O]
    patches = extract_patches(x, o)
    # y[b, n, p, q] = sum_k fk[k, n, :] . patch[b, k, p, q, :]
    return jnp.einsum("kno,bkpqo->bnpq", fk_mats, patches.reshape(b, k, p, p, oo))


def conv_forward_pk(x: jnp.ndarray, pk_mats: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Conv evaluated through the PK matrices. pk_mats: [K, N*O, O].

    partial[b,k,p,cq,(n,j)] = pk[k,(n,j),:] . x[b,k,p:p+O,cq]  (a column product)
    y[b,n,p,q] = sum_k sum_j partial at column cq = q + j.
    """
    k, no, o = pk_mats.shape
    n = n_out
    assert no == n * o
    b, kk, z, _ = x.shape
    p = z - o + 1
    # column windows: [B, K, P, Z, O] — vertical O-slices at every (row p, col c)
    cols = extract_vert_windows(x, o)  # [B, K, P, Z, O]
    part = jnp.einsum("kro,bkpco->bkpcr", pk_mats, cols)  # r = (n, j)
    part = part.reshape(b, k, p, z, n, o)
    # gather j-offset columns: y[..., q] = sum_j part[..., q + j, :, j]
    qs = jnp.arange(p)
    js = jnp.arange(o)
    cq = qs[:, None] + js[None, :]  # [P, O]
    sel = part[:, :, :, cq, :, :]  # [B, K, P, P, O(j), N, O(j')]
    diag = jnp.einsum("bkpqjnj->bkpqn", sel.reshape(b, k, p, p, o, n, o)[..., :, :, :])
    # the einsum above picks j == j' (diagonal over the two O axes)
    y = diag.sum(axis=1)  # sum over input channels
    return jnp.moveaxis(y, -1, 1)  # [B, N, P, P]


def same_pad_2d(z: int, o: int, stride: int) -> tuple[int, int]:
    """XLA "SAME" padding amounts (lo, hi) along one spatial dim."""
    out = -(-z // stride)  # ceil division
    total = max((out - 1) * stride + o - z, 0)
    return total // 2, total - total // 2


def extract_patches(x: jnp.ndarray, o: int, stride: int = 1) -> jnp.ndarray:
    """[B, K, Z, Z] -> [B, K, P, P, O, O] sliding windows (valid, strided)."""
    b, k, z, _ = x.shape
    p = (z - o) // stride + 1
    i = stride * jnp.arange(p)[:, None] + jnp.arange(o)[None, :]  # [P, O]
    rows = x[:, :, i, :]  # [B, K, P, O, Z]
    cols = rows[:, :, :, :, i]  # [B, K, P, O, P, O]
    return jnp.transpose(cols, (0, 1, 2, 4, 3, 5))  # [B, K, P, P, O, O]


def extract_vert_windows(x: jnp.ndarray, o: int, stride: int = 1) -> jnp.ndarray:
    """[B, K, Z, Z] -> [B, K, P, Z, O]: vertical O-windows at each (strided
    output row p, input column)."""
    b, k, z, _ = x.shape
    p = (z - o) // stride + 1
    i = stride * jnp.arange(p)[:, None] + jnp.arange(o)[None, :]  # [P, O]
    win = x[:, :, i, :]  # [B, K, P, O, Z]
    return jnp.transpose(win, (0, 1, 2, 4, 3))  # [B, K, P, Z, O]


def conv_layer_adds(per_matrix_adds: list[int], n_out: int, o: int, method: str,
                    n_channels_nonzero: int | None = None) -> int:
    """Per-output-position additions for a conv layer given per-W_k CMVM adds."""
    k_nz = n_channels_nonzero if n_channels_nonzero is not None else len(per_matrix_adds)
    total = int(sum(per_matrix_adds))
    if method == "fk":
        return total + n_out * max(0, k_nz - 1)
    if method == "pk":
        return total + n_out * (o - 1) + n_out * max(0, k_nz - 1)
    raise ValueError(f"unknown conv method {method!r}")
