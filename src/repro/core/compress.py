"""Algorithm 1: the complete NN compression procedure for LCC.

    1. regularized (group-lasso, proximal) training      -> repro.optim.ProxSGD
    2. affinity-propagation clustering + tied retraining -> weight_sharing
    3. LCC decomposition of every (equivalent) matrix    -> lcc

This module orchestrates steps 2-3 on trained parameters and produces the
per-layer cost report; step 1 happens inside the training loop (the prox is an
optimizer transform).  It is model-agnostic: a model exposes *compressible
units* (dense matrices or conv kernels) through small adapter records.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .conv_reshape import conv_fk_matrices, conv_layer_adds, conv_pk_matrices
from .cost import LayerCost, ModelCostReport, shared_layer_adds
from .csd import adds_csd_matrix
from .lcc import (LCCChain, FSProgram, LCCDecomposition, lcc_decompose,
                  lcc_decompose_slice, plan_col_slices, resolve_target_snr_db,
                  assemble_decomposition, expand_slice_piece, zero_slice_piece)
from .weight_sharing import SharedLayer, cluster_columns, cluster_columns_fixed

__all__ = [
    "CompressionConfig",
    "CompressibleDense",
    "CompressibleConv",
    "CompressedDense",
    "PreparedDense",
    "PreparedConv",
    "prepare_dense",
    "finish_dense",
    "prepare_conv",
    "finish_conv",
    "conv_channel_decompose",
    "compress_dense_matrix",
    "compress_conv_kernel",
    "compress_model_params",
    "prune_columns",
    "slice_job_plan",
]


@dataclass
class CompressionConfig:
    algorithm: str = "fs"  # 'fp' | 'fs'
    s_terms: int = 2
    frac_bits: int = 8
    target_snr_db: float | None = None  # None => match CSD quantization SNR
    snr_offset_db: float = 0.0  # allocator knob: fidelity delta vs the
                                # resolved target (negative => cheaper/lossier)
    slice_width: int | None = None
    weight_sharing: bool = True
    share_damping: float = 0.7
    share_preference: float | None = None
    share_clusters: int | None = None  # allocator knob: exact cluster count
                                       # (deterministic k-center) instead of
                                       # affinity propagation's own choice
    conv_method: str = "pk"  # 'fk' | 'pk'
    prune_tol: float = 1e-8  # column-norm threshold: drop pruned inputs
    max_share_rel_err: float | None = None  # drop sharing if ||W-G[labels]||/||W|| exceeds
                                            # (paper: 'provided this has minimal impact';
                                            # the full remedy is eq.-(9) retraining)
    max_factors: int = 24
    max_terms_per_row: int = 64


@dataclass
class CompressibleDense:
    name: str
    weight: np.ndarray  # [N, K] acting as y = W x


@dataclass
class CompressibleConv:
    name: str
    kernel: np.ndarray  # [N, K, O, O]


@dataclass
class CompressedDense:
    """Everything needed to run + account one compressed dense layer."""

    name: str
    kept_columns: np.ndarray  # indices into the original K inputs
    shared: SharedLayer | None  # None if weight sharing disabled
    decomposition: LCCDecomposition
    effective: np.ndarray  # dense equivalent of the compressed map [N, K_kept]

    def apply(self, x: np.ndarray) -> np.ndarray:
        """Reference evaluation: x [K_orig, ...] -> y [N, ...]."""
        xk = x[self.kept_columns]
        if self.shared is not None:
            c = self.shared.n_clusters
            agg = np.zeros((c,) + xk.shape[1:])
            np.add.at(agg, self.shared.labels, xk)
            return self.decomposition.apply(agg)
        return self.decomposition.apply(xk)


def prune_columns(w: np.ndarray, tol: float) -> tuple[np.ndarray, np.ndarray]:
    """Drop (near-)zero columns produced by the group-lasso prox.

    ``tol < 0`` selects *keep-in-place* mode: columns are not compacted (input
    addressing stays stable, so serving needs no gather layer) and the dead
    columns — norm <= |tol| — are instead eliminated per column slice by
    :func:`slice_job_plan`, which skips all-dead slices and shrinks partially
    dead ones.
    """
    if tol < 0:
        return w, np.arange(w.shape[1])
    norms = np.linalg.norm(w, axis=0)
    keep = np.where(norms > tol)[0]
    if keep.size == 0:
        keep = np.array([int(np.argmax(norms))])
    return w[:, keep], keep


@dataclass
class PreparedDense:
    """Per-unit state after the *prepare* stage (prune + cluster + slice plan).

    Everything a column-slice decomposition job needs is derived from
    ``target``/``target_snr_db`` plus config knobs, so slice jobs are pure,
    order-free and content-addressable."""

    name: str
    weight_shape: tuple[int, int]  # original [N, K] (bytes accounting only —
                                   # the full matrix is NOT retained: prepared
                                   # units are memoized across allocator probes)
    kept_columns: np.ndarray
    shared: SharedLayer | None
    target: np.ndarray  # the matrix the LCC stage decomposes
    target_snr_db: float  # resolved (+ allocator offset)
    col_slices: list[tuple[int, int]]
    baseline_adds: int
    pruned_adds: int
    pre_agg: int


def prepare_dense(name: str, w: np.ndarray, cfg: CompressionConfig) -> PreparedDense:
    """Stage 1 for a dense matrix: prune columns, cluster for weight sharing,
    resolve the fidelity target and plan the column slices."""
    w = np.asarray(w, dtype=np.float64)
    baseline = adds_csd_matrix(w, cfg.frac_bits)

    wp, kept = prune_columns(w, cfg.prune_tol)
    pruned_adds = adds_csd_matrix(wp, cfg.frac_bits)

    shared: SharedLayer | None = None
    target = wp
    pre_agg = 0
    # keep-in-place pruning (prune_tol < 0) forgoes sharing: sharing compacts
    # inputs into codebook space, which defeats stable input addressing, and
    # dead columns would distort the clustering
    if cfg.weight_sharing and wp.shape[1] > 2 and cfg.prune_tol >= 0:
        if cfg.share_clusters is not None:
            labels, cents = cluster_columns_fixed(wp, cfg.share_clusters)
        else:
            labels, cents = cluster_columns(
                wp, damping=cfg.share_damping, preference=cfg.share_preference
            )
        rel = float(np.linalg.norm(wp - cents[:, labels]) /
                    max(np.linalg.norm(wp), 1e-30))
        if cfg.max_share_rel_err is not None and rel > cfg.max_share_rel_err:
            shared = None  # too lossy without eq.-(9) retraining: skip sharing
        else:
            # store labels at their deployment width (uint16 covers any layer
            # whose kept inputs fit a 16-bit index; int32 otherwise) so byte
            # accounting below reads the true stored size, not an assumption
            # about the clustering routine's int64 output
            label_dtype = np.uint16 if cents.shape[1] <= np.iinfo(np.uint16).max else np.int32
            shared = SharedLayer(centroids=cents, labels=labels.astype(label_dtype))
            target = cents
            pre_agg = shared.pre_aggregation_adds()

    snr = resolve_target_snr_db(target, cfg.target_snr_db, cfg.frac_bits) \
        + cfg.snr_offset_db
    return PreparedDense(
        name=name, weight_shape=(int(w.shape[0]), int(w.shape[1])),
        kept_columns=kept, shared=shared, target=target,
        target_snr_db=snr,
        col_slices=plan_col_slices(target.shape[0], target.shape[1],
                                   cfg.slice_width),
        baseline_adds=baseline, pruned_adds=pruned_adds, pre_agg=pre_agg,
    )


def slice_job_plan(
    prep: PreparedDense, cfg: CompressionConfig,
) -> list[tuple[int, tuple[int, int], np.ndarray, np.ndarray | None]]:
    """The decomposition jobs a prepared dense unit actually needs.

    Returns ``(slice_index, (c0, c1), mat, keep)`` per slice that must be
    decomposed; ``keep`` is ``None`` for a full slice, else the surviving
    column offsets within the slice and ``mat`` is compacted to them.  Slices
    whose columns are *all* dead are absent — they cost 0 adds and the
    assembler drops in :func:`repro.core.lcc.zero_slice_piece`.

    In drop mode (``prune_tol >= 0``) dead columns were already removed by
    :func:`prune_columns`, so every slice is a full job and nothing here
    changes — cache keys for non-sparse plans are bitwise-stable across this
    refactor.  Keep-in-place mode (``prune_tol < 0``) is where dead groups
    from regularized training become skipped/shrunk jobs.
    """
    jobs: list[tuple[int, tuple[int, int], np.ndarray, np.ndarray | None]] = []
    sparse = cfg.prune_tol < 0
    tol = abs(cfg.prune_tol)
    for i, (c0, c1) in enumerate(prep.col_slices):
        sub = prep.target[:, c0:c1]
        if not sparse:
            jobs.append((i, (c0, c1), sub, None))
            continue
        alive = np.where(np.linalg.norm(sub, axis=0) > tol)[0]
        if alive.size == 0:
            continue  # fully dead slice: skipped, 0 adds
        if alive.size == sub.shape[1]:
            jobs.append((i, (c0, c1), sub, None))
        else:
            jobs.append((i, (c0, c1), sub[:, alive], alive))
    return jobs


def finish_dense(
    prep: PreparedDense,
    pieces: list[LCCChain | FSProgram],
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
) -> CompressedDense:
    """Stage 3 for a dense matrix: assemble slice pieces (column order),
    account costs and build the dense-effective map."""
    dec = assemble_decomposition(prep.target, prep.col_slices, pieces,
                                 cfg.algorithm, prep.target_snr_db,
                                 cfg.frac_bits)
    shared, kept = prep.shared, prep.kept_columns
    if report is not None:
        lc = LayerCost(name=prep.name, baseline_adds=prep.baseline_adds)
        lc.stage_adds["pruned"] = prep.pruned_adds
        if shared is not None:
            lc.stage_adds["shared"] = shared_layer_adds(shared, cfg.frac_bits)
        lc.stage_adds["lcc"] = prep.pre_agg + dec.num_adds()
        lc.stage_bytes["dense_bf16"] = 2 * prep.weight_shape[0] * prep.weight_shape[1]
        lc.stage_bytes["lcc"] = dec.storage_bytes() + (shared.labels.nbytes if shared else 0)
        lc.extra["kept_cols"] = int(kept.size)
        lc.extra["clusters"] = int(shared.n_clusters) if shared else None
        lc.extra["achieved_snr_db"] = dec.meta.get("achieved_snr_db")
        if cfg.prune_tol < 0:
            dead = int(np.sum(np.linalg.norm(prep.target, axis=0)
                              <= abs(cfg.prune_tol)))
        else:
            dead = prep.weight_shape[1] - int(kept.size)
        lc.extra["dead_groups"] = dead
        report.add(lc)

    eff = dec.to_dense()
    if shared is not None:
        eff = eff[:, shared.labels]  # expand centroids back over kept columns
    return CompressedDense(
        name=prep.name, kept_columns=kept, shared=shared, decomposition=dec,
        effective=eff,
    )


def compress_dense_matrix(
    name: str,
    w: np.ndarray,
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
) -> CompressedDense:
    """Steps 2-3 of Algorithm 1 for one dense matrix (already reg-trained).

    Serial composition of the pipeline stages: :func:`prepare_dense` ->
    :func:`repro.core.lcc.lcc_decompose_slice` per column slice ->
    :func:`finish_dense`.  ``repro.pipeline`` fans the middle stage out over
    worker processes with bitwise-identical results.
    """
    prep = prepare_dense(name, w, cfg)
    n_rows = prep.target.shape[0]
    pieces: list[LCCChain | FSProgram] = [
        zero_slice_piece(cfg.algorithm, n_rows, c1 - c0)
        for c0, c1 in prep.col_slices
    ]
    for i, (c0, c1), mat, keep in slice_job_plan(prep, cfg):
        piece = lcc_decompose_slice(mat, cfg.algorithm,
                                    prep.target_snr_db, s_terms=cfg.s_terms,
                                    max_factors=cfg.max_factors,
                                    max_terms_per_row=cfg.max_terms_per_row)
        if keep is not None:
            piece = expand_slice_piece(piece, keep, c1 - c0)
        pieces[i] = piece
    return finish_dense(prep, pieces, cfg, report)


@dataclass
class PreparedConv:
    """Per-unit state after the conv *prepare* stage (FK/PK reshape + channel
    selection).  Each selected channel matrix decomposes independently — the
    pipeline's conv job granularity."""

    name: str
    kernel_shape: tuple[int, int, int, int]  # [N, K, O, O]; the kernel itself
                                             # is not retained — ``mats`` holds
                                             # the decomposition inputs
    mats: list[np.ndarray]  # per input channel, FK or PK matrix
    ch_nonzero: list[int]
    sel: list[int]  # channels actually decomposed (subsampling)
    baseline_adds: int


def prepare_conv(name: str, kernel: np.ndarray, cfg: CompressionConfig,
                 channel_subsample: int | None = None) -> PreparedConv:
    """Stage 1 for a conv kernel: reshape to per-channel matrices, drop
    group-lasso-pruned channels, pick the (sub)sampled decomposition set."""
    kernel = np.asarray(kernel, dtype=np.float64)
    n, k, o, _ = kernel.shape
    mats = conv_fk_matrices(kernel) if cfg.conv_method == "fk" else conv_pk_matrices(kernel)

    # kernel groups with all-zero rows (pruned by eq. (11) group lasso) drop
    # out; |prune_tol| so the dense keep-in-place convention (< 0) behaves —
    # conv channels decompose independently, so dropping dead ones never
    # perturbs addressing
    ch_nonzero = [i for i in range(k) if np.abs(mats[i]).max() > abs(cfg.prune_tol)]
    base_per = [adds_csd_matrix(mats[i], cfg.frac_bits) for i in range(k)]
    baseline = conv_layer_adds(base_per, n, o, cfg.conv_method, k)
    sel = ch_nonzero if channel_subsample is None else ch_nonzero[::channel_subsample]
    return PreparedConv(name=name, kernel_shape=(n, k, o, o), mats=mats,
                        ch_nonzero=ch_nonzero, sel=list(sel),
                        baseline_adds=baseline)


def conv_channel_decompose(mat: np.ndarray, cfg: CompressionConfig) -> LCCDecomposition:
    """Stage 2 for one conv input channel: decompose its FK/PK matrix.  Pure
    function of (matrix, config) — the conv job the pipeline dispatches."""
    snr = resolve_target_snr_db(mat, cfg.target_snr_db, cfg.frac_bits) \
        + cfg.snr_offset_db
    return lcc_decompose(
        mat,
        algorithm=cfg.algorithm,
        s_terms=cfg.s_terms,
        target_snr_db=snr,
        frac_bits=cfg.frac_bits,
        slice_width=cfg.slice_width,
        max_factors=cfg.max_factors,
        max_terms_per_row=cfg.max_terms_per_row,
    )


def finish_conv(
    prep: PreparedConv,
    decs: dict[int, LCCDecomposition],
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
    channel_subsample: int | None = None,
) -> dict:
    """Stage 3 for a conv kernel: per-channel adds -> layer totals + report."""
    n, k, o, _ = prep.kernel_shape
    mats, ch_nonzero, sel = prep.mats, prep.ch_nonzero, prep.sel
    lcc_per = [decs[i].num_adds() for i in sel]
    scale = (len(ch_nonzero) / max(len(sel), 1)) if sel else 0.0
    lcc_total = conv_layer_adds(
        [int(np.mean(lcc_per)) if lcc_per else 0] * len(ch_nonzero) if channel_subsample else lcc_per,
        n, o, cfg.conv_method, len(ch_nonzero),
    )
    pruned_total = conv_layer_adds(
        [adds_csd_matrix(mats[i], cfg.frac_bits) for i in ch_nonzero], n, o,
        cfg.conv_method, len(ch_nonzero),
    )
    if report is not None:
        lc = LayerCost(name=prep.name, baseline_adds=prep.baseline_adds)
        lc.stage_adds["pruned"] = pruned_total
        lc.stage_adds["lcc"] = lcc_total
        lc.extra["channels_nonzero"] = len(ch_nonzero)
        lc.extra["dead_groups"] = k - len(ch_nonzero)
        lc.extra["subsampled"] = channel_subsample
        report.add(lc)
    return {"decompositions": decs, "channels_nonzero": ch_nonzero,
            "baseline_adds": prep.baseline_adds, "lcc_adds": lcc_total,
            "scale": scale}


def compress_conv_kernel(
    name: str,
    kernel: np.ndarray,
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
    channel_subsample: int | None = None,
) -> dict:
    """Steps 2-3 for a conv layer via the FK or PK matrices.

    ``channel_subsample``: decompose only every n-th input-channel matrix and
    extrapolate the adds count (used for large ResNet benches on this CPU-only
    container; the decomposition of each W_k is independent so the estimate is
    unbiased). Subsampling is recorded in the report.

    Serial composition of :func:`prepare_conv` ->
    :func:`conv_channel_decompose` per channel -> :func:`finish_conv`; the
    pipeline fans the channel loop out with bitwise-identical results.
    """
    prep = prepare_conv(name, kernel, cfg, channel_subsample)
    decs = {i: conv_channel_decompose(prep.mats[i], cfg) for i in prep.sel}
    return finish_conv(prep, decs, cfg, report, channel_subsample)


def compress_model_params(
    units: list[CompressibleDense | CompressibleConv],
    cfg: CompressionConfig,
    conv_channel_subsample: int | None = None,
    progress: Callable | None = None,
    n_workers: int = 1,
    cache_dir: str | None = None,
) -> tuple[dict, ModelCostReport]:
    """Run steps 2-3 of Algorithm 1 over every compressible unit of a model.

    Thin serial wrapper over :func:`repro.pipeline.run_pipeline`: existing
    call sites keep working, and ``n_workers > 1`` / ``cache_dir`` opt into
    the parallel pipeline with identical (bitwise) outputs.  ``progress``
    receives structured :class:`repro.pipeline.CompressionEvent` objects
    (their ``str()`` is the old unit-name line).
    """
    from repro.pipeline import run_pipeline

    res = run_pipeline(units, cfg, n_workers=n_workers, cache_dir=cache_dir,
                       conv_channel_subsample=conv_channel_subsample,
                       progress=progress)
    return res.records, res.report
