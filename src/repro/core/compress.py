"""Algorithm 1: the complete NN compression procedure for LCC.

    1. regularized (group-lasso, proximal) training      -> repro.optim.ProxSGD
    2. affinity-propagation clustering + tied retraining -> weight_sharing
    3. LCC decomposition of every (equivalent) matrix    -> lcc

This module orchestrates steps 2-3 on trained parameters and produces the
per-layer cost report; step 1 happens inside the training loop (the prox is an
optimizer transform).  It is model-agnostic: a model exposes *compressible
units* (dense matrices or conv kernels) through small adapter records.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .conv_reshape import conv_fk_matrices, conv_layer_adds, conv_pk_matrices
from .cost import LayerCost, ModelCostReport, shared_layer_adds
from .csd import adds_csd_matrix
from .lcc import LCCDecomposition, lcc_decompose
from .weight_sharing import SharedLayer, cluster_columns

__all__ = [
    "CompressionConfig",
    "CompressibleDense",
    "CompressibleConv",
    "CompressedDense",
    "compress_dense_matrix",
    "compress_conv_kernel",
    "compress_model_params",
    "prune_columns",
]


@dataclass
class CompressionConfig:
    algorithm: str = "fs"  # 'fp' | 'fs'
    s_terms: int = 2
    frac_bits: int = 8
    target_snr_db: float | None = None  # None => match CSD quantization SNR
    slice_width: int | None = None
    weight_sharing: bool = True
    share_damping: float = 0.7
    share_preference: float | None = None
    conv_method: str = "pk"  # 'fk' | 'pk'
    prune_tol: float = 1e-8  # column-norm threshold: drop pruned inputs
    max_share_rel_err: float | None = None  # drop sharing if ||W-G[labels]||/||W|| exceeds
                                            # (paper: 'provided this has minimal impact';
                                            # the full remedy is eq.-(9) retraining)
    max_factors: int = 24
    max_terms_per_row: int = 64


@dataclass
class CompressibleDense:
    name: str
    weight: np.ndarray  # [N, K] acting as y = W x


@dataclass
class CompressibleConv:
    name: str
    kernel: np.ndarray  # [N, K, O, O]


@dataclass
class CompressedDense:
    """Everything needed to run + account one compressed dense layer."""

    name: str
    kept_columns: np.ndarray  # indices into the original K inputs
    shared: SharedLayer | None  # None if weight sharing disabled
    decomposition: LCCDecomposition
    effective: np.ndarray  # dense equivalent of the compressed map [N, K_kept]

    def apply(self, x: np.ndarray) -> np.ndarray:
        """Reference evaluation: x [K_orig, ...] -> y [N, ...]."""
        xk = x[self.kept_columns]
        if self.shared is not None:
            c = self.shared.n_clusters
            agg = np.zeros((c,) + xk.shape[1:])
            np.add.at(agg, self.shared.labels, xk)
            return self.decomposition.apply(agg)
        return self.decomposition.apply(xk)


def prune_columns(w: np.ndarray, tol: float) -> tuple[np.ndarray, np.ndarray]:
    """Drop (near-)zero columns produced by the group-lasso prox."""
    norms = np.linalg.norm(w, axis=0)
    keep = np.where(norms > tol)[0]
    if keep.size == 0:
        keep = np.array([int(np.argmax(norms))])
    return w[:, keep], keep


def compress_dense_matrix(
    name: str,
    w: np.ndarray,
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
) -> CompressedDense:
    """Steps 2-3 of Algorithm 1 for one dense matrix (already reg-trained)."""
    w = np.asarray(w, dtype=np.float64)
    baseline = adds_csd_matrix(w, cfg.frac_bits)

    wp, kept = prune_columns(w, cfg.prune_tol)
    pruned_adds = adds_csd_matrix(wp, cfg.frac_bits)

    shared: SharedLayer | None = None
    target = wp
    pre_agg = 0
    if cfg.weight_sharing and wp.shape[1] > 2:
        labels, cents = cluster_columns(
            wp, damping=cfg.share_damping, preference=cfg.share_preference
        )
        rel = float(np.linalg.norm(wp - cents[:, labels]) /
                    max(np.linalg.norm(wp), 1e-30))
        if cfg.max_share_rel_err is not None and rel > cfg.max_share_rel_err:
            shared = None  # too lossy without eq.-(9) retraining: skip sharing
        else:
            # store labels at their deployment width (uint16 covers any layer
            # whose kept inputs fit a 16-bit index; int32 otherwise) so byte
            # accounting below reads the true stored size, not an assumption
            # about the clustering routine's int64 output
            label_dtype = np.uint16 if cents.shape[1] <= np.iinfo(np.uint16).max else np.int32
            shared = SharedLayer(centroids=cents, labels=labels.astype(label_dtype))
            target = cents
            pre_agg = shared.pre_aggregation_adds()

    dec = lcc_decompose(
        target,
        algorithm=cfg.algorithm,
        s_terms=cfg.s_terms,
        target_snr_db=cfg.target_snr_db,
        frac_bits=cfg.frac_bits,
        slice_width=cfg.slice_width,
        max_factors=cfg.max_factors,
        max_terms_per_row=cfg.max_terms_per_row,
    )

    if report is not None:
        lc = LayerCost(name=name, baseline_adds=baseline)
        lc.stage_adds["pruned"] = pruned_adds
        if shared is not None:
            lc.stage_adds["shared"] = shared_layer_adds(shared, cfg.frac_bits)
        lc.stage_adds["lcc"] = pre_agg + dec.num_adds()
        lc.stage_bytes["dense_bf16"] = 2 * w.shape[0] * w.shape[1]
        lc.stage_bytes["lcc"] = dec.storage_bytes() + (shared.labels.nbytes if shared else 0)
        lc.extra["kept_cols"] = int(kept.size)
        lc.extra["clusters"] = int(shared.n_clusters) if shared else None
        lc.extra["achieved_snr_db"] = dec.meta.get("achieved_snr_db")
        report.add(lc)

    eff = dec.to_dense()
    if shared is not None:
        eff = eff[:, shared.labels]  # expand centroids back over kept columns
    return CompressedDense(
        name=name, kept_columns=kept, shared=shared, decomposition=dec, effective=eff
    )


def compress_conv_kernel(
    name: str,
    kernel: np.ndarray,
    cfg: CompressionConfig,
    report: ModelCostReport | None = None,
    channel_subsample: int | None = None,
) -> dict:
    """Steps 2-3 for a conv layer via the FK or PK matrices.

    ``channel_subsample``: decompose only every n-th input-channel matrix and
    extrapolate the adds count (used for large ResNet benches on this CPU-only
    container; the decomposition of each W_k is independent so the estimate is
    unbiased). Subsampling is recorded in the report.
    """
    kernel = np.asarray(kernel, dtype=np.float64)
    n, k, o, _ = kernel.shape
    mats = conv_fk_matrices(kernel) if cfg.conv_method == "fk" else conv_pk_matrices(kernel)

    # kernel groups with all-zero rows (pruned by eq. (11) group lasso) drop out
    ch_nonzero = [i for i in range(k) if np.abs(mats[i]).max() > cfg.prune_tol]
    base_per = [adds_csd_matrix(mats[i], cfg.frac_bits) for i in range(k)]
    baseline = conv_layer_adds(base_per, n, o, cfg.conv_method, k)

    sel = ch_nonzero if channel_subsample is None else ch_nonzero[::channel_subsample]
    decs: dict[int, LCCDecomposition] = {}
    lcc_per: list[int] = []
    pruned_per: list[int] = []
    for i in sel:
        d = lcc_decompose(
            mats[i],
            algorithm=cfg.algorithm,
            s_terms=cfg.s_terms,
            target_snr_db=cfg.target_snr_db,
            frac_bits=cfg.frac_bits,
            slice_width=cfg.slice_width,
            max_factors=cfg.max_factors,
            max_terms_per_row=cfg.max_terms_per_row,
        )
        decs[i] = d
        lcc_per.append(d.num_adds())
        pruned_per.append(adds_csd_matrix(mats[i], cfg.frac_bits))
    scale = (len(ch_nonzero) / max(len(sel), 1)) if sel else 0.0
    lcc_total = conv_layer_adds(
        [int(np.mean(lcc_per)) if lcc_per else 0] * len(ch_nonzero) if channel_subsample else lcc_per,
        n, o, cfg.conv_method, len(ch_nonzero),
    )
    pruned_total = conv_layer_adds(
        [adds_csd_matrix(mats[i], cfg.frac_bits) for i in ch_nonzero], n, o,
        cfg.conv_method, len(ch_nonzero),
    )
    if report is not None:
        lc = LayerCost(name=name, baseline_adds=baseline)
        lc.stage_adds["pruned"] = pruned_total
        lc.stage_adds["lcc"] = lcc_total
        lc.extra["channels_nonzero"] = len(ch_nonzero)
        lc.extra["subsampled"] = channel_subsample
        report.add(lc)
    return {"decompositions": decs, "channels_nonzero": ch_nonzero,
            "baseline_adds": baseline, "lcc_adds": lcc_total, "scale": scale}


def compress_model_params(
    units: list[CompressibleDense | CompressibleConv],
    cfg: CompressionConfig,
    conv_channel_subsample: int | None = None,
    progress: Callable[[str], None] | None = None,
) -> tuple[dict, ModelCostReport]:
    """Run steps 2-3 of Algorithm 1 over every compressible unit of a model."""
    report = ModelCostReport()
    out: dict[str, object] = {}
    for u in units:
        if progress:
            progress(u.name)
        if isinstance(u, CompressibleDense):
            out[u.name] = compress_dense_matrix(u.name, u.weight, cfg, report)
        elif isinstance(u, CompressibleConv):
            out[u.name] = compress_conv_kernel(
                u.name, u.kernel, cfg, report, channel_subsample=conv_channel_subsample
            )
        else:
            raise TypeError(f"unknown compressible unit {type(u)}")
    return out, report
