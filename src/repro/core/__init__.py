"""The paper's contribution: LCC + group-lasso pruning + weight sharing."""
from .compress import (  # noqa: F401
    CompressibleConv,
    CompressibleDense,
    CompressionConfig,
    compress_conv_kernel,
    compress_dense_matrix,
    compress_model_params,
)
from .cost import LayerCost, ModelCostReport  # noqa: F401
from .csd import adds_csd_matrix, csd_digit_count, csd_digits, quantize_fixed  # noqa: F401
from .group_lasso import group_lasso_penalty, group_prox_rows, prox_dense_columns  # noqa: F401
from .lcc import LCCDecomposition, lcc_decompose, snr_db  # noqa: F401
from .weight_sharing import (  # noqa: F401
    SharedLayer,
    affinity_propagation,
    cluster_columns,
    shared_matvec,
)
