"""Linear computation coding (LCC) — the paper's core contribution.

A constant matrix ``W`` (vertically sliced into tall submatrices, eq. (3)) is
approximated as a product of sparse factors whose rows hold only signed powers
of two (eq. (4)).  Evaluating ``W @ x`` then needs only additions and
bit-shifts.  Two decomposition algorithms (paper Sec. III-A):

* **FP (fully parallel)** — every factor row draws at most ``S`` terms from the
  *previous factor's outputs*; ≤ S-1 adds per row per factor, rows independent.
* **FS (fully sequential)** — a growing computation DAG: every partial sum ever
  computed may be reused by later rows; better compression, sequential.

Both are greedy matching pursuit over a power-of-two-coefficient dictionary.
Decomposition is offline numpy (float64); runtime application lives in
``repro.kernels`` (TPU) with these classes as the exchange format.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .csd import adds_csd_matrix, quantization_snr_db

__all__ = [
    "LCCFactor",
    "LCCChain",
    "FSProgram",
    "LCCDecomposition",
    "lcc_decompose",
    "lcc_decompose_slice",
    "plan_col_slices",
    "resolve_target_snr_db",
    "assemble_decomposition",
    "snr_db",
    "zero_slice_piece",
    "expand_slice_piece",
]

_EXP_RANGE = (-16, 15)  # signed powers of two representable by the int8 format


def snr_db(w: np.ndarray, w_hat: np.ndarray) -> float:
    err = float(np.sum((np.asarray(w, np.float64) - np.asarray(w_hat, np.float64)) ** 2))
    sig = float(np.sum(np.asarray(w, np.float64) ** 2))
    if err == 0.0:
        return np.inf
    if sig == 0.0:
        return 0.0
    return 10.0 * np.log10(sig / err)


def _quantize_po2(c: np.ndarray, exp_range: tuple[int, int]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Nearest signed power of two.  Returns (sign, exp, value); sign 0 => zero."""
    c = np.asarray(c, dtype=np.float64)
    sign = np.sign(c).astype(np.int8)
    m = np.abs(c)
    emin, emax = exp_range
    with np.errstate(divide="ignore"):
        ef = np.floor(np.log2(np.where(m > 0, m, 1.0))).astype(np.int64)
    # between 2^e and 2^{e+1} the linear midpoint is 1.5 * 2^e
    e = np.where(m > 1.5 * np.exp2(ef.astype(np.float64)), ef + 1, ef)
    e = np.clip(e, emin, emax)
    val = sign * np.exp2(e.astype(np.float64))
    # kill terms that would round to (near) zero: |c| below half the smallest grid step
    dead = m < np.exp2(float(emin)) / 2.0
    sign = np.where(dead, 0, sign).astype(np.int8)
    val = np.where(dead, 0.0, val)
    e = np.where(dead, 0, e)
    return sign, e.astype(np.int8), val


@dataclass
class LCCFactor:
    """One sparse factor: row r computes  sum_s sign[r,s] * 2^exp[r,s] * prev[idx[r,s]]."""

    idx: np.ndarray  # [out_dim, S] int32
    exp: np.ndarray  # [out_dim, S] int8
    sign: np.ndarray  # [out_dim, S] int8 in {-1, 0, +1}; 0 marks an unused slot
    in_dim: int

    @property
    def out_dim(self) -> int:
        return self.idx.shape[0]

    @property
    def s_terms(self) -> int:
        return self.idx.shape[1]

    def to_dense(self) -> np.ndarray:
        d = np.zeros((self.out_dim, self.in_dim), dtype=np.float64)
        val = self.sign.astype(np.float64) * np.exp2(self.exp.astype(np.float64))
        rows = np.repeat(np.arange(self.out_dim), self.s_terms)
        np.add.at(d, (rows, self.idx.reshape(-1)), val.reshape(-1))
        return d

    def apply(self, x: np.ndarray) -> np.ndarray:
        """x: [in_dim, ...] -> [out_dim, ...] via gather/shift/add (no matmul)."""
        val = self.sign.astype(np.float64) * np.exp2(self.exp.astype(np.float64))
        gathered = x[self.idx]  # [out, S, ...]
        return np.einsum("os,os...->o...", val, gathered)

    def num_adds(self) -> int:
        nnz = (self.sign != 0).sum(axis=1)
        return int(np.maximum(nnz - 1, 0).sum())

    def storage_bytes(self) -> int:
        """Compact stream format: int16 index + int8 (sign|exp) per nonzero term."""
        return int(3 * (self.sign != 0).sum())


@dataclass
class LCCChain:
    """FP factor chain for one tall slice:  W_e ~= F_P ... F_1  (F_0 = identity wiring)."""

    factors: list[LCCFactor]
    in_dim: int

    def to_dense(self) -> np.ndarray:
        a = np.eye(self.in_dim, dtype=np.float64)
        for f in self.factors:
            a = f.to_dense() @ a
        return a

    def apply(self, x: np.ndarray) -> np.ndarray:
        for f in self.factors:
            x = f.apply(x)
        return x

    def num_adds(self) -> int:
        return sum(f.num_adds() for f in self.factors)

    def storage_bytes(self) -> int:
        return sum(f.storage_bytes() for f in self.factors)


@dataclass
class FSProgram:
    """FS computation DAG.

    Node ids 0..K-1 are the inputs.  Node K+t computes
        sign_a * 2^exp_a * v[src_a]  (+ sign_b * 2^exp_b * v[src_b]  if src_b >= 0)
    ``outputs[i]`` is the node id providing output row i (-1 => zero row).
    Additions = number of binary nodes (unary nodes are wires/shifts).
    """

    n_inputs: int
    nodes: np.ndarray  # [T, 6] int64: (src_a, exp_a, sign_a, src_b, exp_b, sign_b)
    outputs: np.ndarray  # [N] int64

    def apply(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        vals: list[np.ndarray] = [x[k] for k in range(self.n_inputs)]
        for sa, ea, ga, sb, eb, gb in self.nodes:
            v = float(ga) * np.exp2(float(ea)) * vals[sa]
            if sb >= 0:
                v = v + float(gb) * np.exp2(float(eb)) * vals[sb]
            vals.append(v)
        zero = np.zeros_like(x[0])
        return np.stack([vals[o] if o >= 0 else zero for o in self.outputs])

    def to_dense(self) -> np.ndarray:
        eye = np.eye(self.n_inputs, dtype=np.float64)
        return self.apply(eye)

    def num_adds(self) -> int:
        if len(self.nodes) == 0:
            return 0
        return int((np.asarray(self.nodes)[:, 3] >= 0).sum())

    def storage_bytes(self) -> int:
        # each node: two (int16 idx + int8 sign|exp) slots
        return int(6 * len(self.nodes))


@dataclass
class LCCDecomposition:
    """Full-matrix decomposition: vertical slices (eq. (3)), one chain/program each."""

    shape: tuple[int, int]
    col_slices: list[tuple[int, int]]
    slices: list[LCCChain | FSProgram]
    algorithm: str  # 'fp' | 'fs'
    target_snr_db: float
    meta: dict = field(default_factory=dict)

    def to_dense(self) -> np.ndarray:
        n, k = self.shape
        w = np.zeros((n, k), dtype=np.float64)
        for (c0, c1), s in zip(self.col_slices, self.slices):
            w[:, c0:c1] = s.to_dense()
        return w

    def apply(self, x: np.ndarray) -> np.ndarray:
        """x: [K, ...] -> [N, ...];  W x = sum_e W_e x_e."""
        y = None
        for (c0, c1), s in zip(self.col_slices, self.slices):
            part = s.apply(x[c0:c1])
            y = part if y is None else y + part
        assert y is not None
        return y

    def num_adds(self) -> int:
        """Adds inside slices + combining the slice outputs (N per extra slice)."""
        n, _ = self.shape
        inner = sum(s.num_adds() for s in self.slices)
        nz = sum(1 for s in self.slices if s.num_adds() > 0 or _slice_nonzero(s))
        return inner + max(0, nz - 1) * n

    def storage_bytes(self) -> int:
        return sum(s.storage_bytes() for s in self.slices)

    def achieved_snr_db(self, w: np.ndarray) -> float:
        return snr_db(w, self.to_dense())


def _slice_nonzero(s: LCCChain | FSProgram) -> bool:
    if isinstance(s, FSProgram):
        return bool((np.asarray(s.outputs) >= 0).any())
    return any((f.sign != 0).any() for f in s.factors)


def zero_slice_piece(algorithm: str, n_rows: int, width: int) -> LCCChain | FSProgram:
    """The zero map [width] -> [n_rows] as a slice piece with 0 adds.

    For a fully-pruned slice (every column dead) the planner skips the
    decomposition job entirely and the reducer drops this in.  FP needs an
    explicit all-sign-0 factor — an *empty* chain means identity, not zero.
    FS encodes zero rows natively as ``outputs[i] = -1``.
    """
    if algorithm == "fs":
        return FSProgram(n_inputs=width,
                         nodes=np.zeros((0, 6), dtype=np.int64),
                         outputs=np.full(n_rows, -1, dtype=np.int64))
    return LCCChain(
        factors=[LCCFactor(idx=np.zeros((n_rows, 1), np.int32),
                           exp=np.zeros((n_rows, 1), np.int8),
                           sign=np.zeros((n_rows, 1), np.int8),
                           in_dim=width)],
        in_dim=width)


def expand_slice_piece(piece: LCCChain | FSProgram, keep: np.ndarray,
                       width: int) -> LCCChain | FSProgram:
    """Re-address a piece decomposed on a *compacted* slice back to full width.

    ``keep`` lists the surviving column offsets within the slice; the piece
    consumed a ``len(keep)``-wide input, the expanded piece consumes the full
    ``width``-wide slice and reads only the kept columns.  Pure re-indexing —
    adds, values, and structure are unchanged, so shrunk jobs cost exactly
    what the compacted decomposition cost.
    """
    keep = np.asarray(keep, dtype=np.int64)
    kdrop = len(keep)
    if isinstance(piece, FSProgram):
        n_in = piece.n_inputs
        assert n_in == kdrop, (n_in, kdrop)
        shift = width - kdrop

        def remap(ids: np.ndarray) -> np.ndarray:
            ids = np.asarray(ids, dtype=np.int64)
            out = np.where(ids >= kdrop, ids + shift, ids)
            is_input = (ids >= 0) & (ids < kdrop)
            out = np.where(is_input, keep[np.clip(ids, 0, kdrop - 1)], out)
            return np.where(ids < 0, ids, out)  # -1 (zero row / unary) stays

        nodes = np.asarray(piece.nodes, dtype=np.int64).copy()
        if len(nodes):
            nodes[:, 0] = remap(nodes[:, 0])
            nodes[:, 3] = remap(nodes[:, 3])
        return FSProgram(n_inputs=width, nodes=nodes,
                         outputs=remap(piece.outputs))
    assert piece.in_dim == kdrop, (piece.in_dim, kdrop)
    if not piece.factors:
        # empty chain = identity on the compacted input; expanded, that is a
        # 0-add gather of the kept columns
        gather = LCCFactor(idx=keep.astype(np.int32).reshape(-1, 1),
                           exp=np.zeros((kdrop, 1), np.int8),
                           sign=np.ones((kdrop, 1), np.int8),
                           in_dim=width)
        return LCCChain(factors=[gather], in_dim=width)
    first = piece.factors[0]
    remapped = LCCFactor(idx=keep[first.idx].astype(np.int32),
                         exp=first.exp, sign=first.sign, in_dim=width)
    return LCCChain(factors=[remapped] + piece.factors[1:], in_dim=width)


# --------------------------------------------------------------------------
# FP algorithm: vectorized matching pursuit, one factor at a time
# --------------------------------------------------------------------------


def _mp_factor(
    targets: np.ndarray,  # [N, K] rows to approximate
    dictionary: np.ndarray,  # [M, K] currently computable functionals
    s_terms: int,
    exp_range: tuple[int, int],
) -> tuple[LCCFactor, np.ndarray]:
    n, k = targets.shape
    m = dictionary.shape[0]
    dn2 = np.sum(dictionary**2, axis=1)
    ok = dn2 > 1e-30
    dn2_safe = np.where(ok, dn2, 1.0)

    idx = np.zeros((n, s_terms), dtype=np.int32)
    exp = np.zeros((n, s_terms), dtype=np.int8)
    sgn = np.zeros((n, s_terms), dtype=np.int8)

    r = targets.astype(np.float64).copy()
    for s in range(s_terms):
        corr = r @ dictionary.T  # [N, M]
        gain = np.where(ok[None, :], corr**2 / dn2_safe[None, :], -1.0)
        j = np.argmax(gain, axis=1)  # [N]
        c = corr[np.arange(n), j] / dn2_safe[j]
        sg, e, val = _quantize_po2(c, exp_range)
        r -= val[:, None] * dictionary[j]
        idx[:, s] = j
        exp[:, s] = e
        sgn[:, s] = sg
    approx = targets - r  # = F @ dictionary by construction
    return LCCFactor(idx=idx, exp=exp, sign=sgn, in_dim=m), approx


def _fp_chain_fixed_s(
    w: np.ndarray,
    s_terms: int,
    target_snr_db: float,
    max_factors: int,
    exp_range: tuple[int, int],
) -> LCCChain:
    n, k = w.shape
    factors: list[LCCFactor] = []
    dictionary = np.eye(k, dtype=np.float64)
    approx = np.zeros_like(w, dtype=np.float64)
    prev_snr = -np.inf
    for p in range(max_factors):
        f, approx = _mp_factor(w, dictionary, s_terms, exp_range)
        factors.append(f)
        dictionary = approx  # next factor draws from this factor's outputs only
        cur = snr_db(w, approx)
        if cur >= target_snr_db or cur - prev_snr < 0.1:  # met or stalled
            break
        prev_snr = cur
    return LCCChain(factors=factors, in_dim=k)


def _fp_chain(
    w: np.ndarray,
    s_terms: int,
    target_snr_db: float,
    max_factors: int,
    exp_range: tuple[int, int],
) -> LCCChain:
    """FP with S-escalation: greedy MP with quantized coefficients can stall
    below the target (quantization error ~ residual); when that happens a
    larger per-row budget S converges in far fewer factors — and empirically
    often with *fewer total adds*.  We keep the cheapest chain that meets the
    target (or the best-SNR chain if none does)."""
    best_met: LCCChain | None = None
    best_met_adds = None
    best_any: LCCChain | None = None
    best_any_snr = -np.inf
    for s in range(s_terms, s_terms + 3):
        chain = _fp_chain_fixed_s(w, s, target_snr_db, max_factors, exp_range)
        cur = snr_db(w, chain.to_dense())
        if cur >= target_snr_db and (best_met_adds is None
                                     or chain.num_adds() < best_met_adds):
            best_met, best_met_adds = chain, chain.num_adds()
        if cur > best_any_snr or best_any is None:
            best_any, best_any_snr = chain, cur
    return best_met if best_met is not None else best_any


# --------------------------------------------------------------------------
# FS algorithm: sequential matching pursuit over a growing global codebook
# --------------------------------------------------------------------------


def _fs_program(
    w: np.ndarray,
    target_snr_db: float,
    max_terms_per_row: int,
    exp_range: tuple[int, int],
) -> FSProgram:
    n, k = w.shape
    snr_lin = 10.0 ** (target_snr_db / 10.0)

    cap = k + 4 * n + 8
    book = np.zeros((cap, k), dtype=np.float64)
    book[:k] = np.eye(k)
    norms2 = np.ones(cap)
    norms2[:k] = 1.0
    m = k  # current codebook size

    nodes: list[tuple[int, int, int, int, int, int]] = []
    outputs = np.full(n, -1, dtype=np.int64)

    # process high-energy rows first: their partial sums seed the codebook
    order = np.argsort(-np.sum(w**2, axis=1))
    for i in order:
        wi = w[i].astype(np.float64)
        wn2 = float(np.sum(wi**2))
        if wn2 <= 1e-30:
            continue  # structurally zero (pruned) row
        tol2 = wn2 / snr_lin
        r = wi.copy()
        cur_node = -1
        cur_vec = np.zeros(k)
        for _ in range(max_terms_per_row):
            if float(np.sum(r**2)) <= tol2:
                break
            corr = book[:m] @ r
            gain = corr**2 / norms2[:m]
            j = int(np.argmax(gain))
            c = float(corr[j] / norms2[j])
            sg, e, val = _quantize_po2(np.array([c]), exp_range)
            if sg[0] == 0:
                break  # nothing representable improves the residual
            a = float(val[0])
            new_vec = cur_vec + a * book[j]
            if cur_node == -1:
                nodes.append((j, int(e[0]), int(sg[0]), -1, 0, 0))  # wire/shift: 0 adds
            else:
                nodes.append((cur_node, 0, 1, j, int(e[0]), int(sg[0])))  # 1 add
            node_id = k + len(nodes) - 1
            cur_node = node_id
            cur_vec = new_vec
            r = wi - cur_vec
            # codebook rows stay aligned with node ids (row id == node id) so
            # every partial sum ever computed is reusable by later rows — the
            # defining property of the FS algorithm.
            row = k + len(nodes) - 1
            if row >= book.shape[0]:
                newcap = max(2 * book.shape[0], row + 1)
                book = np.concatenate([book, np.zeros((newcap - book.shape[0], k))])
                norms2 = np.concatenate([norms2, np.ones(newcap - norms2.shape[0])])
            book[row] = new_vec
            nn = float(np.sum(new_vec**2))
            norms2[row] = nn if nn > 1e-30 else 1.0
            m = row + 1
        outputs[i] = cur_node
    return FSProgram(
        n_inputs=k,
        nodes=np.asarray(nodes, dtype=np.int64).reshape(-1, 6),
        outputs=outputs,
    )


# --------------------------------------------------------------------------
# top-level entry point
# --------------------------------------------------------------------------


def _default_slice_width(n_rows: int) -> int:
    # LCC wants exponential aspect ratio: slice width ~ log2(N)  [paper Sec. III-A]
    return int(np.clip(round(np.log2(max(n_rows, 2))), 2, 16))


def resolve_target_snr_db(w: np.ndarray, target_snr_db: float | None,
                          frac_bits: int) -> float:
    """Concrete fidelity target for ``w``: the given dB figure, or (when None)
    the SNR of ``frac_bits`` fixed-point CSD quantization of the same matrix,
    so baseline and LCC models are compared at equal precision (paper Sec. IV).
    Resolving this *before* slicing keeps per-slice jobs pure functions of
    (slice matrix, knobs) — the pipeline's cache-key contract."""
    if target_snr_db is None:
        target_snr_db = quantization_snr_db(np.asarray(w, np.float64), frac_bits)
        if not np.isfinite(target_snr_db):
            target_snr_db = 6.02 * frac_bits + 10.0
    return float(target_snr_db)


def plan_col_slices(n_rows: int, n_cols: int,
                    slice_width: int | None = None) -> list[tuple[int, int]]:
    """The vertical slice grid of eq. (3): [(c0, c1), ...] covering n_cols."""
    if slice_width is None:
        slice_width = _default_slice_width(n_rows)
    slice_width = max(1, min(slice_width, n_cols))
    return [(c0, min(c0 + slice_width, n_cols))
            for c0 in range(0, n_cols, slice_width)]


def lcc_decompose_slice(
    we: np.ndarray,
    algorithm: str,
    target_snr_db: float,
    s_terms: int = 2,
    max_factors: int = 24,
    max_terms_per_row: int = 64,
    exp_range: tuple[int, int] = _EXP_RANGE,
) -> LCCChain | FSProgram:
    """Decompose ONE tall column slice (the embarrassingly-parallel unit of
    work: slices never interact until the final sum over slice outputs)."""
    we = np.asarray(we, dtype=np.float64)
    if algorithm == "fp":
        return _fp_chain(we, s_terms, target_snr_db, max_factors, exp_range)
    if algorithm == "fs":
        return _fs_program(we, target_snr_db, max_terms_per_row, exp_range)
    raise ValueError(f"unknown LCC algorithm {algorithm!r} (want 'fp' or 'fs')")


def assemble_decomposition(
    w: np.ndarray,
    col_slices: list[tuple[int, int]],
    pieces: list[LCCChain | FSProgram],
    algorithm: str,
    target_snr_db: float,
    frac_bits: int = 8,
) -> LCCDecomposition:
    """Deterministic reduction: slice pieces (in column order) -> one
    decomposition, with the meta fields ``lcc_decompose`` records."""
    w = np.asarray(w, dtype=np.float64)
    dec = LCCDecomposition(
        shape=(w.shape[0], w.shape[1]),
        col_slices=list(col_slices),
        slices=list(pieces),
        algorithm=algorithm,
        target_snr_db=float(target_snr_db),
    )
    dec.meta["csd_adds_baseline"] = adds_csd_matrix(w, frac_bits)
    dec.meta["achieved_snr_db"] = dec.achieved_snr_db(w)
    return dec


def lcc_decompose(
    w: np.ndarray,
    algorithm: str = "fp",
    s_terms: int = 2,
    target_snr_db: float | None = None,
    frac_bits: int = 8,
    slice_width: int | None = None,
    max_factors: int = 24,
    max_terms_per_row: int = 64,
    exp_range: tuple[int, int] = _EXP_RANGE,
) -> LCCDecomposition:
    """Decompose ``w`` into an LCC representation.

    If ``target_snr_db`` is None the fidelity target is matched to the SNR of
    ``frac_bits`` fixed-point CSD quantization of the same matrix, so that
    baseline and LCC models are compared at equal precision (paper Sec. IV).

    This is the serial composition of the three pipeline stages
    (:func:`plan_col_slices` -> :func:`lcc_decompose_slice` per slice ->
    :func:`assemble_decomposition`); ``repro.pipeline`` runs the same stages
    with the slice loop fanned out over worker processes, producing bitwise
    identical results.
    """
    w = np.asarray(w, dtype=np.float64)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got {w.shape}")
    n, k = w.shape
    target_snr_db = resolve_target_snr_db(w, target_snr_db, frac_bits)
    col_slices = plan_col_slices(n, k, slice_width)
    pieces = [
        lcc_decompose_slice(w[:, c0:c1], algorithm, target_snr_db,
                            s_terms=s_terms, max_factors=max_factors,
                            max_terms_per_row=max_terms_per_row,
                            exp_range=exp_range)
        for c0, c1 in col_slices
    ]
    return assemble_decomposition(w, col_slices, pieces, algorithm,
                                  target_snr_db, frac_bits)
