"""Canonical signed digit (CSD) recoding and addition accounting.

The paper's baseline cost model (Sec. IV): quantize weights to a fixed-point
grid, recode each weight in CSD (a.k.a. the non-adjacent form, NAF), and count
the additions needed to evaluate ``W @ x`` as shift-and-add hardware would:

    adds(row i) = (sum_j nnz_digits(w_ij)) - 1        (0 for all-zero rows)

Multiplication by a signed power of two is free (a bit-shift on an FPGA; an
exact float scale on TPU).

Everything here is plain numpy -- this is offline tooling, not a hot path.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "quantize_fixed",
    "csd_digit_count",
    "csd_digits",
    "adds_csd_matrix",
    "adds_csd_rowwise",
    "quantization_snr_db",
]


def quantize_fixed(w: np.ndarray, frac_bits: int = 8, word_bits: int | None = None) -> np.ndarray:
    """Round to the fixed-point grid 2^-frac_bits (optionally saturating)."""
    w = np.asarray(w, dtype=np.float64)
    scale = float(2**frac_bits)
    q = np.round(w * scale)
    if word_bits is not None:
        lim = float(2 ** (word_bits - 1) - 1)
        q = np.clip(q, -lim, lim)
    return q / scale


def _naf_nonzero_count(n: np.ndarray) -> np.ndarray:
    """Vectorized count of nonzero digits in the NAF of integer array ``n``.

    NAF is the canonical signed-digit form: digits in {-1, 0, +1}, no two
    adjacent nonzeros, provably minimal number of nonzero digits.
    """
    n = n.astype(np.int64).copy()
    count = np.zeros(n.shape, dtype=np.int64)
    # int64 NAF needs at most ~65 iterations; loop while anything is nonzero.
    while np.any(n != 0):
        odd = (n & 1) != 0
        r = (n & 3).astype(np.int64)  # n mod 4 (two's complement safe)
        z = np.where(odd, 2 - r, 0)
        count += (z != 0).astype(np.int64)
        n = (n - z) >> 1
    return count


def csd_digit_count(w: np.ndarray, frac_bits: int = 8) -> np.ndarray:
    """Number of nonzero CSD digits of each (quantized) entry of ``w``."""
    w = np.asarray(w, dtype=np.float64)
    n = np.round(w * (2.0**frac_bits)).astype(np.int64)
    return _naf_nonzero_count(n)


def csd_digits(value: float, frac_bits: int = 8) -> list[tuple[int, int]]:
    """CSD digits of a scalar as ``[(exponent, sign), ...]`` (sign in {-1,+1}).

    ``value ~= sum_i sign_i * 2**exponent_i`` exactly on the quantized grid.
    """
    n = int(round(float(value) * (2**frac_bits)))
    digits: list[tuple[int, int]] = []
    pos = -frac_bits
    while n != 0:
        if n & 1:
            r = n & 3
            z = 2 - r  # +1 or -1
            digits.append((pos, int(z)))
            n -= z
        n >>= 1
        pos += 1
    return digits


def adds_csd_rowwise(w: np.ndarray, frac_bits: int = 8) -> np.ndarray:
    """Additions per output row for ``W @ x`` in CSD shift-add form."""
    w = np.asarray(w, dtype=np.float64)
    if w.ndim != 2:
        raise ValueError(f"expected 2-D matrix, got shape {w.shape}")
    digits = csd_digit_count(w, frac_bits)
    row_tot = digits.sum(axis=1)
    return np.maximum(row_tot - 1, 0)


def adds_csd_matrix(w: np.ndarray, frac_bits: int = 8) -> int:
    """Total additions to evaluate ``W @ x`` with CSD-recoded weights."""
    return int(adds_csd_rowwise(w, frac_bits).sum())


def quantization_snr_db(w: np.ndarray, frac_bits: int = 8, word_bits: int | None = None) -> float:
    """SNR (dB) of the fixed-point quantization of ``w``.

    Used as the fidelity target for LCC so baseline and compressed model are
    compared at matched precision (paper Sec. IV).
    """
    w = np.asarray(w, dtype=np.float64)
    q = quantize_fixed(w, frac_bits, word_bits)
    err = float(np.sum((w - q) ** 2))
    sig = float(np.sum(w**2))
    if err == 0.0:
        return np.inf
    if sig == 0.0:
        return 0.0
    return 10.0 * np.log10(sig / err)
