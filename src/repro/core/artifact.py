"""Serializable compressed-model artifact (offline compress once, serve many).

A :class:`CompressedModel` bundles everything the serving engine needs to run
a model compressed by Algorithm 1:

* ``records`` — per-unit :class:`CompressedDense` / conv records (prune
  indices, weight-sharing labels+centroids, the LCC decomposition itself);
* ``packed`` — the fused-kernel buffers (``kernels.ops.PackedDecomposition``)
  ready for ``lcc_chain_matmul`` launches;
* ``params`` — dense-effective weights, a drop-in pytree for the stock XLA
  forward (the non-kernel fallback and everything not compressed);
* ``report`` — the :class:`ModelCostReport` adds/bytes accounting;
* the :class:`CompressionConfig` and the model config that produced it.

Persistence goes through the existing msgpack+crc32 ``Checkpointer``: the
artifact is one array pytree plus a JSON manifest (itself stored as a uint8
leaf), published atomically under ``<dir>/step_<N>/``.  ``load`` walks steps
newest-first and skips corrupted shards with a warning, exactly like training
restore.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from .compress import CompressedDense, CompressionConfig
from .cost import LayerCost, ModelCostReport
from .lcc import FSProgram, LCCChain, LCCDecomposition, LCCFactor
from .weight_sharing import SharedLayer

__all__ = ["CompressedModel"]

_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# decomposition <-> (meta, arrays)
# ---------------------------------------------------------------------------


def _dec_to_tree(dec: LCCDecomposition) -> tuple[dict, dict]:
    meta = {
        "shape": list(dec.shape),
        "col_slices": [list(cs) for cs in dec.col_slices],
        "algorithm": dec.algorithm,
        "target_snr_db": dec.target_snr_db,
        "meta": {k: v for k, v in dec.meta.items()
                 if isinstance(v, (int, float, str, bool, type(None)))},
        "slices": [],
    }
    arrays: dict[str, Any] = {}
    for i, s in enumerate(dec.slices):
        key = f"s{i:03d}"
        if isinstance(s, LCCChain):
            meta["slices"].append({"kind": "fp", "in_dim": s.in_dim,
                                   "factor_in_dims": [f.in_dim for f in s.factors]})
            arrays[key] = {f"f{j:02d}": {"idx": f.idx, "exp": f.exp, "sign": f.sign}
                           for j, f in enumerate(s.factors)}
        else:
            meta["slices"].append({"kind": "fs", "n_inputs": s.n_inputs})
            arrays[key] = {"nodes": np.asarray(s.nodes, np.int64).reshape(-1, 6),
                           "outputs": np.asarray(s.outputs, np.int64)}
    return meta, arrays


def _dec_from_tree(meta: dict, arrays: dict) -> LCCDecomposition:
    slices: list[LCCChain | FSProgram] = []
    for i, sm in enumerate(meta["slices"]):
        tree = arrays.get(f"s{i:03d}", {})
        if sm["kind"] == "fp":
            factors = [LCCFactor(idx=np.asarray(tree[k]["idx"], np.int32),
                                 exp=np.asarray(tree[k]["exp"], np.int8),
                                 sign=np.asarray(tree[k]["sign"], np.int8),
                                 in_dim=int(sm["factor_in_dims"][j]))
                       for j, k in enumerate(sorted(tree))]
            slices.append(LCCChain(factors=factors, in_dim=int(sm["in_dim"])))
        else:
            slices.append(FSProgram(n_inputs=int(sm["n_inputs"]),
                                    nodes=np.asarray(tree["nodes"], np.int64).reshape(-1, 6),
                                    outputs=np.asarray(tree["outputs"], np.int64)))
    dec = LCCDecomposition(
        shape=tuple(meta["shape"]),
        col_slices=[tuple(cs) for cs in meta["col_slices"]],
        slices=slices,
        algorithm=meta["algorithm"],
        target_snr_db=float(meta["target_snr_db"]),
    )
    dec.meta.update(meta.get("meta", {}))
    return dec


# ---------------------------------------------------------------------------
# flat-name pytree reconstruction ("blocks/0/conv1" -> list index 0)
# ---------------------------------------------------------------------------


def _unflatten(flat: dict[str, Any]):
    root: dict = {}
    for name, leaf in flat.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf

    def listify(node):
        if not isinstance(node, dict):
            return node
        out = {k: listify(v) for k, v in node.items()}
        if out and all(k.isdigit() for k in out):
            return [out[k] for k in sorted(out, key=int)]
        return out

    return listify(root)


def _report_to_json(report: ModelCostReport) -> list[dict]:
    return [{"name": l.name, "baseline_adds": l.baseline_adds,
             "stage_adds": l.stage_adds, "stage_bytes": l.stage_bytes,
             "extra": {k: v for k, v in l.extra.items()
                       if isinstance(v, (int, float, str, bool, type(None)))}}
            for l in report.layers]


def _report_from_json(rows: list[dict]) -> ModelCostReport:
    rep = ModelCostReport()
    for r in rows:
        lc = LayerCost(name=r["name"], baseline_adds=int(r["baseline_adds"]))
        lc.stage_adds.update({k: int(v) for k, v in r["stage_adds"].items()})
        lc.stage_bytes.update({k: int(v) for k, v in r["stage_bytes"].items()})
        lc.extra.update(r["extra"])
        rep.add(lc)
    return rep


def _config_to_manifest(cfg) -> tuple[str, dict]:
    from repro.configs.base import ArchConfig, arch_to_dict

    if isinstance(cfg, ArchConfig):
        return "arch", arch_to_dict(cfg)
    return type(cfg).__name__, asdict(cfg)


def _config_from_manifest(kind: str, d: dict):
    from repro.configs.base import arch_from_dict

    if kind == "arch":
        return arch_from_dict(d)
    if kind == "ResNetConfig":
        from repro.models.resnet import ResNetConfig

        d = dict(d)
        d["stages"] = tuple(d["stages"])
        d["widths"] = tuple(d["widths"])
        return ResNetConfig(**d)
    if kind == "MLPConfig":
        from repro.models.mlp import MLPConfig

        return MLPConfig(**d)
    raise ValueError(f"unknown config kind {kind!r} in artifact manifest")


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------


@dataclass
class CompressedModel:
    config: Any  # ArchConfig | ResNetConfig | MLPConfig
    params: Any  # dense-effective pytree
    records: dict[str, Any]  # unit name -> CompressedDense | conv dict
    packed: dict[str, Any] = field(default_factory=dict)  # name -> PackedDecomposition
    report: ModelCostReport = field(default_factory=ModelCostReport)
    compression: CompressionConfig = field(default_factory=CompressionConfig)
    # per-unit plans (adds-budget allocator output); empty => ``compression``
    # applied globally.  ``unit_config_for`` is the read surface.
    unit_configs: dict[str, CompressionConfig] = field(default_factory=dict)
    pipeline_stats: dict = field(default_factory=dict)  # workers/cache/wall
    # layer plans: plan key ("step", "moe:l3") -> {stage name -> PackedStage}.
    # Built lazily by the executor on first decode and persisted so reloads
    # skip the packing pass entirely.
    plans: dict[str, dict] = field(default_factory=dict)

    def unit_config_for(self, name: str) -> CompressionConfig:
        return self.unit_configs.get(name, self.compression)

    @property
    def family(self) -> str:
        from repro.models import api

        return api.family_of(self.config)

    def dense_unit_names(self) -> list[str]:
        return [n for n, r in self.records.items()
                if isinstance(r, CompressedDense)]

    # ------------------------------------------------------------------ save
    def save(self, directory: str, step: int = 0) -> None:
        from repro.checkpoint.checkpointer import Checkpointer

        units_tree: dict[str, Any] = {}
        conv_tree: dict[str, Any] = {}
        packed_tree: dict[str, Any] = {}
        man_units: dict[str, Any] = {}
        for name, rec in self.records.items():
            if isinstance(rec, CompressedDense):
                dm, da = _dec_to_tree(rec.decomposition)
                t = {"kept": np.asarray(rec.kept_columns, np.int64),
                     "effective": np.asarray(rec.effective, np.float64),
                     "dec": da}
                if rec.shared is not None:
                    t["labels"] = np.asarray(rec.shared.labels)
                    t["centroids"] = np.asarray(rec.shared.centroids, np.float64)
                units_tree[name] = t
                man_units[name] = {"type": "dense", "dec": dm,
                                   "has_shared": rec.shared is not None}
            else:
                chans = {}
                decs_meta = {}
                for ch, dec in rec["decompositions"].items():
                    dm, da = _dec_to_tree(dec)
                    chans[f"ch{ch:04d}"] = da
                    decs_meta[str(ch)] = dm
                conv_tree[name] = chans
                man_units[name] = {
                    "type": "conv", "decs": decs_meta,
                    "channels_nonzero": [int(c) for c in rec["channels_nonzero"]],
                    "baseline_adds": int(rec["baseline_adds"]),
                    "lcc_adds": int(rec["lcc_adds"]),
                    "scale": float(rec["scale"]),
                }
        man_packed: dict[str, Any] = {}
        for name, pk in self.packed.items():
            packed_tree[name] = {
                "idx": np.asarray(pk.idx), "exp": np.asarray(pk.exp),
                "sign": np.asarray(pk.sign),
                "dense": {f"d{i:02d}": np.asarray(w)
                          for i, ((_, _), w) in enumerate(pk.dense)},
            }
            man_packed[name] = {
                "col_slices": [list(cs) for cs in pk.col_slices],
                "dense_slices": [list(cs) for cs, _ in pk.dense],
                "in_dim": pk.in_dim, "out_dim": pk.out_dim, "d_pad": pk.d_pad,
                "first_width": pk.first_width,
                "chain_lengths": list(pk.chain_lengths),
            }
        # layer plans: arrays per (plan key, stage name), presence + static
        # ints in the manifest.  Optional manifest key — format version stays
        # 1 and pre-plan artifacts load unchanged.
        plans_tree: dict[str, Any] = {}
        man_plans: dict[str, Any] = {}
        # "segs" (segment-packed layout) is optional: PR 8-era artifacts
        # without it load with segs=None and take the operand kernel path
        _STAGE_ARRAYS = ("prep_src", "prep_tgt", "gidx", "gexp", "gsgn",
                         "outg", "fs_mat", "dw_mat", "bias", "segs")
        for pkey, stages in self.plans.items():
            plans_tree[pkey] = {}
            man_plans[pkey] = {}
            for sname, ps in stages.items():
                arrs = {f: np.asarray(getattr(ps, f)) for f in _STAGE_ARRAYS
                        if getattr(ps, f) is not None}
                plans_tree[pkey][sname] = arrs
                man_plans[pkey][sname] = {
                    "k_alloc": ps.k_alloc, "d_src": ps.d_src,
                    "out_dim": ps.out_dim, "n_layers": ps.n_layers,
                    "site_names": list(ps.site_names),
                    "present": sorted(arrs),
                }
        kind, cfg_dict = _config_to_manifest(self.config)
        manifest = {
            "version": _FORMAT_VERSION,
            "kind": kind,
            "config": cfg_dict,
            "compression": asdict(self.compression),
            "unit_configs": {n: asdict(c) for n, c in self.unit_configs.items()},
            "pipeline_stats": self.pipeline_stats,
            "report": _report_to_json(self.report),
            "units": man_units,
            "packed": man_packed,
        }
        if man_plans:
            manifest["plans"] = man_plans
        tree = {"manifest": np.frombuffer(
                    json.dumps(manifest).encode(), np.uint8).copy(),
                "params": self.params}
        if units_tree:
            tree["units"] = units_tree
        if conv_tree:
            tree["conv"] = conv_tree
        if packed_tree:
            tree["packed"] = packed_tree
        if plans_tree:
            tree["plans"] = plans_tree
        Checkpointer(directory).save(step, tree, blocking=True)

    # ------------------------------------------------------------------ load
    @classmethod
    def load(cls, directory: str) -> "CompressedModel":
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(directory)
        steps = ckpt.all_steps()
        for step in reversed(steps):
            try:
                flat = ckpt.restore_flat(step)
            except Exception as e:  # corrupted shard: fall back to older step
                print(f"[artifact] step {step} unreadable ({e}); trying older")
                continue
            return cls._from_flat(flat)
        raise FileNotFoundError(
            f"no intact compressed-model artifact under {directory!r}")

    @classmethod
    def _from_flat(cls, flat: dict[str, Any]) -> "CompressedModel":
        from repro.kernels.ops import PackedDecomposition

        tree = _unflatten(flat)
        manifest = json.loads(np.asarray(tree.pop("manifest"),
                                         np.uint8).tobytes().decode())
        if manifest["version"] != _FORMAT_VERSION:
            raise ValueError(f"artifact format v{manifest['version']} "
                             f"!= supported v{_FORMAT_VERSION}")
        config = _config_from_manifest(manifest["kind"], manifest["config"])
        records: dict[str, Any] = {}
        for name, um in manifest["units"].items():
            if um["type"] == "dense":
                t = tree["units"][name]
                shared = None
                if um["has_shared"]:
                    shared = SharedLayer(centroids=np.asarray(t["centroids"]),
                                         labels=np.asarray(t["labels"]))
                records[name] = CompressedDense(
                    name=name,
                    kept_columns=np.asarray(t["kept"], np.int64),
                    shared=shared,
                    decomposition=_dec_from_tree(um["dec"], t.get("dec", {})),
                    effective=np.asarray(t["effective"], np.float64),
                )
            else:
                chans = tree.get("conv", {}).get(name, {})
                decs = {int(ch): _dec_from_tree(dm, chans.get(f"ch{int(ch):04d}", {}))
                        for ch, dm in um["decs"].items()}
                records[name] = {
                    "decompositions": decs,
                    "channels_nonzero": list(um["channels_nonzero"]),
                    "baseline_adds": um["baseline_adds"],
                    "lcc_adds": um["lcc_adds"],
                    "scale": um["scale"],
                }
        packed: dict[str, Any] = {}
        for name, pm in manifest.get("packed", {}).items():
            t = tree.get("packed", {}).get(name, {})
            dense_arrs = t.get("dense", {})
            dense = tuple(
                (tuple(cs), jnp.asarray(dense_arrs[f"d{i:02d}"], jnp.float32))
                for i, cs in enumerate(pm["dense_slices"]))
            packed[name] = PackedDecomposition(
                idx=jnp.asarray(t["idx"], jnp.int32),
                exp=jnp.asarray(t["exp"], jnp.int8),
                sign=jnp.asarray(t["sign"], jnp.int8),
                col_slices=tuple(tuple(cs) for cs in pm["col_slices"]),
                dense=dense,
                in_dim=int(pm["in_dim"]), out_dim=int(pm["out_dim"]),
                d_pad=int(pm["d_pad"]), first_width=int(pm["first_width"]),
                chain_lengths=tuple(pm["chain_lengths"]),
            )
        plans: dict[str, dict] = {}
        for pkey, pstages in manifest.get("plans", {}).items():
            from repro.kernels.ops import PackedStage

            stages = {}
            for sname, sm in pstages.items():
                arrs = tree.get("plans", {}).get(pkey, {}).get(sname, {})
                kw = {f: (np.asarray(arrs[f]) if f in sm["present"] else None)
                      for f in ("prep_src", "prep_tgt", "gidx", "gexp",
                                "gsgn", "outg", "fs_mat", "dw_mat", "bias",
                                "segs")}
                stages[sname] = PackedStage(
                    k_alloc=int(sm["k_alloc"]), d_src=int(sm["d_src"]),
                    out_dim=int(sm["out_dim"]), n_layers=int(sm["n_layers"]),
                    site_names=tuple(sm["site_names"]), **kw)
            plans[pkey] = stages
        comp = CompressionConfig(**manifest["compression"])
        unit_configs = {n: CompressionConfig(**d)
                        for n, d in manifest.get("unit_configs", {}).items()}
        return cls(config=config, params=tree["params"], records=records,
                   packed=packed, report=_report_from_json(manifest["report"]),
                   compression=comp, unit_configs=unit_configs,
                   pipeline_stats=manifest.get("pipeline_stats", {}),
                   plans=plans)
