"""Weight sharing via affinity-propagation column clustering (paper Sec. III-C).

Pipeline (method of Zhang et al. [29], as adopted by the paper):
 1. cluster the *columns* of a trained weight matrix with affinity propagation
    (implemented from scratch -- no scikit-learn in this environment; same
    message-passing updates as Frey & Dueck 2007);
 2. retrain with tied parameters: the centroid gradient is the *mean* of its
    members' gradients (eq. (9));
 3. evaluate with eq. (10):  W x = sum_i g_i * (sum_{j in I_i} x_j)
    -- a per-cluster input pre-aggregation (scalar adds only) followed by a
    small dense matrix of unique centroids.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "affinity_propagation",
    "cluster_columns",
    "cluster_columns_fixed",
    "SharedLayer",
    "shared_matvec",
    "centroid_grad_from_member_grads",
    "expand_centroids",
]


def affinity_propagation(
    similarity: np.ndarray,
    damping: float = 0.7,
    max_iter: int = 300,
    convergence_iter: int = 20,
    preference: float | np.ndarray | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Affinity propagation (Frey & Dueck, Science 2007). Returns labels [n].

    ``similarity[i,k]``: suitability of k as exemplar for i. ``preference``
    (diagonal) controls cluster count; defaults to the median similarity, the
    standard choice (also sklearn's default).
    """
    s = np.array(similarity, dtype=np.float64, copy=True)
    n = s.shape[0]
    if n == 1:
        return np.zeros(1, dtype=np.int64)
    if preference is None:
        preference = np.median(s[~np.eye(n, dtype=bool)])
    s[np.diag_indices(n)] = preference
    # tiny noise breaks degenerate ties (as in the reference implementation)
    rng = np.random.default_rng(seed)
    s += 1e-12 * rng.standard_normal((n, n)) * (np.max(s) - np.min(s) + 1e-30)

    r = np.zeros((n, n))
    a = np.zeros((n, n))
    idx = np.arange(n)
    stable = 0
    last_ex: np.ndarray | None = None
    for _ in range(max_iter):
        # responsibilities
        as_ = a + s
        first = np.max(as_, axis=1)
        jmax = np.argmax(as_, axis=1)
        as_[idx, jmax] = -np.inf
        second = np.max(as_, axis=1)
        rnew = s - first[:, None]
        rnew[idx, jmax] = s[idx, jmax] - second
        r = damping * r + (1 - damping) * rnew
        # availabilities
        rp = np.maximum(r, 0.0)
        rp[np.diag_indices(n)] = r[np.diag_indices(n)]
        col = rp.sum(axis=0)
        anew = col[None, :] - rp
        dA = np.diag(anew).copy()
        anew = np.minimum(anew, 0.0)
        anew[np.diag_indices(n)] = dA
        a = damping * a + (1 - damping) * anew
        # convergence: exemplar set unchanged for ``convergence_iter`` rounds
        ex = np.where(np.diag(a + r) > 0)[0]
        if last_ex is not None and ex.size == last_ex.size and np.array_equal(ex, last_ex):
            stable += 1
            if stable >= convergence_iter and ex.size > 0:
                break
        else:
            stable = 0
        last_ex = ex

    exemplars = np.where(np.diag(a + r) > 0)[0]
    if exemplars.size == 0:
        exemplars = np.array([int(np.argmax(np.diag(a + r)))])
    # assign each point to its best exemplar; exemplars point to themselves
    labels_ex = np.argmax(s[:, exemplars], axis=1)
    labels_ex[exemplars] = np.arange(exemplars.size)
    return labels_ex.astype(np.int64)


def cluster_columns(
    w: np.ndarray,
    damping: float = 0.7,
    max_iter: int = 300,
    preference: float | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster the columns of ``w`` -> (labels [K], centroids [N, C]).

    Similarity = negative squared euclidean distance between columns
    (the standard affinity for AP). Centroids are cluster means.
    """
    w = np.asarray(w, dtype=np.float64)
    cols = w.T  # [K, N]
    d2 = np.sum(cols**2, axis=1, keepdims=True)
    sim = -(d2 + d2.T - 2.0 * cols @ cols.T)
    labels = affinity_propagation(sim, damping=damping, max_iter=max_iter, preference=preference)
    c = int(labels.max()) + 1
    centroids = np.zeros((w.shape[0], c))
    for i in range(c):
        centroids[:, i] = w[:, labels == i].mean(axis=1)
    return labels, centroids


def cluster_columns_fixed(
    w: np.ndarray,
    n_clusters: int,
    n_iter: int = 5,
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster columns into (at most) an *exact requested count* of clusters.

    Affinity propagation picks its own cluster count; the adds-budget
    allocator needs the count as a continuous dial between "a handful of
    centroids" and "no sharing at all" (``n_clusters >= K``).  Deterministic:
    farthest-point (k-center) seeding from the max-norm column + a few Lloyd
    refinements, no RNG — so pipeline re-runs and resumed runs are bitwise
    reproducible.  Returns (labels [K], centroids [N, C]); C can come out
    below ``n_clusters`` when columns coincide or clusters empty out.
    """
    w = np.asarray(w, dtype=np.float64)
    cols = w.T  # [K, N]
    k = cols.shape[0]
    c = max(1, min(int(n_clusters), k))
    chosen = [int(np.argmax(np.sum(cols**2, axis=1)))]
    d2 = np.sum((cols - cols[chosen[0]]) ** 2, axis=1)
    while len(chosen) < c:
        j = int(np.argmax(d2))
        if d2[j] <= 0.0:
            break  # duplicate columns: fewer distinct centers exist
        chosen.append(j)
        d2 = np.minimum(d2, np.sum((cols - cols[j]) ** 2, axis=1))
    cents = cols[chosen].copy()  # [C, N]

    def assign(cents):
        # ||a-b||^2 via the matmul identity: [K, C] memory, never [K, C, N]
        # (C can be ~K when the allocator dials toward the unshared end)
        d = (np.sum(cols**2, axis=1)[:, None]
             + np.sum(cents**2, axis=1)[None, :] - 2.0 * cols @ cents.T)
        return np.argmin(d, axis=1)

    for _ in range(n_iter):
        labels = assign(cents)
        for i in range(cents.shape[0]):
            m = labels == i
            if m.any():
                cents[i] = cols[m].mean(axis=0)
    labels = assign(cents)
    used = np.unique(labels)  # drop empty clusters, relabel compactly
    remap = np.zeros(cents.shape[0], dtype=np.int64)
    remap[used] = np.arange(used.size)
    return remap[labels].astype(np.int64), cents[used].T.copy()


@dataclass
class SharedLayer:
    """Weight-shared layer: W == centroids[:, labels] (eq. (10) evaluation)."""

    centroids: np.ndarray  # [N, C]
    labels: np.ndarray  # [K] int, cluster id per input column

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[1]

    def expand(self) -> np.ndarray:
        return self.centroids[:, self.labels]

    def pre_aggregation_adds(self) -> int:
        """Scalar adds for the per-cluster input sums: sum_i (|I_i| - 1)."""
        counts = np.bincount(self.labels, minlength=self.n_clusters)
        return int(np.maximum(counts - 1, 0).sum())


def shared_matvec(centroids: jnp.ndarray, labels: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Eq. (10):  y = G @ segment_sum(x, labels).  x: [..., K] -> [..., N]."""
    c = centroids.shape[1]
    x_agg = jax.ops.segment_sum(
        jnp.moveaxis(x, -1, 0), labels, num_segments=c
    )  # [C, ...]
    y = jnp.tensordot(centroids, x_agg, axes=([1], [0]))  # [N, ...]
    return jnp.moveaxis(y, 0, -1)


def expand_centroids(centroids: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """W = G[:, labels] — used to keep autodiff flowing through tied params."""
    return jnp.take(centroids, labels, axis=1)


def centroid_grad_from_member_grads(w_grad: np.ndarray | jnp.ndarray, labels, n_clusters: int):
    """Eq. (9): dL/dg_i = (1/|C_i|) * sum_{w in C_i} dL/dw  (columns of W)."""
    g = jnp.asarray(w_grad)
    summed = jax.ops.segment_sum(jnp.moveaxis(g, -1, 0), jnp.asarray(labels), num_segments=n_clusters)
    counts = jax.ops.segment_sum(
        jnp.ones((g.shape[-1],), g.dtype), jnp.asarray(labels), num_segments=n_clusters
    )
    out = summed / jnp.maximum(counts, 1.0)[(...,) + (None,) * (summed.ndim - 1)]
    return jnp.moveaxis(out, 0, -1)
