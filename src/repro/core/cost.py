"""Addition accounting and compression ratios (paper Sec. IV).

Compression ratio = adds(uncompressed model, CSD) / adds(compressed model).
Only matrix-vector-product additions are counted (activations etc. excluded),
exactly as in the paper.  For the TPU adaptation we additionally track weight
*bytes* moved per matvec (the quantity that bounds memory-bound decode).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .csd import adds_csd_matrix
from .lcc import LCCDecomposition
from .weight_sharing import SharedLayer

__all__ = ["LayerCost", "ModelCostReport", "dense_layer_adds", "pruned_layer_adds",
           "shared_layer_adds", "lcc_layer_adds", "dense_bytes"]


def dense_layer_adds(w: np.ndarray, frac_bits: int = 8) -> int:
    """CSD shift-add cost of the uncompressed (but quantized) matrix."""
    return adds_csd_matrix(w, frac_bits)


def pruned_layer_adds(w: np.ndarray, frac_bits: int = 8) -> int:
    """After structured pruning: zero rows/cols simply drop out of the CSD count."""
    return adds_csd_matrix(w, frac_bits)


def shared_layer_adds(layer: SharedLayer, frac_bits: int = 8) -> int:
    """Eq. (10): input pre-aggregation adds + CSD adds of the centroid matrix."""
    return layer.pre_aggregation_adds() + adds_csd_matrix(layer.centroids, frac_bits)


def lcc_layer_adds(dec: LCCDecomposition, pre_aggregation: int = 0) -> int:
    return pre_aggregation + dec.num_adds()


def dense_bytes(w: np.ndarray, bytes_per_weight: float = 2.0) -> int:
    """HBM bytes to stream the dense weights once (bf16 by default)."""
    return int(w.shape[0] * w.shape[1] * bytes_per_weight)


@dataclass
class LayerCost:
    name: str
    baseline_adds: int
    stage_adds: dict[str, int] = field(default_factory=dict)  # stage -> adds
    stage_bytes: dict[str, int] = field(default_factory=dict)
    extra: dict = field(default_factory=dict)

    def ratio(self, stage: str) -> float:
        a = self.stage_adds.get(stage, 0)
        return self.baseline_adds / a if a > 0 else float("inf")


@dataclass
class ModelCostReport:
    layers: list[LayerCost] = field(default_factory=list)

    def add(self, layer: LayerCost) -> None:
        self.layers.append(layer)

    def total_baseline(self) -> int:
        return sum(l.baseline_adds for l in self.layers)

    def total_stage(self, stage: str) -> int:
        return sum(l.stage_adds.get(stage, l.baseline_adds) for l in self.layers)

    def ratio(self, stage: str) -> float:
        s = self.total_stage(stage)
        return self.total_baseline() / s if s > 0 else float("inf")

    def table(self) -> str:
        stages: list[str] = []
        for l in self.layers:
            for s in l.stage_adds:
                if s not in stages:
                    stages.append(s)
        hdr = "layer,baseline_adds," + ",".join(f"{s}_adds,{s}_ratio" for s in stages)
        rows = [hdr]
        for l in self.layers:
            cells = [l.name, str(l.baseline_adds)]
            for s in stages:
                a = l.stage_adds.get(s)
                cells += [str(a) if a is not None else "",
                          f"{l.ratio(s):.2f}" if a else ""]
            rows.append(",".join(cells))
        tot = ["TOTAL", str(self.total_baseline())]
        for s in stages:
            tot += [str(self.total_stage(s)), f"{self.ratio(s):.2f}"]
        rows.append(",".join(tot))
        return "\n".join(rows)
