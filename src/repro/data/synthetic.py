"""Synthetic-but-learnable data (no internet / no MNIST in this container).

* ``MarkovLM``: token streams from a sparse random Markov chain — has real
  structure (per-token optimal loss == chain entropy), so LM training curves
  are meaningful and a trained model measurably beats the uniform baseline.
* ``digits_like``: procedural 7-segment-style digit images with jitter + noise
  (28x28, 10 classes) — the MNIST stand-in for the paper's MLP experiment.
* ``textures_like``: class-conditional oriented textures (CIFAR/TinyImageNet
  stand-in for the ResNet experiment).

All generators are deterministic in (seed, index) so input pipelines are
restart-reproducible (fault-tolerance requirement: a resumed job re-reads the
same batch sequence).
"""
from __future__ import annotations

import numpy as np

__all__ = ["MarkovLM", "digits_like", "textures_like", "batches"]


class MarkovLM:
    """Sparse random Markov chain over ``vocab`` tokens; branching ``k``."""

    def __init__(self, vocab: int = 512, k: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.succ = rng.integers(0, vocab, size=(vocab, k))
        logits = rng.standard_normal((vocab, k))
        p = np.exp(logits)
        self.p = p / p.sum(1, keepdims=True)
        self.entropy = float(-(self.p * np.log(self.p)).sum(1).mean())

    def sample(self, batch: int, seq_len: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng((seed, 7919))
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        for t in range(seq_len):
            cur = toks[:, t]
            choice = (rng.random(batch)[:, None] < np.cumsum(self.p[cur], 1)).argmax(1)
            toks[:, t + 1] = self.succ[cur, choice]
        return toks

    def batch(self, batch: int, seq_len: int, seed: int) -> dict:
        toks = self.sample(batch, seq_len, seed)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


_SEGS = {  # 7-segment truth table per digit
    0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
    5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abfgcd",
}
_SEG_COORDS = {  # (row range, col range) on a 28x28 canvas
    "a": ((3, 6), (7, 21)), "b": ((6, 14), (18, 21)), "c": ((14, 23), (18, 21)),
    "d": ((22, 25), (7, 21)), "e": ((14, 23), (7, 10)), "f": ((6, 14), (7, 10)),
    "g": ((12, 15), (7, 21)),
}


def digits_like(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(x [n, 784] float32 in [0,1], y [n] int32) — procedural digits."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, n).astype(np.int32)
    x = np.zeros((n, 28, 28), np.float32)
    for i in range(n):
        img = np.zeros((28, 28), np.float32)
        dr, dc = rng.integers(-2, 3), rng.integers(-2, 3)
        for s in _SEGS[int(y[i])]:
            (r0, r1), (c0, c1) = _SEG_COORDS[s]
            img[max(r0 + dr, 0):min(r1 + dr, 28), max(c0 + dc, 0):min(c1 + dc, 28)] = 1.0
        img *= rng.uniform(0.7, 1.0)
        img += rng.normal(0, 0.15, (28, 28))
        x[i] = np.clip(img, 0, 1)
    return x.reshape(n, 784), y


def textures_like(n: int, size: int = 32, classes: int = 10,
                  seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(x [n, 3, size, size], y [n]) — class = oriented sinusoid grating + hue."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, n).astype(np.int32)
    r = np.arange(size)
    xx, yy = np.meshgrid(r, r)
    x = np.empty((n, 3, size, size), np.float32)
    for i in range(n):
        c = int(y[i])
        ang = np.pi * c / classes
        freq = 0.3 + 0.15 * (c % 3)
        phase = rng.uniform(0, 2 * np.pi)
        g = np.sin(freq * (np.cos(ang) * xx + np.sin(ang) * yy) + phase)
        hue = np.array([np.sin(c), np.cos(c), np.sin(2 * c)])[:, None, None]
        img = 0.5 + 0.35 * g[None] * (0.5 + 0.5 * hue)
        img += rng.normal(0, 0.1, (3, size, size))
        x[i] = np.clip(img, 0, 1)
    return x, y


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
    """Deterministic epoch shuffler."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    for i in range(0, len(x) - batch_size + 1, batch_size):
        j = idx[i:i + batch_size]
        yield x[j], y[j]
