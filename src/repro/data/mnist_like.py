"""MNIST-scale stroke digits (no internet in this container, so no real
MNIST download; this is the closest procedural stand-in).

Unlike ``synthetic.digits_like`` (rigid 7-segment glyphs), these digits are
rendered from per-class *stroke skeletons* — polylines and elliptical arcs in
a unit box — passed through a random affine (rotation, anisotropic scale,
shear, translation) and drawn with a soft Gaussian brush plus pixel noise.
The result has the properties the paper's MLP experiment needs from MNIST:
28x28 grayscale, 10 classes, large intra-class variation with smooth strokes,
and enough difficulty that regularization/pruning measurably moves accuracy.

Deterministic in ``seed`` (restart-reproducible input pipelines).
"""
from __future__ import annotations

import numpy as np

__all__ = ["mnist_like", "train_test"]


def _line(p0, p1, n=18):
    t = np.linspace(0.0, 1.0, n)[:, None]
    return (1 - t) * np.asarray(p0, float) + t * np.asarray(p1, float)


def _arc(c, rx, ry, a0_deg, a1_deg, n=26):
    a = np.radians(np.linspace(a0_deg, a1_deg, n))
    cx, cy = c
    return np.stack([cx + rx * np.cos(a), cy + ry * np.sin(a)], axis=1)


# stroke skeletons per digit, (x, y) in a unit box with y pointing DOWN
_STROKES = {
    0: [_arc((0.5, 0.5), 0.27, 0.37, 0, 360, 48)],
    1: [_line((0.36, 0.30), (0.54, 0.13)), _line((0.54, 0.13), (0.54, 0.87)),
        _line((0.38, 0.87), (0.68, 0.87), 10)],
    2: [_arc((0.5, 0.32), 0.24, 0.19, 180, 355, 30),
        _line((0.72, 0.38), (0.27, 0.84)),
        _line((0.27, 0.84), (0.76, 0.84), 14)],
    3: [_arc((0.47, 0.31), 0.22, 0.17, 160, 380, 26),
        _arc((0.47, 0.66), 0.25, 0.21, -70, 170, 28)],
    4: [_line((0.66, 0.12), (0.24, 0.60)), _line((0.24, 0.60), (0.80, 0.60)),
        _line((0.66, 0.34), (0.66, 0.88))],
    5: [_line((0.72, 0.14), (0.32, 0.14), 12), _line((0.32, 0.14), (0.30, 0.45)),
        _arc((0.47, 0.64), 0.25, 0.22, -100, 130, 30)],
    6: [_arc((0.62, 0.25), 0.45, 0.55, 115, 180, 20),
        _arc((0.48, 0.66), 0.22, 0.21, 0, 360, 36)],
    7: [_line((0.24, 0.15), (0.76, 0.15), 14), _line((0.76, 0.15), (0.40, 0.88)),
        _line((0.38, 0.52), (0.64, 0.52), 8)],
    8: [_arc((0.5, 0.31), 0.19, 0.17, 0, 360, 30),
        _arc((0.5, 0.68), 0.23, 0.20, 0, 360, 34)],
    9: [_arc((0.5, 0.34), 0.21, 0.20, 0, 360, 32),
        _arc((0.40, 0.55), 0.32, 0.36, -25, 65, 18)],
}


def _render(points: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Affine-jitter stroke points and splat them with a soft brush -> 28x28."""
    ang = np.radians(rng.uniform(-12.0, 12.0))
    sx, sy = rng.uniform(0.82, 1.12, 2)
    shear = rng.uniform(-0.15, 0.15)
    rot = np.array([[np.cos(ang), -np.sin(ang)], [np.sin(ang), np.cos(ang)]])
    aff = rot @ np.array([[sx, shear * sx], [0.0, sy]])
    centered = points - 0.5
    pts = centered @ aff.T + 0.5 + rng.uniform(-0.07, 0.07, 2)
    pix = pts * 24.0 + 2.0  # margin so jittered strokes stay on canvas
    cols, rows = pix[:, 0], pix[:, 1]
    rr = np.arange(28, dtype=np.float64)
    dr2 = (rr[:, None] - rows[None, :]) ** 2  # [28, M]
    dc2 = (rr[:, None] - cols[None, :]) ** 2
    sigma = rng.uniform(0.75, 1.05)
    # max over stroke points of a Gaussian blob: constant-intensity strokes
    blob = np.exp(-(dr2[:, None, :] + dc2[None, :, :]) / (2.0 * sigma * sigma))
    return blob.max(axis=2)


def mnist_like(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(x [n, 784] float32 in [0, 1], y [n] int32) stroke-skeleton digits."""
    rng = np.random.default_rng((seed, 104729))
    y = rng.integers(0, 10, n).astype(np.int32)
    x = np.empty((n, 784), np.float32)
    skel = {d: np.concatenate(s, axis=0) for d, s in _STROKES.items()}
    for i in range(n):
        img = _render(skel[int(y[i])], rng)
        img *= rng.uniform(0.75, 1.0)
        img += rng.normal(0.0, 0.08, (28, 28))
        x[i] = np.clip(img, 0.0, 1.0).reshape(784).astype(np.float32)
    return x, y


def train_test(n_train: int, n_test: int, seed: int = 0):
    """((x_tr, y_tr), (x_te, y_te)) from disjoint deterministic streams."""
    return mnist_like(n_train, seed=seed), mnist_like(n_test, seed=seed + 1)
