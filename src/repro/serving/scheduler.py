"""Async request scheduler over :class:`~repro.serving.engine.ServingEngine`.

The engine owns slots and the fused device step; the scheduler owns the
request lifecycle:

* a priority queue (higher ``priority`` first, FIFO within a priority),
* admission control — a request enters a slot only when one is free AND its
  prompt fits the per-slot KV budget (``max_len``); requests whose prompt +
  budget exceed the cache are still admitted and simply capped at ``max_len``,
* per-request ``max_new`` / ``temperature`` overrides (forwarded to the
  engine's per-slot budget arrays inside the fused step),
* streaming: ``on_token(rid, token)`` fires for every token sampled by this
  scheduler's ``step()``/``run()`` (steps driven directly on the engine
  bypass it — their tokens land only in the request's result),
* failed-request isolation — a prompt that fails validation (empty, beyond
  the KV cache) or whose submission raises becomes a finished
  ``GenerationResult(error=...)``; the rest of the batch is unaffected.

Both ``ServingEngine.generate()`` and ``repro.launch.serve`` drive their
batches through this class.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

from .engine import GenerationResult, ServingEngine, StepEvent

__all__ = ["Request", "Scheduler"]


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int | None = None
    temperature: float | None = None
    priority: int = 0
    on_token: Callable[[int, int], None] | None = field(default=None, repr=False)
    sid: int | None = None  # tracer span id (tracer namespace, not rid)
    # aliased engine result: survives the engine-side eviction at retire
    result: GenerationResult | None = field(default=None, repr=False)


class Scheduler:
    """Queue + admission + streaming over one engine.  Request ids issued by
    the scheduler are its own namespace (``results`` is keyed by them); the
    engine's internal ids never surface."""

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self._heap: list[tuple[int, int, Request]] = []
        self._seq = 0  # FIFO tiebreak within a priority class
        self._next_rid = 0
        self.results: dict[int, GenerationResult] = {}
        self._inflight: dict[int, Request] = {}  # engine rid -> request
        # continuous-batching telemetry (ints kept for direct access; the
        # engine's registry mirrors them as counters when metrics are on)
        self.admitted_while_running = 0  # admissions joining a live batch
        self.mem_stalls = 0  # admit() passes blocked on KV blocks, not slots
        m = engine.metrics
        if m is not None:
            self._m_admit_run = m.counter(
                "sched_admitted_while_running_total",
                "admissions that joined a live batch (continuous batching)")
            self._m_stalls = m.counter(
                "sched_mem_stalls_total",
                "admission passes blocked on KV blocks, not slots")
            self._m_pending = m.gauge("sched_pending", "queued requests")
            self._m_inflight = m.gauge("sched_inflight", "in-flight requests")
        else:
            self._m_admit_run = self._m_stalls = None
            self._m_pending = self._m_inflight = None

    @property
    def tracer(self):
        """The engine's tracer, read live (it may be attached after this
        scheduler was built)."""
        return self.engine.tracer

    # ---------------------------------------------------------------- queue
    def enqueue(self, prompt: list[int], *, max_new: int | None = None,
                temperature: float | None = None, priority: int = 0,
                on_token: Callable[[int, int], None] | None = None) -> int:
        """Queue a request; returns its scheduler id immediately.  Invalid
        prompts resolve to an errored, finished result instead of raising."""
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=list(prompt), max_new=max_new,
                      temperature=temperature, priority=priority,
                      on_token=on_token)
        tr = self.tracer
        if tr is not None:
            req.sid = tr.enqueue(rid, len(req.prompt))
        err = self.engine.validate_prompt(req.prompt)
        if err is not None:
            self.results[rid] = GenerationResult(
                tokens=list(req.prompt), prompt_len=len(req.prompt),
                finished=True, error=err)
            if req.sid is not None:
                tr.retire(req.sid, status="error", error=err)
            return rid
        heapq.heappush(self._heap, (-priority, self._seq, req))
        self._seq += 1
        return rid

    def take_result(self, rid: int) -> GenerationResult:
        """Pop a request's result (raises KeyError if unknown).  Long-running
        serve loops should collect through this so memory stays bounded by
        in-flight + uncollected work, not by total requests ever served."""
        return self.results.pop(rid)

    @property
    def pending(self) -> int:
        return len(self._heap)

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    # ------------------------------------------------------------ admission
    def admit(self) -> list[int]:
        """Move queued requests into free engine slots (priority order);
        returns the scheduler ids admitted now.

        Continuous batching: this runs between jitted steps, so requests join
        a live batch the moment a slot frees — the batch never drains.  On a
        paged engine admission is additionally gated on KV *blocks*
        (``engine.can_admit``): when the head-of-queue prompt cannot get its
        blocks even by evicting cached prefixes, admission stops — strictly,
        so a big high-priority request is never starved by small ones slipping
        past it (no head-of-line bypass)."""
        admitted: list[int] = []
        tr = self.tracer
        while self._heap and (~self.engine.active).any():
            req = self._heap[0][2]
            if not self.engine.can_admit(req.prompt):
                self.mem_stalls += 1
                if self._m_stalls is not None:
                    self._m_stalls.inc()
                break
            heapq.heappop(self._heap)
            was_running = bool(self.engine.active.any())
            try:
                erid = self.engine.submit(req.prompt, max_new=req.max_new,
                                          temperature=req.temperature)
            except Exception as e:  # isolation: one bad request never
                self.results[req.rid] = GenerationResult(  # strands the batch
                    tokens=list(req.prompt), prompt_len=len(req.prompt),
                    finished=True, error=str(e))
                if tr is not None and req.sid is not None:
                    tr.retire(req.sid, status="error", error=str(e))
                continue
            # alias the engine's live result object: token appends and the
            # finished flag propagate without copying
            req.result = self.engine.results[erid]
            self.results[req.rid] = req.result
            self._inflight[erid] = req
            admitted.append(req.rid)
            self.admitted_while_running += was_running
            if tr is not None and req.sid is not None:
                tr.admit(req.sid)
            if was_running and self._m_admit_run is not None:
                self._m_admit_run.inc()
        return admitted

    # ---------------------------------------------------------------- drive
    def step(self) -> list[StepEvent]:
        """Admit what fits, run one fused engine step, fire callbacks.
        Returns the step's events re-keyed to *scheduler* request ids (events
        for slots submitted outside this scheduler are omitted — the engine
        id namespace never surfaces here)."""
        self.admit()
        events = self.engine.step()
        tr = self.tracer
        out: list[StepEvent] = []
        for ev in events:
            req = self._inflight.get(ev.rid)
            if req is None:
                continue  # slot submitted outside this scheduler
            out.append(StepEvent(rid=req.rid, token=ev.token,
                                 finished=ev.finished))
            if ev.token is not None and tr is not None and req.sid is not None:
                tr.token(req.sid)
            if ev.token is not None and req.on_token is not None:
                try:
                    req.on_token(req.rid, ev.token)
                except Exception as e:  # isolation: a broken streaming
                    # consumer cancels only its own request, not the batch —
                    # and only if generation is still running; a delivery
                    # failure on the final token leaves the completed result
                    if not ev.finished:
                        # guarded lookup: the caller may have collected the
                        # in-flight result via take_result() already
                        res = self.results.get(req.rid,
                                               self.engine.results.get(ev.rid))
                        if res is not None:
                            res.error = f"streaming callback failed: {e!r}"
                        self.engine.cancel(ev.rid)
                        # consumers keying teardown off StepEvent.finished
                        # still get a terminal event for the cancelled request
                        out.append(StepEvent(rid=req.rid, token=None,
                                             finished=True))
        # retire via the aliased result, not the event stream: a request whose
        # finishing step ran outside this scheduler (direct engine.step(), an
        # interleaved generate()) must still unblock run().  The engine-side
        # entry is evicted here; the scheduler's own ``results`` keeps the
        # finished result until the caller collects it via take_result().
        for erid in [e for e, rq in self._inflight.items()
                     if self.engine.results.get(e) is None
                     or rq.result.finished]:
            req = self._inflight.pop(erid)
            self.engine.results.pop(erid, None)
            if tr is not None and req.sid is not None:
                r = req.result
                tr.annotate(req.sid, **r.stats)
                if r.stats.get("cancelled"):
                    tr.retire(req.sid, status="cancelled", error=r.error)
                elif r.error is not None:
                    tr.retire(req.sid, status="error", error=r.error)
                else:
                    tr.retire(req.sid, status="ok")
        if self._m_pending is not None:
            self._m_pending.set(len(self._heap))
            self._m_inflight.set(len(self._inflight))
        return out

    def run(self) -> dict[int, GenerationResult]:
        """Drive until the queue and all in-flight slots drain."""
        while self._heap or self._inflight or self.engine.active.any():
            self.step()
        return self.results
