"""Site-keyed compressed execution: route EVERY compressed site through fused
kernels at serving time.

The PR-2 engine routed only dense-family FFN projections through the fused
LCC chain; every other site an adapter can compress (attention q/k/v/o, MoE
experts, RWKV/Mamba mixes, Whisper decoder, ResNet convs) fell back to its
dense-effective weights — the artifact saved memory but not computation.
:class:`CompressedExecutor` closes that gap: built from any
:class:`~repro.core.artifact.CompressedModel`, it maps every adapter site name
(the keys of ``artifact.records``, produced by
``models.compress_adapters.sites_for``) to a fused-kernel callable, and the
model decode paths consult it *inside* the jitted step.

Three kernel routes:

* :class:`LCCMatvec` — one dense site: prune gather -> eq. (10) segment-sum ->
  the whole FP chain in ONE ``lcc_chain_matmul`` launch.
* :class:`GroupedLCCMatvec` — one *fused region*: several sites (an MoE
  layer's experts, an attention layer's q/k/v, RWKV's r/k/v/g) apply their
  chains in ONE ``lcc_group_matmul`` launch, so a decode step pays one
  dispatch per region instead of one per site.
* :class:`ConvLCC` — a conv site executed in the compressed domain: the
  FK/PK reshape of ``core.conv_reshape`` turns the conv into per-channel
  CMVMs and all decomposed channels run as one grouped launch.

Models never import this module — they receive the executor as an opaque
object with the protocol ``matvec(name)``, ``grouped(names)``, ``conv(name)``
(each returning a callable or None) so the dependency stays
serving -> models, never the reverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressedExecutor", "LCCMatvec", "GroupedLCCMatvec", "ConvLCC",
           "matvecs_from_artifact"]


class LCCMatvec:
    """One compressed projection as a fused-kernel matvec: x [K, B] -> [N, B].

    Prune (kept_columns gather) -> optional weight-sharing segment-sum (paper
    eq. (10)) -> the whole FP decomposition in a single ``lcc_chain_matmul``
    launch.  Built from a ``core.compress.CompressedDense`` record; pass
    ``packed=`` to reuse an artifact's pre-packed kernel buffers instead of
    re-packing the decomposition.

    ``B`` is bucketed to powers of two (pad + slice), so serving many distinct
    decode/prefill batch widths compiles at most log2 variants of the fused
    chain instead of one per width.
    """

    def __init__(self, cd, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = cd.name
        self.packed = (packed if packed is not None
                       else ops.pack_decomposition(cd.decomposition, block))
        self.kept = jnp.asarray(np.asarray(cd.kept_columns), jnp.int32)
        self.labels = (jnp.asarray(cd.shared.labels, jnp.int32)
                       if cd.shared is not None else None)
        self.n_clusters = cd.shared.n_clusters if cd.shared is not None else 0
        self.interpret = interpret
        # jit the whole chain (gather -> segment-sum -> fused kernel) so a
        # per-token decode loop pays one dispatch, not one per slice/stage
        self._fn = jax.jit(self._run)

    def _run(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops

        xk = x[self.kept]
        if self.labels is not None:
            xk = ops.segment_sum_tpu(self.labels, xk, self.n_clusters,
                                     interpret=self.interpret)
        return ops.apply_packed_decomposition(self.packed, xk,
                                              interpret=self.interpret)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        vec = x.ndim == 1
        if vec:
            x = x[:, None]
        b = x.shape[1]
        b_pad = 1 << (b - 1).bit_length()  # next power of two (b=1 -> 1)
        if b_pad != b:
            x = jnp.pad(x, ((0, 0), (0, b_pad - b)))
        y = self._fn(x)
        return y[:, 0] if vec else y[:, :b]


class GroupedLCCMatvec:
    """Several compressed sites applied in ONE fused launch (a *fused region*).

    Call with a per-site list of features-major inputs ``[K_g, B]`` (all the
    same batch width; input widths may differ — each member gathers its own
    kept columns and segment-sums its own clusters before the shared
    ``lcc_group_matmul`` dispatch).  Returns the per-site ``[N_g, B]`` outputs.
    """

    def __init__(self, records, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        packed = packed or [None] * len(records)
        members = [pk if pk is not None
                   else ops.pack_decomposition(cd.decomposition, block)
                   for cd, pk in zip(records, packed)]
        self.names = tuple(cd.name for cd in records)
        self.group = ops.pack_group(members)
        # cached state stays numpy: groups are assembled lazily — the first
        # decode trace to name this fused region builds the object — and numpy
        # constants embed per-trace instead of leaking that trace's tracers
        self.kept = [np.asarray(cd.kept_columns, np.int32) for cd in records]
        self.labels = [np.asarray(cd.shared.labels, np.int32)
                       if cd.shared is not None else None for cd in records]
        self.n_clusters = [cd.shared.n_clusters if cd.shared is not None else 0
                           for cd in records]
        self.interpret = interpret
        self._fn = jax.jit(self._run)

    def _run(self, xs):
        from repro.kernels import ops

        prep = []
        for x, kept, labels, nc in zip(xs, self.kept, self.labels,
                                       self.n_clusters):
            xk = x[kept]
            if labels is not None:
                xk = ops.segment_sum_tpu(labels, xk, nc,
                                         interpret=self.interpret)
            prep.append(xk)
        return tuple(ops.apply_packed_group(self.group, prep,
                                            interpret=self.interpret))

    def __call__(self, xs) -> list[jnp.ndarray]:
        b = xs[0].shape[1]
        b_pad = 1 << (b - 1).bit_length()
        if b_pad != b:
            xs = [jnp.pad(x, ((0, 0), (0, b_pad - b))) for x in xs]
        ys = self._fn(tuple(xs))
        return [y[:, :b] for y in ys]


class ConvLCC:
    """One compressed conv layer executed in the compressed domain.

    Decomposed input channels run their FK/PK CMVM chains in ONE grouped
    launch over ``core.conv_reshape``'s window extraction; channels without a
    decomposition (subsampled-out or pruned) go through a dense conv on the
    residual kernel.  Matches ``lax.conv`` SAME/VALID semantics including
    stride, so ``resnet_forward(..., executor=...)`` is a drop-in.
    """

    def __init__(self, name: str, kernel: np.ndarray, record: dict,
                 method: str, *, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = name
        self.method = method
        self.n, _, self.o, _ = kernel.shape
        self.channels = sorted(record["decompositions"])
        packed = [ops.pack_decomposition(record["decompositions"][ch], block)
                  for ch in self.channels]
        self.group = ops.pack_group(packed) if packed else None
        rest = np.asarray(kernel, np.float32).copy()
        rest[:, self.channels] = 0.0  # chain channels leave the dense conv
        self.rest = jnp.asarray(rest)
        self.has_rest = bool(np.abs(rest).max() > 0)
        self.interpret = interpret
        self._fn = jax.jit(self._run, static_argnames=("stride", "padding"))

    def _run(self, x: jnp.ndarray, stride: int = 1, padding: str = "SAME"
             ) -> jnp.ndarray:
        from jax import lax

        from repro.core.conv_reshape import (extract_patches,
                                             extract_vert_windows, same_pad_2d)
        from repro.kernels import ops

        b, k, z, _ = x.shape
        o = self.o
        if padding == "SAME":
            lo, hi = same_pad_2d(z, o, stride)
            xp = jnp.pad(x, ((0, 0), (0, 0), (lo, hi), (lo, hi)))
        else:
            xp = x
        zp = xp.shape[2]
        p = (zp - o) // stride + 1
        y = None
        if self.has_rest:
            y = lax.conv_general_dilated(
                xp.astype(jnp.float32), self.rest, (stride, stride), "VALID",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if self.group is not None:
            xc = xp[:, jnp.asarray(self.channels, jnp.int32)]
            if self.method == "fk":
                pat = extract_patches(xc, o, stride)  # [B, C, P, P, O, O]
                xs = [pat[:, i].reshape(b * p * p, o * o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                yc = sum(ys)  # [N, B*P*P]
                yc = jnp.moveaxis(yc.T.reshape(b, p, p, self.n), -1, 1)
            else:  # pk: rows (n, j) are kernel columns over vertical windows
                win = extract_vert_windows(xc, o, stride)  # [B, C, P, Zp, O]
                xs = [win[:, i].reshape(b * p * zp, o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                # sum channel parts, then gather the j-offset columns:
                # y[b, n, p, q] = sum_j part[b, p, q*stride + j, n, j]
                part = sum(ys)  # [N*O, B*P*Zp]
                part = part.reshape(self.n, o, b, p, zp)
                part = jnp.transpose(part, (2, 3, 4, 0, 1))  # [B, P, Zp, N, O]
                cq = stride * jnp.arange(p)[:, None] + jnp.arange(o)[None, :]
                sel = part[:, :, cq]  # [B, P, Q, O(j), N, O(j')]
                yc = jnp.moveaxis(jnp.einsum("bpqjnj->bpqn", sel), -1, 1)
            y = yc if y is None else y + yc
        if y is None:
            raise ValueError(f"conv site {self.name!r}: nothing to execute")
        return y.astype(x.dtype)

    def __call__(self, x: jnp.ndarray, *, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
        return self._fn(x, stride=stride, padding=padding)


def matvecs_from_artifact(artifact, *, include=None, block: int = 128,
                          interpret: bool | None = None) -> dict[str, LCCMatvec]:
    """Per-site :class:`LCCMatvec` table for an artifact's dense records.

    The one place the (name -> record, ``packed=`` lookup) wiring lives —
    both :class:`CompressedExecutor` and the legacy
    ``compress_ffn_for_serving`` build their tables through it.  ``include``
    filters site names (callable or prefix string).
    """
    from repro.core.compress import CompressedDense

    keep = (include if callable(include)
            else (lambda n: n.startswith(include)) if include is not None
            else (lambda n: True))
    return {name: LCCMatvec(rec, packed=artifact.packed.get(name),
                            block=block, interpret=interpret)
            for name, rec in artifact.records.items()
            if isinstance(rec, CompressedDense) and keep(name)}


class CompressedExecutor:
    """Site-keyed registry mapping every compressed site of an artifact to a
    fused-kernel callable.

    Protocol consumed by the model decode paths (duck-typed — models never
    import serving):

    * ``matvec(name)``   -> features-major callable ``[K, B] -> [N, B]`` or
      None when the site is not compressed (dense fallback).
    * ``grouped(names)`` -> one-launch callable over a *fused region* (list of
      per-site ``[K_g, B]`` inputs -> list of ``[N_g, B]`` outputs), or None
      unless every name is a compressed dense site.
    * ``conv(name)``     -> :class:`ConvLCC` or None.

    ``routed`` records (at trace time) every site actually served by a fused
    kernel — tests assert it covers the artifact, and the engine reports it.
    """

    def __init__(self, artifact, *, block: int = 128,
                 interpret: bool | None = None):
        self.artifact = artifact
        self.block = block
        self.interpret = interpret
        self._matvecs = matvecs_from_artifact(artifact, block=block,
                                              interpret=interpret)
        self._convs: dict[str, ConvLCC] = {}
        self._groups: dict[tuple, GroupedLCCMatvec | None] = {}
        self.routed: set[str] = set()
        conv_names = [n for n, r in artifact.records.items()
                      if not hasattr(r, "decomposition")]
        if conv_names:
            from repro.models import compress_adapters as ca

            kernels = {s.name: s.kernel(artifact.params)
                       for s in ca.sites_for(artifact.params, artifact.config)
                       if isinstance(s, ca.ConvSite)}
            for name in conv_names:
                self._convs[name] = ConvLCC(
                    name, kernels[name], artifact.records[name],
                    artifact.unit_config_for(name).conv_method,
                    block=block, interpret=interpret)

    @property
    def sites(self) -> set[str]:
        """Every site this executor can serve through a fused kernel."""
        return set(self._matvecs) | set(self._convs)

    def __contains__(self, name: str) -> bool:
        return name in self._matvecs or name in self._convs

    def matvec(self, name: str):
        fn = self._matvecs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn

    def grouped(self, names):
        names = tuple(names)
        if names not in self._groups:
            if all(n in self._matvecs for n in names) and names:
                recs = [self.artifact.records[n] for n in names]
                # reuse the eagerly-packed per-site buffers: group assembly
                # happens at trace time and must only touch concrete arrays
                packed = [self._matvecs[n].packed for n in names]
                self._groups[names] = GroupedLCCMatvec(
                    recs, packed=packed, block=self.block,
                    interpret=self.interpret)
            else:
                self._groups[names] = None
        g = self._groups[names]
        if g is not None:
            self.routed.update(names)
        return g

    def conv(self, name: str):
        fn = self._convs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn
