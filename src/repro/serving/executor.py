"""Site-keyed compressed execution: route EVERY compressed site through fused
kernels at serving time.

The PR-2 engine routed only dense-family FFN projections through the fused
LCC chain; every other site an adapter can compress (attention q/k/v/o, MoE
experts, RWKV/Mamba mixes, Whisper decoder, ResNet convs) fell back to its
dense-effective weights — the artifact saved memory but not computation.
:class:`CompressedExecutor` closes that gap: built from any
:class:`~repro.core.artifact.CompressedModel`, it maps every adapter site name
(the keys of ``artifact.records``, produced by
``models.compress_adapters.sites_for``) to a fused-kernel callable, and the
model decode paths consult it *inside* the jitted step.

Three kernel routes:

* :class:`LCCMatvec` — one dense site: prune gather -> eq. (10) segment-sum ->
  the whole FP chain in ONE ``lcc_chain_matmul`` launch.
* :class:`GroupedLCCMatvec` — one *fused region*: several sites (an MoE
  layer's experts, an attention layer's q/k/v, RWKV's r/k/v/g) apply their
  chains in ONE ``lcc_group_matmul`` launch, so a decode step pays one
  dispatch per region instead of one per site.
* :class:`ConvLCC` — a conv site executed in the compressed domain: the
  FK/PK reshape of ``core.conv_reshape`` turns the conv into per-channel
  CMVMs and all decomposed channels run as one grouped launch.

Models never import this module — they receive the executor as an opaque
object with the protocol ``matvec(name)``, ``grouped(names)``, ``conv(name)``
(each returning a callable or None) so the dependency stays
serving -> models, never the reverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressedExecutor", "LCCMatvec", "GroupedLCCMatvec", "ConvLCC",
           "StepPlan", "MoEPlan", "matvecs_from_artifact"]


class LCCMatvec:
    """One compressed projection as a fused-kernel matvec: x [K, B] -> [N, B].

    Prune (kept_columns gather) -> optional weight-sharing segment-sum (paper
    eq. (10)) -> the whole FP decomposition in a single ``lcc_chain_matmul``
    launch.  Built from a ``core.compress.CompressedDense`` record; pass
    ``packed=`` to reuse an artifact's pre-packed kernel buffers instead of
    re-packing the decomposition.

    ``B`` is bucketed to powers of two (pad + slice), so serving many distinct
    decode/prefill batch widths compiles at most log2 variants of the fused
    chain instead of one per width.
    """

    def __init__(self, cd, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = cd.name
        self.packed = (packed if packed is not None
                       else ops.pack_decomposition(cd.decomposition, block))
        self.kept = jnp.asarray(np.asarray(cd.kept_columns), jnp.int32)
        self.labels = (jnp.asarray(cd.shared.labels, jnp.int32)
                       if cd.shared is not None else None)
        self.n_clusters = cd.shared.n_clusters if cd.shared is not None else 0
        self.interpret = interpret
        # jit the whole chain (gather -> segment-sum -> fused kernel) so a
        # per-token decode loop pays one dispatch, not one per slice/stage
        self._fn = jax.jit(self._run)

    def _run(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops

        xk = x[self.kept]
        if self.labels is not None:
            xk = ops.segment_sum_tpu(self.labels, xk, self.n_clusters,
                                     interpret=self.interpret)
        return ops.apply_packed_decomposition(self.packed, xk,
                                              interpret=self.interpret)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        vec = x.ndim == 1
        if vec:
            x = x[:, None]
        b = x.shape[1]
        b_pad = 1 << (b - 1).bit_length()  # next power of two (b=1 -> 1)
        if b_pad != b:
            x = jnp.pad(x, ((0, 0), (0, b_pad - b)))
        y = self._fn(x)
        return y[:, 0] if vec else y[:, :b]


class GroupedLCCMatvec:
    """Several compressed sites applied in ONE fused launch (a *fused region*).

    Call with a per-site list of features-major inputs ``[K_g, B]`` (all the
    same batch width; input widths may differ — each member gathers its own
    kept columns and segment-sums its own clusters before the shared
    ``lcc_group_matmul`` dispatch).  Returns the per-site ``[N_g, B]`` outputs.
    """

    def __init__(self, records, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        packed = packed or [None] * len(records)
        members = [pk if pk is not None
                   else ops.pack_decomposition(cd.decomposition, block)
                   for cd, pk in zip(records, packed)]
        self.names = tuple(cd.name for cd in records)
        self.group = ops.pack_group(members)
        # cached state stays numpy: groups are assembled lazily — the first
        # decode trace to name this fused region builds the object — and numpy
        # constants embed per-trace instead of leaking that trace's tracers
        self.kept = [np.asarray(cd.kept_columns, np.int32) for cd in records]
        self.labels = [np.asarray(cd.shared.labels, np.int32)
                       if cd.shared is not None else None for cd in records]
        self.n_clusters = [cd.shared.n_clusters if cd.shared is not None else 0
                           for cd in records]
        self.interpret = interpret
        self._fn = jax.jit(self._run)

    def _run(self, xs):
        from repro.kernels import ops

        prep = []
        for x, kept, labels, nc in zip(xs, self.kept, self.labels,
                                       self.n_clusters):
            xk = x[kept]
            if labels is not None:
                xk = ops.segment_sum_tpu(labels, xk, nc,
                                         interpret=self.interpret)
            prep.append(xk)
        return tuple(ops.apply_packed_group(self.group, prep,
                                            interpret=self.interpret))

    def __call__(self, xs) -> list[jnp.ndarray]:
        b = xs[0].shape[1]
        b_pad = 1 << (b - 1).bit_length()
        if b_pad != b:
            xs = [jnp.pad(x, ((0, 0), (0, b_pad - b))) for x in xs]
        ys = self._fn(tuple(xs))
        return [y[:, :b] for y in ys]


class ConvLCC:
    """One compressed conv layer executed in the compressed domain.

    Decomposed input channels run their FK/PK CMVM chains in ONE grouped
    launch over ``core.conv_reshape``'s window extraction; channels without a
    decomposition (subsampled-out or pruned) go through a dense conv on the
    residual kernel.  Matches ``lax.conv`` SAME/VALID semantics including
    stride, so ``resnet_forward(..., executor=...)`` is a drop-in.
    """

    def __init__(self, name: str, kernel: np.ndarray, record: dict,
                 method: str, *, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = name
        self.method = method
        self.n, _, self.o, _ = kernel.shape
        self.channels = sorted(record["decompositions"])
        packed = [ops.pack_decomposition(record["decompositions"][ch], block)
                  for ch in self.channels]
        self.group = ops.pack_group(packed) if packed else None
        rest = np.asarray(kernel, np.float32).copy()
        rest[:, self.channels] = 0.0  # chain channels leave the dense conv
        self.rest = jnp.asarray(rest)
        self.has_rest = bool(np.abs(rest).max() > 0)
        self.interpret = interpret
        self._fn = jax.jit(self._run, static_argnames=("stride", "padding"))

    def _run(self, x: jnp.ndarray, stride: int = 1, padding: str = "SAME"
             ) -> jnp.ndarray:
        from jax import lax

        from repro.core.conv_reshape import (extract_patches,
                                             extract_vert_windows, same_pad_2d)
        from repro.kernels import ops

        b, k, z, _ = x.shape
        o = self.o
        if padding == "SAME":
            lo, hi = same_pad_2d(z, o, stride)
            xp = jnp.pad(x, ((0, 0), (0, 0), (lo, hi), (lo, hi)))
        else:
            xp = x
        zp = xp.shape[2]
        p = (zp - o) // stride + 1
        y = None
        if self.has_rest:
            y = lax.conv_general_dilated(
                xp.astype(jnp.float32), self.rest, (stride, stride), "VALID",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if self.group is not None:
            xc = xp[:, jnp.asarray(self.channels, jnp.int32)]
            if self.method == "fk":
                pat = extract_patches(xc, o, stride)  # [B, C, P, P, O, O]
                xs = [pat[:, i].reshape(b * p * p, o * o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                yc = sum(ys)  # [N, B*P*P]
                yc = jnp.moveaxis(yc.T.reshape(b, p, p, self.n), -1, 1)
            else:  # pk: rows (n, j) are kernel columns over vertical windows
                win = extract_vert_windows(xc, o, stride)  # [B, C, P, Zp, O]
                xs = [win[:, i].reshape(b * p * zp, o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                # sum channel parts, then gather the j-offset columns:
                # y[b, n, p, q] = sum_j part[b, p, q*stride + j, n, j]
                part = sum(ys)  # [N*O, B*P*Zp]
                part = part.reshape(self.n, o, b, p, zp)
                part = jnp.transpose(part, (2, 3, 4, 0, 1))  # [B, P, Zp, N, O]
                cq = stride * jnp.arange(p)[:, None] + jnp.arange(o)[None, :]
                sel = part[:, :, cq]  # [B, P, Q, O(j), N, O(j')]
                yc = jnp.moveaxis(jnp.einsum("bpqjnj->bpqn", sel), -1, 1)
            y = yc if y is None else y + yc
        if y is None:
            raise ValueError(f"conv site {self.name!r}: nothing to execute")
        return y.astype(x.dtype)

    def __call__(self, x: jnp.ndarray, *, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
        return self._fn(x, stride=stride, padding=padding)


class StepPlan:
    """Whole-decode-step layer plan for the dense transformer family.

    Packs every site of every layer — attention q/k/v/o and FFN gate/up/down,
    compressed (CSD shift-add segments) or not (baked dense blocks) — into
    four stacked :class:`~repro.kernels.ops.PackedStage` buffers and executes
    the full step as ONE ``pallas_call`` with grid ``(L,)``
    (:func:`repro.kernels.layer_plan.step_plan_matmul`).  KV write-back runs
    outside the kernel, vectorized over layers, for both contiguous and paged
    caches.
    """

    def __init__(self, executor, cfg):
        from repro.kernels import ops

        self.executor = executor
        self.cfg = cfg
        art = executor.artifact
        blocks = art.params["blocks"]
        d, dff = cfg.d_model, cfg.d_ff
        nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        covered: list[str] = []

        def spec(name, pdict, li, out_off):
            rec = art.records.get(name)
            # np.asarray BEFORE indexing: the plan may build lazily inside a
            # jit trace, where even slicing a concrete constant binds a traced
            # op — converting the whole stack first keeps the build pure-host
            bias = (np.asarray(pdict["b"], np.float32)[li]
                    if "b" in pdict else None)
            if rec is None or not hasattr(rec, "decomposition"):
                # uncovered site: bake its dense weights into the stage so the
                # plan still emits the layer's full output
                return {"kind": "dense", "out_off": out_off, "src_off": 0,
                        "w": np.asarray(pdict["w"], np.float32)[li],
                        "bias": bias}
            covered.append(name)
            packed = art.packed.get(name)
            if packed is None:
                packed = ops.pack_decomposition(rec.decomposition,
                                                executor.block)
            return {"kind": "lcc", "name": name, "out_off": out_off,
                    "src_off": 0,
                    "kept": np.asarray(rec.kept_columns, np.int64),
                    "labels": (np.asarray(rec.shared.labels, np.int64)
                               if rec.shared is not None else None),
                    "n_clusters": (rec.shared.n_clusters
                                   if rec.shared is not None else 0),
                    "packed": packed, "bias": bias}

        qkv, o_, gu, dn = [], [], [], []
        for li in range(cfg.n_layers):
            ab, fb = blocks["attn"], blocks["ffn"]
            qkv.append([spec(f"attn.q.l{li}", ab["q"], li, 0),
                        spec(f"attn.k.l{li}", ab["k"], li, nq * hd),
                        spec(f"attn.v.l{li}", ab["v"], li, (nq + nkv) * hd)])
            o_.append([spec(f"attn.o.l{li}", ab["o"], li, 0)])
            gu.append([spec(f"ffn.gate.l{li}", fb["gate"], li, 0),
                       spec(f"ffn.up.l{li}", fb["up"], li, dff)])
            dn.append([spec(f"ffn.down.l{li}", fb["down"], li, 0)])
        pre = art.plans.get("step") if hasattr(art, "plans") else None
        if (pre is not None
                and all(ps.n_layers == cfg.n_layers for ps in pre.values())):
            self.stages = pre  # artifact shipped plan-ready packed buffers
        else:
            self.stages = ops.pack_layer({
                "qkv": (qkv, d, (nq + 2 * nkv) * hd),
                "o": (o_, nq * hd, d),
                "gu": (gu, d, 2 * dff),
                "dn": (dn, dff, d)})
            if hasattr(art, "plans"):
                art.plans["step"] = self.stages
        self.ln1 = (np.asarray(blocks["ln1"], np.float32)
                    if cfg.norm == "rms" else None)
        self.ln2 = (np.asarray(blocks["ln2"], np.float32)
                    if cfg.norm == "rms" else None)
        self.covered = frozenset(covered)

    def decode_layers(self, state, x, pos):
        """x [B, 1, d] embedded tokens -> (x' [B, 1, d], new kv state)."""
        from repro.kernels import layer_plan
        from repro.models.layers import _rope_sincos

        cfg = self.cfg
        self.executor.routed.update(self.covered)
        k_state, v_state, kpos = state["k"], state["v"], state["kpos"]
        tbl = state.get("block_tbl")
        nl, nkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        b = x.shape[0]
        if tbl is not None:  # paged: pre-gather the table's view for the kernel
            kc = k_state[:, tbl].reshape(nl, b, -1, nkv, hd)
            vc = v_state[:, tbl].reshape(nl, b, -1, nkv, hd)
        else:
            kc, vc = k_state, v_state
        pos = pos.astype(jnp.int32)
        cos = sin = None
        rope = cfg.pos == "rope"
        if rope:
            sin, cos = _rope_sincos(pos, hd, cfg.rope_theta)
        y, kn, vn = layer_plan.step_plan_matmul(
            self.stages, n_heads=cfg.n_heads, n_kv_heads=nkv, head_dim=hd,
            d_ff=cfg.d_ff, norm=cfg.norm, rope=rope,
            x0=x[:, 0, :].astype(jnp.float32).T, pos=pos, cos=cos, sin=sin,
            ln1=self.ln1, ln2=self.ln2, kc=kc, vc=vc, kpos=kpos,
            interpret=self.executor.interpret)
        dt = k_state.dtype
        kn, vn = kn.astype(dt), vn.astype(dt)
        if tbl is None:
            smax = k_state.shape[2]
            sel = jax.nn.one_hot(pos, smax, dtype=dt)
            grow = sel[None, :, :, None, None]
            new = {"k": k_state * (1 - grow) + grow * kn[:, :, None],
                   "v": v_state * (1 - grow) + grow * vn[:, :, None],
                   "kpos": jnp.where(sel[None] > 0, pos[None, :, None], kpos)}
        else:
            bs = k_state.shape[2]
            w = jnp.maximum(pos, 0)
            bidx = jnp.take_along_axis(tbl, (w // bs)[:, None], axis=1)[:, 0]
            # inactive slots (pos == -1) scatter into the null block; their
            # kpos stays -1 so the stale row is never attended to
            bidx = jnp.where(pos >= 0, bidx, 0)
            sel = jax.nn.one_hot(pos, kpos.shape[2])
            new = {"k": k_state.at[:, bidx, w % bs].set(kn),
                   "v": v_state.at[:, bidx, w % bs].set(vn),
                   "kpos": jnp.where(sel[None] > 0, pos[None, :, None], kpos),
                   "block_tbl": tbl}
        return y.T[:, None, :].astype(x.dtype), new


class MoEPlan:
    """One MoE layer's expert FFNs as a single launch.

    Two stages over flattened expert buffers — A: all gates+ups from
    ``[E*d, C]``, B: all downs from the in-kernel SwiGLU ``[E*dff, C]`` —
    replacing the three grouped ``expert_mm`` dispatches per layer.
    """

    def __init__(self, executor, site_tag: str, *, n_experts: int,
                 d_model: int, d_ff: int):
        from repro.kernels import ops

        self.executor = executor
        art = executor.artifact
        e, d, dff = n_experts, d_model, d_ff

        def spec(name, out_off, src_off):
            rec = art.records[name]
            packed = art.packed.get(name)
            if packed is None:
                packed = ops.pack_decomposition(rec.decomposition,
                                                executor.block)
            return {"kind": "lcc", "name": name, "out_off": out_off,
                    "src_off": src_off,
                    "kept": np.asarray(rec.kept_columns, np.int64),
                    "labels": (np.asarray(rec.shared.labels, np.int64)
                               if rec.shared is not None else None),
                    "n_clusters": (rec.shared.n_clusters
                                   if rec.shared is not None else 0),
                    "packed": packed, "bias": None}

        sa, sb, names = [], [], []
        for ei in range(e):
            sa.append(spec(f"moe.gate.{site_tag}.e{ei}", ei * dff, ei * d))
            sa.append(spec(f"moe.up.{site_tag}.e{ei}",
                           e * dff + ei * dff, ei * d))
            sb.append(spec(f"moe.down.{site_tag}.e{ei}", ei * d, ei * dff))
            names += [f"moe.{p}.{site_tag}.e{ei}"
                      for p in ("gate", "up", "down")]
        key = f"moe:{site_tag}"
        pre = art.plans.get(key) if hasattr(art, "plans") else None
        if pre is not None:
            self.stages = pre
        else:
            self.stages = {
                "a": ops.pack_stage([sa], d_src=e * d, out_dim=2 * e * dff),
                "b": ops.pack_stage([sb], d_src=e * dff, out_dim=e * d)}
            if hasattr(art, "plans"):
                art.plans[key] = self.stages
        self.covered = frozenset(names)
        self.d_ff_total = e * dff

    def __call__(self, buf):
        """buf [E, C, d] dispatched tokens -> [E, C, d] expert outputs."""
        from repro.kernels import layer_plan

        self.executor.routed.update(self.covered)
        e, c, d = buf.shape
        src = buf.astype(jnp.float32).transpose(0, 2, 1).reshape(e * d, c)
        out = layer_plan.moe_plan_matmul(
            self.stages["a"], self.stages["b"], d_ff_total=self.d_ff_total,
            src=src, interpret=self.executor.interpret)
        return out.reshape(e, d, c).transpose(0, 2, 1).astype(buf.dtype)


def matvecs_from_artifact(artifact, *, include=None, block: int = 128,
                          interpret: bool | None = None) -> dict[str, LCCMatvec]:
    """Per-site :class:`LCCMatvec` table for an artifact's dense records.

    The one place the (name -> record, ``packed=`` lookup) wiring lives —
    both :class:`CompressedExecutor` and the legacy
    ``compress_ffn_for_serving`` build their tables through it.  ``include``
    filters site names (callable or prefix string).
    """
    from repro.core.compress import CompressedDense

    keep = (include if callable(include)
            else (lambda n: n.startswith(include)) if include is not None
            else (lambda n: True))
    return {name: LCCMatvec(rec, packed=artifact.packed.get(name),
                            block=block, interpret=interpret)
            for name, rec in artifact.records.items()
            if isinstance(rec, CompressedDense) and keep(name)}


class CompressedExecutor:
    """Site-keyed registry mapping every compressed site of an artifact to a
    fused-kernel callable.

    Protocol consumed by the model decode paths (duck-typed — models never
    import serving):

    * ``matvec(name)``   -> features-major callable ``[K, B] -> [N, B]`` or
      None when the site is not compressed (dense fallback).
    * ``grouped(names)`` -> one-launch callable over a *fused region* (list of
      per-site ``[K_g, B]`` inputs -> list of ``[N_g, B]`` outputs), or None
      unless every name is a compressed dense site.
    * ``conv(name)``     -> :class:`ConvLCC` or None.

    ``routed`` records (at trace time) every site actually served by a fused
    kernel — tests assert it covers the artifact, and the engine reports it.

    Layer plans (``use_plans=True``, the default): on the interpreter path
    the executor additionally builds *layer plans* — ``step_plan(cfg)``
    collapses a whole dense-family decode step into one launch,
    ``moe_plan(...)`` collapses an MoE layer's expert FFNs — and the models
    consult them before falling back to the per-region grouped route.
    Compiled TPU keeps the per-region kernels (the plan kernels are
    gather/scatter-shaped, which Mosaic does not support in-kernel), so
    ``use_plans`` is ANDed with ``resolve_interpret``.
    """

    def __init__(self, artifact, *, block: int = 128,
                 interpret: bool | None = None, use_plans: bool = True):
        from repro.kernels.dispatch import resolve_interpret

        self.artifact = artifact
        self.block = block
        self.interpret = interpret
        self.use_plans = bool(use_plans) and resolve_interpret(interpret)
        self._plans: dict[str, object] = {}
        self._matvecs = matvecs_from_artifact(artifact, block=block,
                                              interpret=interpret)
        self._convs: dict[str, ConvLCC] = {}
        self._groups: dict[tuple, GroupedLCCMatvec | None] = {}
        self.routed: set[str] = set()
        conv_names = [n for n, r in artifact.records.items()
                      if not hasattr(r, "decomposition")]
        if conv_names:
            from repro.models import compress_adapters as ca

            kernels = {s.name: s.kernel(artifact.params)
                       for s in ca.sites_for(artifact.params, artifact.config)
                       if isinstance(s, ca.ConvSite)}
            for name in conv_names:
                cv = ConvLCC(
                    name, kernels[name], artifact.records[name],
                    artifact.unit_config_for(name).conv_method,
                    block=block, interpret=interpret)
                self._convs[name] = cv
                if cv.group is not None and cv.group.waste is not None:
                    artifact.pipeline_stats.setdefault(
                        "padding_waste", {})[name] = cv.group.waste

    @property
    def sites(self) -> set[str]:
        """Every site this executor can serve through a fused kernel."""
        return set(self._matvecs) | set(self._convs)

    def __contains__(self, name: str) -> bool:
        return name in self._matvecs or name in self._convs

    def matvec(self, name: str):
        fn = self._matvecs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn

    def grouped(self, names):
        names = tuple(names)
        if names not in self._groups:
            if all(n in self._matvecs for n in names) and names:
                recs = [self.artifact.records[n] for n in names]
                # reuse the eagerly-packed per-site buffers: group assembly
                # happens at trace time and must only touch concrete arrays
                packed = [self._matvecs[n].packed for n in names]
                g = GroupedLCCMatvec(recs, packed=packed, block=self.block,
                                     interpret=self.interpret)
                self._groups[names] = g
                if g.group.waste is not None:
                    self.artifact.pipeline_stats.setdefault(
                        "padding_waste", {})["+".join(names)] = g.group.waste
            else:
                self._groups[names] = None
        g = self._groups[names]
        if g is not None:
            self.routed.update(names)
        return g

    def conv(self, name: str):
        fn = self._convs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn

    # -- layer plans --------------------------------------------------------

    def step_plan(self, cfg):
        """Whole-decode-step plan for the dense transformer family, or None.

        Built once per executor and cached; eligibility is conservative —
        anything the step kernel does not model (MoE/MLA/ssm/hybrid layers,
        windowed attention, encoder-decoder, learned positions, non-f32
        compute dtype, compiled TPU backend) falls back to the per-region
        grouped route, which covers every family.
        """
        if not self.use_plans:
            return None
        if "step" not in self._plans:
            self._plans["step"] = self._build_step_plan(cfg)
        plan = self._plans["step"]
        if plan is not None:
            self.routed.update(plan.covered)
        return plan

    def _build_step_plan(self, cfg):
        eligible = (
            getattr(cfg, "moe", None) is None
            and getattr(cfg, "mla", None) is None
            and getattr(cfg, "family", "") not in ("ssm", "hybrid")
            and getattr(cfg, "enc_layers", 0) == 0
            and getattr(cfg, "attn_window", None) is None
            and getattr(cfg, "pos", "rope") in ("rope", "none")
            and getattr(cfg, "norm", "rms") in ("rms", "nonparam")
            and jnp.zeros((), cfg.cdtype).dtype == jnp.float32
            and bool(self._matvecs))
        if not eligible:
            return None
        try:
            return StepPlan(self, cfg)
        except Exception as exc:  # defensive: plan failure must not kill decode
            import warnings

            warnings.warn(f"step plan build failed ({exc}); "
                          "falling back to per-region kernels")
            return None

    def moe_plan(self, site_tag: str, *, n_experts: int, d_model: int,
                 d_ff: int):
        """Single-launch plan for one MoE layer's expert FFNs, or None."""
        if not self.use_plans:
            return None
        key = f"moe:{site_tag}"
        if key not in self._plans:
            names = [f"moe.{p}.{site_tag}.e{e}" for e in range(n_experts)
                     for p in ("gate", "up", "down")]
            plan = None
            if (all(n in self._matvecs for n in names)
                    and jnp.zeros((), self.artifact.config.cdtype).dtype
                    == jnp.float32):
                try:
                    plan = MoEPlan(self, site_tag, n_experts=n_experts,
                                   d_model=d_model, d_ff=d_ff)
                except Exception as exc:
                    import warnings

                    warnings.warn(f"moe plan build failed ({exc}); "
                                  "falling back to per-region kernels")
            self._plans[key] = plan
        plan = self._plans[key]
        if plan is not None:
            self.routed.update(plan.covered)
        return plan

    @property
    def n_layer_plans(self) -> int:
        """Distinct layer plans built (a whole-step plan counts once)."""
        return sum(1 for p in self._plans.values() if p is not None)
