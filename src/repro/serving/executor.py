"""Site-keyed compressed execution: route EVERY compressed site through fused
kernels at serving time.

The PR-2 engine routed only dense-family FFN projections through the fused
LCC chain; every other site an adapter can compress (attention q/k/v/o, MoE
experts, RWKV/Mamba mixes, Whisper decoder, ResNet convs) fell back to its
dense-effective weights — the artifact saved memory but not computation.
:class:`CompressedExecutor` closes that gap: built from any
:class:`~repro.core.artifact.CompressedModel`, it maps every adapter site name
(the keys of ``artifact.records``, produced by
``models.compress_adapters.sites_for``) to a fused-kernel callable, and the
model decode paths consult it *inside* the jitted step.

Three kernel routes:

* :class:`LCCMatvec` — one dense site: prune gather -> eq. (10) segment-sum ->
  the whole FP chain in ONE ``lcc_chain_matmul`` launch.
* :class:`GroupedLCCMatvec` — one *fused region*: several sites (an MoE
  layer's experts, an attention layer's q/k/v, RWKV's r/k/v/g) apply their
  chains in ONE ``lcc_group_matmul`` launch, so a decode step pays one
  dispatch per region instead of one per site.
* :class:`ConvLCC` — a conv site executed in the compressed domain: the
  FK/PK reshape of ``core.conv_reshape`` turns the conv into per-channel
  CMVMs and all decomposed channels run as one grouped launch.

Models never import this module — they receive the executor as an opaque
object with the protocol ``matvec(name)``, ``grouped(names)``, ``conv(name)``
(each returning a callable or None) so the dependency stays
serving -> models, never the reverse.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CompressedExecutor", "LCCMatvec", "GroupedLCCMatvec", "ConvLCC",
           "StepPlan", "MoEPlan", "matvecs_from_artifact"]


class LCCMatvec:
    """One compressed projection as a fused-kernel matvec: x [K, B] -> [N, B].

    Prune (kept_columns gather) -> optional weight-sharing segment-sum (paper
    eq. (10)) -> the whole FP decomposition in a single ``lcc_chain_matmul``
    launch.  Built from a ``core.compress.CompressedDense`` record; pass
    ``packed=`` to reuse an artifact's pre-packed kernel buffers instead of
    re-packing the decomposition.

    ``B`` is bucketed to powers of two (pad + slice), so serving many distinct
    decode/prefill batch widths compiles at most log2 variants of the fused
    chain instead of one per width.
    """

    def __init__(self, cd, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = cd.name
        self.packed = (packed if packed is not None
                       else ops.pack_decomposition(cd.decomposition, block))
        self.kept = jnp.asarray(np.asarray(cd.kept_columns), jnp.int32)
        self.labels = (jnp.asarray(cd.shared.labels, jnp.int32)
                       if cd.shared is not None else None)
        self.n_clusters = cd.shared.n_clusters if cd.shared is not None else 0
        self.interpret = interpret
        # jit the whole chain (gather -> segment-sum -> fused kernel) so a
        # per-token decode loop pays one dispatch, not one per slice/stage
        self._fn = jax.jit(self._run)

    def _run(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops

        xk = x[self.kept]
        if self.labels is not None:
            xk = ops.segment_sum_tpu(self.labels, xk, self.n_clusters,
                                     interpret=self.interpret)
        return ops.apply_packed_decomposition(self.packed, xk,
                                              interpret=self.interpret)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        vec = x.ndim == 1
        if vec:
            x = x[:, None]
        b = x.shape[1]
        b_pad = 1 << (b - 1).bit_length()  # next power of two (b=1 -> 1)
        if b_pad != b:
            x = jnp.pad(x, ((0, 0), (0, b_pad - b)))
        y = self._fn(x)
        return y[:, 0] if vec else y[:, :b]


class GroupedLCCMatvec:
    """Several compressed sites applied in ONE fused launch (a *fused region*).

    Call with a per-site list of features-major inputs ``[K_g, B]`` (all the
    same batch width; input widths may differ — each member gathers its own
    kept columns and segment-sums its own clusters before the shared
    ``lcc_group_matmul`` dispatch).  Returns the per-site ``[N_g, B]`` outputs.
    """

    def __init__(self, records, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        packed = packed or [None] * len(records)
        members = [pk if pk is not None
                   else ops.pack_decomposition(cd.decomposition, block)
                   for cd, pk in zip(records, packed)]
        self.names = tuple(cd.name for cd in records)
        self.group = ops.pack_group(members)
        # cached state stays numpy: groups are assembled lazily — the first
        # decode trace to name this fused region builds the object — and numpy
        # constants embed per-trace instead of leaking that trace's tracers
        self.kept = [np.asarray(cd.kept_columns, np.int32) for cd in records]
        self.labels = [np.asarray(cd.shared.labels, np.int32)
                       if cd.shared is not None else None for cd in records]
        self.n_clusters = [cd.shared.n_clusters if cd.shared is not None else 0
                           for cd in records]
        self.interpret = interpret
        self._fn = jax.jit(self._run)

    def _run(self, xs):
        from repro.kernels import ops

        prep = []
        for x, kept, labels, nc in zip(xs, self.kept, self.labels,
                                       self.n_clusters):
            xk = x[kept]
            if labels is not None:
                xk = ops.segment_sum_tpu(labels, xk, nc,
                                         interpret=self.interpret)
            prep.append(xk)
        return tuple(ops.apply_packed_group(self.group, prep,
                                            interpret=self.interpret))

    def __call__(self, xs) -> list[jnp.ndarray]:
        b = xs[0].shape[1]
        b_pad = 1 << (b - 1).bit_length()
        if b_pad != b:
            xs = [jnp.pad(x, ((0, 0), (0, b_pad - b))) for x in xs]
        ys = self._fn(tuple(xs))
        return [y[:, :b] for y in ys]


class ConvLCC:
    """One compressed conv layer executed in the compressed domain.

    Decomposed input channels run their FK/PK CMVM chains in ONE grouped
    launch over ``core.conv_reshape``'s window extraction; channels without a
    decomposition (subsampled-out or pruned) go through a dense conv on the
    residual kernel.  Matches ``lax.conv`` SAME/VALID semantics including
    stride, so ``resnet_forward(..., executor=...)`` is a drop-in.
    """

    def __init__(self, name: str, kernel: np.ndarray, record: dict,
                 method: str, *, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = name
        self.method = method
        self.n, _, self.o, _ = kernel.shape
        self.channels = sorted(record["decompositions"])
        packed = [ops.pack_decomposition(record["decompositions"][ch], block)
                  for ch in self.channels]
        self.group = ops.pack_group(packed) if packed else None
        rest = np.asarray(kernel, np.float32).copy()
        rest[:, self.channels] = 0.0  # chain channels leave the dense conv
        self.rest = jnp.asarray(rest)
        self.has_rest = bool(np.abs(rest).max() > 0)
        self.interpret = interpret
        self._fn = jax.jit(self._run, static_argnames=("stride", "padding"))

    def _run(self, x: jnp.ndarray, stride: int = 1, padding: str = "SAME"
             ) -> jnp.ndarray:
        from jax import lax

        from repro.core.conv_reshape import (extract_patches,
                                             extract_vert_windows, same_pad_2d)
        from repro.kernels import ops

        b, k, z, _ = x.shape
        o = self.o
        if padding == "SAME":
            lo, hi = same_pad_2d(z, o, stride)
            xp = jnp.pad(x, ((0, 0), (0, 0), (lo, hi), (lo, hi)))
        else:
            xp = x
        zp = xp.shape[2]
        p = (zp - o) // stride + 1
        y = None
        if self.has_rest:
            y = lax.conv_general_dilated(
                xp.astype(jnp.float32), self.rest, (stride, stride), "VALID",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if self.group is not None:
            xc = xp[:, jnp.asarray(self.channels, jnp.int32)]
            if self.method == "fk":
                pat = extract_patches(xc, o, stride)  # [B, C, P, P, O, O]
                xs = [pat[:, i].reshape(b * p * p, o * o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                yc = sum(ys)  # [N, B*P*P]
                yc = jnp.moveaxis(yc.T.reshape(b, p, p, self.n), -1, 1)
            else:  # pk: rows (n, j) are kernel columns over vertical windows
                win = extract_vert_windows(xc, o, stride)  # [B, C, P, Zp, O]
                xs = [win[:, i].reshape(b * p * zp, o).T
                      for i in range(len(self.channels))]
                ys = ops.apply_packed_group(self.group, xs,
                                            interpret=self.interpret)
                # sum channel parts, then gather the j-offset columns:
                # y[b, n, p, q] = sum_j part[b, p, q*stride + j, n, j]
                part = sum(ys)  # [N*O, B*P*Zp]
                part = part.reshape(self.n, o, b, p, zp)
                part = jnp.transpose(part, (2, 3, 4, 0, 1))  # [B, P, Zp, N, O]
                cq = stride * jnp.arange(p)[:, None] + jnp.arange(o)[None, :]
                sel = part[:, :, cq]  # [B, P, Q, O(j), N, O(j')]
                yc = jnp.moveaxis(jnp.einsum("bpqjnj->bpqn", sel), -1, 1)
            y = yc if y is None else y + yc
        if y is None:
            raise ValueError(f"conv site {self.name!r}: nothing to execute")
        return y.astype(x.dtype)

    def __call__(self, x: jnp.ndarray, *, stride: int = 1,
                 padding: str = "SAME") -> jnp.ndarray:
        return self._fn(x, stride=stride, padding=padding)


def _mesh_wrap(fn, b: int, *, batch_axes, out_axes, replicate=False,
               mesh=None):
    """Wrap a layer-plan kernel call in ``shard_map`` when serving under a
    device mesh, so each shard runs the one-launch plan over its local slots.

    ``batch_axes``/``out_axes`` give the batch(-slot) axis position of each
    positional argument / output.  Stage buffers are trace-time constants and
    embed replicated per shard.  ``replicate=True`` (MoE plans) keeps the
    batch axis unsplit: router rank and capacity are global-batch ops, so
    slot-splitting would change the routing — every shard then computes the
    identical full step, which still dodges the GSPMD partitioner that the
    interpreter-mode kernel cannot pass through.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.distributed import act_shard
    from repro.distributed import sharding as shd

    if mesh is None:
        mesh = act_shard.get_mesh()
    if mesh is None:
        return fn
    bspec = None if replicate else shd.plan_batch_spec(mesh, b)

    def pspec(ax):
        if bspec is None:
            return P()
        return P(*([None] * ax + [bspec]))

    return compat.shard_map(
        fn, mesh=mesh,
        in_specs=tuple(pspec(a) for a in batch_axes),
        out_specs=tuple(pspec(a) for a in out_axes),
        check_vma=False)


class StepPlan:
    """Whole-decode-step layer plan for the dense transformer family.

    Packs every site of every layer — attention q/k/v/o and FFN gate/up/down,
    compressed (CSD shift-add segments) or not (baked dense blocks) — into
    four stacked :class:`~repro.kernels.ops.PackedStage` buffers and executes
    the full step as ONE ``pallas_call`` with grid ``(L,)``
    (:func:`repro.kernels.layer_plan.step_plan_matmul`).  KV write-back runs
    outside the kernel, vectorized over layers, for both contiguous and paged
    caches.

    MoE families (``cfg.moe``): the FFN stages become the two *expert
    super-stages* — "eg" (all experts' gates+ups, e-major ``[E*d] ->
    [2*E*dff]``) and "ed" (all downs, ``[E*dff] -> [E*d]``) — and the router
    weights ride along as a trace-time constant so the whole routed block
    (softmax/top-k, capacity dispatch, SwiGLU, gated combine) runs *inside*
    the single step launch.

    Under a device mesh, :meth:`decode_layers` wraps the kernel in
    ``shard_map``: activations and the KV view split on the batch/slot axis
    over ("pod","data") while the stage buffers — trace-time constants —
    embed replicated per shard, so distributed serving keeps the one
    launch-per-plan step.
    """

    def __init__(self, executor, cfg):
        from repro.kernels import ops

        self.executor = executor
        self.cfg = cfg
        art = executor.artifact
        blocks = art.params["blocks"]
        d, dff = cfg.d_model, cfg.d_ff
        nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        covered: list[str] = []

        def spec(name, w_stack, li, out_off, src_off=0, bias_stack=None):
            rec = art.records.get(name)
            # np.asarray BEFORE indexing: the plan may build lazily inside a
            # jit trace, where even slicing a concrete constant binds a traced
            # op — converting the whole stack first keeps the build pure-host
            bias = (np.asarray(bias_stack, np.float32)[li]
                    if bias_stack is not None else None)
            if rec is None or not hasattr(rec, "decomposition"):
                # uncovered site: bake its dense weights into the stage so the
                # plan still emits the layer's full output
                return {"kind": "dense", "out_off": out_off,
                        "src_off": src_off,
                        "w": np.asarray(w_stack, np.float32)[li],
                        "bias": bias}
            covered.append(name)
            packed = art.packed.get(name)
            if packed is None:
                packed = ops.pack_decomposition(rec.decomposition,
                                                executor.block)
            return {"kind": "lcc", "name": name, "out_off": out_off,
                    "src_off": src_off,
                    "kept": np.asarray(rec.kept_columns, np.int64),
                    "labels": (np.asarray(rec.shared.labels, np.int64)
                               if rec.shared is not None else None),
                    "n_clusters": (rec.shared.n_clusters
                                   if rec.shared is not None else 0),
                    "packed": packed, "bias": bias}

        ab, fb = blocks["attn"], blocks["ffn"]
        qkv, o_ = [], []
        for li in range(cfg.n_layers):
            qkv.append([spec(f"attn.q.l{li}", ab["q"]["w"], li, 0,
                             bias_stack=ab["q"].get("b")),
                        spec(f"attn.k.l{li}", ab["k"]["w"], li, nq * hd,
                             bias_stack=ab["k"].get("b")),
                        spec(f"attn.v.l{li}", ab["v"]["w"], li, (nq + nkv) * hd,
                             bias_stack=ab["v"].get("b"))])
            o_.append([spec(f"attn.o.l{li}", ab["o"]["w"], li, 0,
                            bias_stack=ab["o"].get("b"))])
        stage_specs = {"qkv": (qkv, d, (nq + 2 * nkv) * hd),
                       "o": (o_, nq * hd, d)}
        self.moe = None
        if getattr(cfg, "moe", None) is None:
            gu, dn = [], []
            for li in range(cfg.n_layers):
                gu.append([spec(f"ffn.gate.l{li}", fb["gate"]["w"], li, 0,
                                bias_stack=fb["gate"].get("b")),
                           spec(f"ffn.up.l{li}", fb["up"]["w"], li, dff,
                                bias_stack=fb["up"].get("b"))])
                dn.append([spec(f"ffn.down.l{li}", fb["down"]["w"], li, 0,
                                bias_stack=fb["down"].get("b"))])
            stage_specs["gu"] = (gu, d, 2 * dff)
            stage_specs["dn"] = (dn, dff, d)
        else:
            ne, edff = cfg.moe.n_experts, cfg.moe.d_ff_expert
            gw = np.asarray(fb["gate"], np.float32)  # [L, E, d, dff]
            uw = np.asarray(fb["up"], np.float32)
            dw = np.asarray(fb["down"], np.float32)  # [L, E, dff, d]
            eg, ed = [], []
            for li in range(cfg.n_layers):
                a_sites, b_sites = [], []
                for ei in range(ne):
                    a_sites.append(spec(f"moe.gate.l{li}.e{ei}", gw[:, ei],
                                        li, ei * edff, ei * d))
                    a_sites.append(spec(f"moe.up.l{li}.e{ei}", uw[:, ei],
                                        li, ne * edff + ei * edff, ei * d))
                    b_sites.append(spec(f"moe.down.l{li}.e{ei}", dw[:, ei],
                                        li, ei * d, ei * edff))
                eg.append(a_sites)
                ed.append(b_sites)
            stage_specs["eg"] = (eg, ne * d, 2 * ne * edff)
            stage_specs["ed"] = (ed, ne * edff, ne * d)
            self.moe = {"router": np.asarray(fb["router"], np.float32),
                        "n_experts": ne, "top_k": cfg.moe.top_k,
                        "capacity_factor": cfg.moe.capacity_factor,
                        "norm_topk": cfg.moe.norm_topk, "min_capacity": 4,
                        "d_ff": ne * edff}
        pre = art.plans.get("step") if hasattr(art, "plans") else None
        if (pre is not None and set(pre) == set(stage_specs)
                and all(ps.n_layers == cfg.n_layers for ps in pre.values())):
            self.stages = pre  # artifact shipped plan-ready packed buffers
        else:
            self.stages = ops.pack_layer(stage_specs)
            if hasattr(art, "plans"):
                art.plans["step"] = self.stages
        stats = getattr(art, "pipeline_stats", None)
        if stats is not None:
            for name, ps in self.stages.items():
                if ps.waste is not None:
                    stats.setdefault("padding_waste",
                                     {})[f"plan.{name}"] = ps.waste
                if ps.seg_stats is not None:
                    stats.setdefault("segment_layout",
                                     {})[f"plan.{name}"] = ps.seg_stats
        self.ln1 = (np.asarray(blocks["ln1"], np.float32)
                    if cfg.norm == "rms" else None)
        self.ln2 = (np.asarray(blocks["ln2"], np.float32)
                    if cfg.norm == "rms" else None)
        self.covered = frozenset(covered)

    def decode_layers(self, state, x, pos):
        """x [B, 1, d] embedded tokens -> (x' [B, 1, d], new kv state)."""
        from repro.kernels import layer_plan
        from repro.models.layers import _rope_sincos

        cfg = self.cfg
        self.executor.routed.update(self.covered)
        k_state, v_state, kpos = state["k"], state["v"], state["kpos"]
        tbl = state.get("block_tbl")
        nl, nkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        b = x.shape[0]
        if tbl is not None:  # paged: pre-gather the table's view for the kernel
            kc = k_state[:, tbl].reshape(nl, b, -1, nkv, hd)
            vc = v_state[:, tbl].reshape(nl, b, -1, nkv, hd)
        else:
            kc, vc = k_state, v_state
        pos = pos.astype(jnp.int32)
        cos = sin = None
        rope = cfg.pos == "rope"
        if rope:
            sin, cos = _rope_sincos(pos, hd, cfg.rope_theta)

        def run(x0, pos_, cos_, sin_, kc_, vc_, kpos_):
            return layer_plan.step_plan_matmul(
                self.stages, n_heads=cfg.n_heads, n_kv_heads=nkv, head_dim=hd,
                d_ff=cfg.d_ff, norm=cfg.norm, rope=rope,
                x0=x0, pos=pos_, cos=cos_, sin=sin_,
                ln1=self.ln1, ln2=self.ln2, kc=kc_, vc=vc_, kpos=kpos_,
                moe=self.moe, window=cfg.attn_window,
                interpret=self.executor.interpret)

        args = (x[:, 0, :].astype(jnp.float32).T, pos, cos, sin, kc, vc, kpos)
        run = _mesh_wrap(run, b, batch_axes=(1, 0, 0, 0, 1, 1, 1),
                         out_axes=(1, 1, 1),
                         replicate=self.moe is not None,
                         mesh=self.executor.mesh)
        y, kn, vn = run(*args)
        dt = k_state.dtype
        kn, vn = kn.astype(dt), vn.astype(dt)
        win = cfg.attn_window
        if tbl is None:
            smax = k_state.shape[2]
            # sliding window: the cache is a ring buffer, slot = pos % smax
            slot = (jnp.where(pos >= 0, pos % smax, -1) if win is not None
                    else pos)
            # row scatter, not a one-hot merge: rewriting the full [L,B,S,...]
            # cache twice per step costs more than the attention einsums
            active = slot >= 0
            safe = jnp.where(active, slot, 0)
            bi = jnp.arange(b)
            am = active[None, :, None, None]
            new = {"k": k_state.at[:, bi, safe].set(
                       jnp.where(am, kn, k_state[:, bi, safe])),
                   "v": v_state.at[:, bi, safe].set(
                       jnp.where(am, vn, v_state[:, bi, safe])),
                   "kpos": kpos.at[:, bi, safe].set(
                       jnp.where(active[None], pos[None], kpos[:, bi, safe]))}
        else:
            bs = k_state.shape[2]
            w = jnp.maximum(pos, 0)
            if win is not None:
                w = w % kpos.shape[2]  # ring over the paged view
            bidx = jnp.take_along_axis(tbl, (w // bs)[:, None], axis=1)[:, 0]
            # inactive slots (pos == -1) scatter into the null block; their
            # kpos stays -1 so the stale row is never attended to
            bidx = jnp.where(pos >= 0, bidx, 0)
            slot = jnp.where(pos >= 0, w, -1) if win is not None else pos
            sel = jax.nn.one_hot(slot, kpos.shape[2])
            new = {"k": k_state.at[:, bidx, w % bs].set(kn),
                   "v": v_state.at[:, bidx, w % bs].set(vn),
                   "kpos": jnp.where(sel[None] > 0, pos[None, :, None], kpos),
                   "block_tbl": tbl}
        return y.T[:, None, :].astype(x.dtype), new


class MoEPlan:
    """One MoE layer's expert FFNs as a single launch.

    Two stages over flattened expert buffers — A: all gates+ups from
    ``[E*d, C]``, B: all downs from the in-kernel SwiGLU ``[E*dff, C]`` —
    replacing the three grouped ``expert_mm`` dispatches per layer.
    """

    def __init__(self, executor, site_tag: str, *, n_experts: int,
                 d_model: int, d_ff: int):
        from repro.kernels import ops

        self.executor = executor
        art = executor.artifact
        e, d, dff = n_experts, d_model, d_ff

        def spec(name, out_off, src_off):
            rec = art.records[name]
            packed = art.packed.get(name)
            if packed is None:
                packed = ops.pack_decomposition(rec.decomposition,
                                                executor.block)
            return {"kind": "lcc", "name": name, "out_off": out_off,
                    "src_off": src_off,
                    "kept": np.asarray(rec.kept_columns, np.int64),
                    "labels": (np.asarray(rec.shared.labels, np.int64)
                               if rec.shared is not None else None),
                    "n_clusters": (rec.shared.n_clusters
                                   if rec.shared is not None else 0),
                    "packed": packed, "bias": None}

        sa, sb, names = [], [], []
        for ei in range(e):
            sa.append(spec(f"moe.gate.{site_tag}.e{ei}", ei * dff, ei * d))
            sa.append(spec(f"moe.up.{site_tag}.e{ei}",
                           e * dff + ei * dff, ei * d))
            sb.append(spec(f"moe.down.{site_tag}.e{ei}", ei * d, ei * dff))
            names += [f"moe.{p}.{site_tag}.e{ei}"
                      for p in ("gate", "up", "down")]
        key = f"moe:{site_tag}"
        pre = art.plans.get(key) if hasattr(art, "plans") else None
        if pre is not None:
            self.stages = pre
        else:
            self.stages = {
                "a": ops.pack_stage([sa], d_src=e * d, out_dim=2 * e * dff),
                "b": ops.pack_stage([sb], d_src=e * dff, out_dim=e * d)}
            if hasattr(art, "plans"):
                art.plans[key] = self.stages
        self.covered = frozenset(names)
        self.d_ff_total = e * dff

    def __call__(self, buf):
        """buf [E, C, d] dispatched tokens -> [E, C, d] expert outputs."""
        from repro.kernels import layer_plan

        self.executor.routed.update(self.covered)
        e, c, d = buf.shape
        src = buf.astype(jnp.float32).transpose(0, 2, 1).reshape(e * d, c)
        out = layer_plan.moe_plan_matmul(
            self.stages["a"], self.stages["b"], d_ff_total=self.d_ff_total,
            src=src, interpret=self.executor.interpret)
        return out.reshape(e, d, c).transpose(0, 2, 1).astype(buf.dtype)


def matvecs_from_artifact(artifact, *, include=None, block: int = 128,
                          interpret: bool | None = None) -> dict[str, LCCMatvec]:
    """Per-site :class:`LCCMatvec` table for an artifact's dense records.

    The one place the (name -> record, ``packed=`` lookup) wiring lives —
    both :class:`CompressedExecutor` and the legacy
    ``compress_ffn_for_serving`` build their tables through it.  ``include``
    filters site names (callable or prefix string).
    """
    from repro.core.compress import CompressedDense

    keep = (include if callable(include)
            else (lambda n: n.startswith(include)) if include is not None
            else (lambda n: True))
    return {name: LCCMatvec(rec, packed=artifact.packed.get(name),
                            block=block, interpret=interpret)
            for name, rec in artifact.records.items()
            if isinstance(rec, CompressedDense) and keep(name)}


def _plan_ineligible_reason(cfg, has_sites: bool) -> str | None:
    """Why ``cfg`` cannot take the whole-step plan route (None = eligible).

    The reason strings feed ``serving_plan_fallbacks_total{reason}`` and
    ``Engine.plan_stats()``, so a bench row can explain a missing plan.
    """
    if getattr(cfg, "mla", None) is not None:
        return "mla"
    family = getattr(cfg, "family", "")
    if family in ("ssm", "hybrid"):
        return f"family:{family}"
    if getattr(cfg, "enc_layers", 0) != 0:
        return "encoder_decoder"
    pos = getattr(cfg, "pos", "rope")
    if pos not in ("rope", "none"):
        return f"pos:{pos}"
    norm = getattr(cfg, "norm", "rms")
    if norm not in ("rms", "nonparam"):
        return f"norm:{norm}"
    if jnp.zeros((), cfg.cdtype).dtype != jnp.float32:
        return "cdtype"
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        if getattr(cfg, "moe_manual", False):
            return "moe_manual"  # manual EP shards experts across devices
        if getattr(moe, "n_shared", 0) > 0:
            return "moe_shared"  # shared experts keep their own site route
    if not has_sites:
        return "no_sites"
    return None


class CompressedExecutor:
    """Site-keyed registry mapping every compressed site of an artifact to a
    fused-kernel callable.

    Protocol consumed by the model decode paths (duck-typed — models never
    import serving):

    * ``matvec(name)``   -> features-major callable ``[K, B] -> [N, B]`` or
      None when the site is not compressed (dense fallback).
    * ``grouped(names)`` -> one-launch callable over a *fused region* (list of
      per-site ``[K_g, B]`` inputs -> list of ``[N_g, B]`` outputs), or None
      unless every name is a compressed dense site.
    * ``conv(name)``     -> :class:`ConvLCC` or None.

    ``routed`` records (at trace time) every site actually served by a fused
    kernel — tests assert it covers the artifact, and the engine reports it.

    Layer plans (``use_plans=True``, the default): on the interpreter path
    the executor additionally builds *layer plans* — ``step_plan(cfg)``
    collapses a whole dense-family decode step into one launch,
    ``moe_plan(...)`` collapses an MoE layer's expert FFNs — and the models
    consult them before falling back to the per-region grouped route.
    Compiled TPU keeps the per-region kernels (the plan kernels are
    gather/scatter-shaped, which Mosaic does not support in-kernel), so
    ``use_plans`` is ANDed with ``resolve_interpret``.
    """

    def __init__(self, artifact, *, block: int = 128,
                 interpret: bool | None = None, use_plans: bool = True,
                 mesh=None):
        from repro.kernels.dispatch import resolve_interpret

        self.artifact = artifact
        self.block = block
        self.interpret = interpret
        # device mesh for plan shard_map (serving engines pass theirs; None
        # falls back to the act_shard context, e.g. under launch/train)
        self.mesh = mesh
        self.use_plans = bool(use_plans) and resolve_interpret(interpret)
        # plan key ("step" / "moe:<tag>") -> why it fell back to the
        # per-region route; the engine publishes these as
        # serving_plan_fallbacks_total{reason} and plan_stats() reports them
        self.plan_fallbacks: dict[str, str] = {}
        self._disabled_reason = (
            None if self.use_plans
            else ("plans_disabled" if not use_plans else "not_interpret"))
        self._plans: dict[str, object] = {}
        self._matvecs = matvecs_from_artifact(artifact, block=block,
                                              interpret=interpret)
        # record ineligibility eagerly so engines over families whose decode
        # path never consults step_plan() (ssm/hybrid/...) still surface a
        # reason in plan_stats() / serving_plan_fallbacks_total
        if self.use_plans and hasattr(artifact.config, "family"):
            reason = _plan_ineligible_reason(artifact.config,
                                             bool(self._matvecs))
            if reason is not None:
                self.plan_fallbacks.setdefault("step", reason)
        self._convs: dict[str, ConvLCC] = {}
        self._groups: dict[tuple, GroupedLCCMatvec | None] = {}
        self.routed: set[str] = set()
        conv_names = [n for n, r in artifact.records.items()
                      if not hasattr(r, "decomposition")]
        if conv_names:
            from repro.models import compress_adapters as ca

            kernels = {s.name: s.kernel(artifact.params)
                       for s in ca.sites_for(artifact.params, artifact.config)
                       if isinstance(s, ca.ConvSite)}
            for name in conv_names:
                cv = ConvLCC(
                    name, kernels[name], artifact.records[name],
                    artifact.unit_config_for(name).conv_method,
                    block=block, interpret=interpret)
                self._convs[name] = cv
                if cv.group is not None and cv.group.waste is not None:
                    artifact.pipeline_stats.setdefault(
                        "padding_waste", {})[name] = cv.group.waste

    @property
    def sites(self) -> set[str]:
        """Every site this executor can serve through a fused kernel."""
        return set(self._matvecs) | set(self._convs)

    def __contains__(self, name: str) -> bool:
        return name in self._matvecs or name in self._convs

    def matvec(self, name: str):
        fn = self._matvecs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn

    def grouped(self, names):
        names = tuple(names)
        if names not in self._groups:
            if all(n in self._matvecs for n in names) and names:
                recs = [self.artifact.records[n] for n in names]
                # reuse the eagerly-packed per-site buffers: group assembly
                # happens at trace time and must only touch concrete arrays
                packed = [self._matvecs[n].packed for n in names]
                g = GroupedLCCMatvec(recs, packed=packed, block=self.block,
                                     interpret=self.interpret)
                self._groups[names] = g
                if g.group.waste is not None:
                    self.artifact.pipeline_stats.setdefault(
                        "padding_waste", {})["+".join(names)] = g.group.waste
            else:
                self._groups[names] = None
        g = self._groups[names]
        if g is not None:
            self.routed.update(names)
        return g

    def conv(self, name: str):
        fn = self._convs.get(name)
        if fn is not None:
            self.routed.add(name)
        return fn

    # -- layer plans --------------------------------------------------------

    def step_plan(self, cfg):
        """Whole-decode-step plan for the transformer families, or None.

        Built once per executor and cached; eligibility is conservative —
        anything the step kernel does not model (MLA/ssm/hybrid layers,
        manual-EP or shared-expert MoE, encoder-decoder, learned positions,
        non-f32 compute dtype, compiled TPU backend) falls back to the
        per-region grouped route, which covers every family.  Every fallback
        records its reason in :attr:`plan_fallbacks`.
        """
        if not self.use_plans:
            self.plan_fallbacks.setdefault("step", self._disabled_reason)
            return None
        if "step" not in self._plans:
            reason = _plan_ineligible_reason(cfg, bool(self._matvecs))
            plan = None
            if reason is None:
                try:
                    plan = StepPlan(self, cfg)
                except Exception as exc:  # defensive: plan failure must not
                    import warnings  # kill decode

                    warnings.warn(f"step plan build failed ({exc}); "
                                  "falling back to per-region kernels")
                    reason = f"build_error:{type(exc).__name__}"
            if reason is not None:
                self.plan_fallbacks["step"] = reason
            self._plans["step"] = plan
        plan = self._plans["step"]
        if plan is not None:
            self.routed.update(plan.covered)
        return plan

    def moe_plan(self, site_tag: str, *, n_experts: int, d_model: int,
                 d_ff: int):
        """Single-launch plan for one MoE layer's expert FFNs, or None."""
        key = f"moe:{site_tag}"
        if not self.use_plans:
            self.plan_fallbacks.setdefault(key, self._disabled_reason)
            return None
        if key not in self._plans:
            names = [f"moe.{p}.{site_tag}.e{e}" for e in range(n_experts)
                     for p in ("gate", "up", "down")]
            plan, reason = None, None
            if not all(n in self._matvecs for n in names):
                reason = "moe_sites_missing"
            elif jnp.zeros((), self.artifact.config.cdtype).dtype \
                    != jnp.float32:
                reason = "cdtype"
            else:
                try:
                    plan = MoEPlan(self, site_tag, n_experts=n_experts,
                                   d_model=d_model, d_ff=d_ff)
                except Exception as exc:
                    import warnings

                    warnings.warn(f"moe plan build failed ({exc}); "
                                  "falling back to per-region kernels")
                    reason = f"build_error:{type(exc).__name__}"
            if reason is not None:
                self.plan_fallbacks[key] = reason
            self._plans[key] = plan
        plan = self._plans[key]
        if plan is not None:
            self.routed.update(plan.covered)
        return plan

    @property
    def n_layer_plans(self) -> int:
        """Distinct layer plans built (a whole-step plan counts once)."""
        return sum(1 for p in self._plans.values() if p is not None)
