"""Batched serving engine: prefill + decode with slot-based continuous batching.

The engine keeps a fixed decode batch of ``n_slots``; finished sequences free
their slot and queued requests are prefilled into it (KV written at their
positions).  Greedy or temperature sampling.  Works for every decode-capable
family through models.api.

Compressed serving is first-class: :func:`compress_ffn_for_serving` runs the
paper's Algorithm 1 over every FFN projection and returns (a) dense-effective
weights for the stock XLA forward and (b) :class:`LCCMatvec` closures per
projection — prune + (optional) weight-sharing segment-sum + the LCC runtime.
FP decompositions run their whole factor chain as ONE fused Pallas launch
(``repro.kernels.lcc_chain_matmul``, the shift-add runtime the paper
targets); FS decompositions evaluate through their dense equivalent.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api, transformer

__all__ = ["ServingEngine", "GenerationResult", "LCCMatvec",
           "compress_ffn_for_serving"]


@dataclass
class GenerationResult:
    tokens: list[int]
    prompt_len: int
    finished: bool


class ServingEngine:
    def __init__(self, params, cfg: ArchConfig, *, n_slots: int = 8,
                 max_len: int = 512, eos_id: int | None = None,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        # per-request decode budget; generate() overrides it per call, but a
        # standalone submit()/step() loop must find it initialized
        self.max_new = max_len
        self.eos = eos_id
        self.temp = temperature
        self.key = jax.random.PRNGKey(seed)
        self.state = api.init_decode_state(cfg, n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self.results: dict[int, GenerationResult] = {}
        self.slot_req: dict[int, int] = {}
        self._next_req = 0
        self._decode = jax.jit(lambda p, s, t, pos: api.decode(p, cfg, s, t, pos))

    # ------------------------------------------------------------------ API
    def submit(self, prompt: list[int]) -> int:
        """Prefill a prompt into a free slot; returns request id."""
        if not prompt:
            raise ValueError("empty prompt: decode needs at least one token")
        if len(prompt) > self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds the "
                             f"engine's max_len={self.max_len} KV cache")
        free = np.where(~self.active)[0]
        if free.size == 0:
            raise RuntimeError("no free slots; call step() until one finishes")
        slot = int(free[0])
        rid = self._next_req
        self._next_req += 1
        # prefill token-by-token through decode (single-request path keeps the
        # cache layout identical; bulk prefill via forward() feeds training)
        for t, tok in enumerate(prompt):
            _logits, self.state = self._decode(
                self.params, self.state,
                self._token_batch(slot, tok), self._pos_batch(slot, t))
        self.pos[slot] = len(prompt)
        self.active[slot] = True
        self.slot_req[slot] = rid
        self.results[rid] = GenerationResult(tokens=list(prompt),
                                             prompt_len=len(prompt), finished=False)
        return rid

    def step(self) -> None:
        """One decode step for every active slot."""
        if not self.active.any():
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            toks[slot, 0] = self.results[rid].tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos - 1, jnp.int32))
        logits = np.asarray(logits, np.float32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            nxt = self._sample(logits[slot])
            r = self.results[rid]
            r.tokens.append(int(nxt))
            self.pos[slot] += 1
            done = (self.eos is not None and nxt == self.eos) or \
                (len(r.tokens) - r.prompt_len >= self.max_new) or \
                (self.pos[slot] >= self.max_len)
            if done:
                r.finished = True
                self.active[slot] = False

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32
                 ) -> list[GenerationResult]:
        """Continuous-batched generation over a request list."""
        prev_max_new = self.max_new  # restored below: the per-call budget must
        self.max_new = max_new_tokens  # not leak into later standalone loops
        queue = list(enumerate(prompts))
        rid_map = {}
        try:
            while queue or self.active.any():
                while queue and (~self.active).any():
                    i, prompt = queue.pop(0)
                    rid_map[self.submit(prompt)] = i
                self.step()
        finally:
            self.max_new = prev_max_new
        out: list[GenerationResult | None] = [None] * len(prompts)
        for rid, i in rid_map.items():
            out[i] = self.results[rid]
        return out  # type: ignore[return-value]

    # -------------------------------------------------------------- helpers
    def _token_batch(self, slot: int, tok: int):
        t = np.zeros((self.n_slots, 1), np.int32)
        t[slot, 0] = tok
        return jnp.asarray(t)

    def _pos_batch(self, slot: int, pos: int):
        p = np.asarray(self.pos - 1, np.int64).clip(0)
        p[slot] = pos
        return jnp.asarray(p, jnp.int32)

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temp))


# ---------------------------------------------------------------- compression


class LCCMatvec:
    """One compressed projection as a fused-kernel matvec: x [K, B] -> [N, B].

    Prune (kept_columns gather) -> optional weight-sharing segment-sum (paper
    eq. (10)) -> the whole FP decomposition in a single ``lcc_chain_matmul``
    launch.  Built from a ``core.compress.CompressedDense`` record.
    """

    def __init__(self, cd, *, block: int = 128, interpret: bool | None = None):
        from repro.kernels import ops

        self.name = cd.name
        self.packed = ops.pack_decomposition(cd.decomposition, block)
        self.kept = jnp.asarray(np.asarray(cd.kept_columns), jnp.int32)
        self.labels = (jnp.asarray(cd.shared.labels, jnp.int32)
                       if cd.shared is not None else None)
        self.n_clusters = cd.shared.n_clusters if cd.shared is not None else 0
        self.interpret = interpret
        # jit the whole chain (gather -> segment-sum -> fused kernel) so a
        # per-token decode loop pays one dispatch, not one per slice/stage
        self._fn = jax.jit(self._run)

    def _run(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops

        xk = x[self.kept]
        if self.labels is not None:
            xk = ops.segment_sum_tpu(self.labels, xk, self.n_clusters,
                                     interpret=self.interpret)
        return ops.apply_packed_decomposition(self.packed, xk,
                                              interpret=self.interpret)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if x.ndim == 1:
            return self._fn(x[:, None])[:, 0]
        return self._fn(x)


def compress_ffn_for_serving(params, cfg: ArchConfig, compression=None, *,
                             report=None, interpret: bool | None = None,
                             build_matvecs: bool = True):
    """Algorithm 1 over every FFN projection of a dense transformer.

    Returns ``(params_c, matvecs, report)``: ``params_c`` are the original
    params with FFN weights replaced by their compressed dense equivalent
    (drop-in for the stock XLA forward, used by :class:`ServingEngine`);
    ``matvecs[proj][layer]`` is the :class:`LCCMatvec` running the same map on
    the fused shift-add kernel path.  ``build_matvecs=False`` skips the
    packing + device upload when the caller only wants the dense-effective
    params (``matvecs`` comes back empty).
    """
    from repro import core

    if cfg.moe is not None or cfg.family in ("ssm", "hybrid") or cfg.enc_layers:
        raise ValueError(
            f"FFN compression targets dense-FFN architectures, not {cfg.family!r} "
            "(MoE/SSM/hybrid/encoder-decoder FFNs need per-family adapters)")
    if compression is None:
        compression = core.CompressionConfig(algorithm="fs", weight_sharing=True,
                                             max_share_rel_err=0.06)
    if report is None:
        report = core.ModelCostReport()
    ffn = params["blocks"]["ffn"]
    new_ffn = dict(ffn)
    matvecs: dict[str, list[LCCMatvec]] = {}
    for proj in ("gate", "up", "down"):
        stack = np.asarray(ffn[proj]["w"], np.float64)
        eff_stack, mvs = [], []
        for li in range(stack.shape[0]):
            w = stack[li].T  # act as y = W x (paper layout)
            cd = core.compress_dense_matrix(f"ffn.{proj}.l{li}", w,
                                            compression, report)
            eff = np.zeros_like(w)
            eff[:, cd.kept_columns] = cd.effective
            eff_stack.append(eff.T.astype(np.float32))
            if build_matvecs:
                mvs.append(LCCMatvec(cd, interpret=interpret))
        new_ffn[proj] = {"w": jnp.asarray(np.stack(eff_stack))}
        matvecs[proj] = mvs
    params_c = dict(params)
    params_c["blocks"] = {**params["blocks"], "ffn": new_ffn}
    return params_c, matvecs, report
