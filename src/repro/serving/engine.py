"""Batched serving engine: prefill + decode with slot-based continuous batching.

The engine keeps a fixed decode batch of ``n_slots``; finished sequences free
their slot and queued requests are prefilled into it (one bulk ``api.prefill``
writes the slot's KV cache in a single forward).  Greedy or temperature
sampling.  Works for every decode-capable family through models.api.

Compressed serving is first-class and artifact-driven: compress offline with
``models.api.compress_model``, save the :class:`~repro.core.artifact.
CompressedModel`, and construct ``ServingEngine(artifact=art)``.  The engine
serves the artifact's dense-effective params and — for dense-FFN families —
routes every FFN projection through :class:`LCCMatvec` *inside* the jitted
decode step, so FP decompositions execute their whole factor chain as ONE
fused Pallas launch (``repro.kernels.lcc_chain_matmul``, the shift-add
runtime the paper targets).  FS decompositions evaluate through their dense
equivalent.  :func:`compress_ffn_for_serving` remains as the legacy
FFN-only wrapper over the same pipeline.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api

__all__ = ["ServingEngine", "GenerationResult", "LCCMatvec",
           "compress_ffn_for_serving"]


@dataclass
class GenerationResult:
    tokens: list[int]
    prompt_len: int
    finished: bool


class ServingEngine:
    """``ServingEngine(params, cfg)`` serves raw weights; ``ServingEngine(
    artifact=compressed_model)`` serves a compression artifact (params and
    config come from the artifact, and FFN projections of dense-FFN families
    run on the fused LCC kernel path unless ``use_kernel=False``)."""

    def __init__(self, params=None, cfg: ArchConfig | None = None, *,
                 artifact=None, n_slots: int = 8,
                 max_len: int = 512, eos_id: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 use_kernel: bool = True, bulk_prefill: bool = True,
                 interpret: bool | None = None):
        if artifact is not None:
            if cfg is None:
                cfg = artifact.config
            if params is None:
                params = artifact.params
        if params is None or cfg is None:
            raise ValueError("ServingEngine needs (params, cfg) or artifact=...")
        self.params = params
        self.cfg = cfg
        self.artifact = artifact
        self.n_slots = n_slots
        self.max_len = max_len
        # per-request decode budget; generate() overrides it per call, but a
        # standalone submit()/step() loop must find it initialized
        self.max_new = max_len
        self.eos = eos_id
        self.temp = temperature
        self.bulk_prefill = bulk_prefill
        self.key = jax.random.PRNGKey(seed)
        self.state = api.init_decode_state(cfg, n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self.results: dict[int, GenerationResult] = {}
        self.slot_req: dict[int, int] = {}
        self._next_req = 0
        self._prefill_fns: dict[int, object] = {}
        self.matvec_overrides = (
            self._build_overrides(artifact, interpret) if use_kernel else None)
        ov = self.matvec_overrides
        self._decode = jax.jit(
            lambda p, s, t, pos: api.decode(p, cfg, s, t, pos,
                                            matvec_overrides=ov))

    @staticmethod
    def _build_overrides(artifact, interpret):
        """Per-layer LCCMatvec table for the FFN projections of a dense-FFN
        artifact (None when the artifact has no routable units)."""
        if artifact is None or api.family_of(artifact.config) not in ("dense", "vlm"):
            return None
        cfg = artifact.config
        ov: dict[str, list] = {}
        for proj in ("gate", "up", "down"):
            fns: list = [None] * cfg.n_layers
            found = False
            for li in range(cfg.n_layers):
                name = f"ffn.{proj}.l{li}"
                rec = artifact.records.get(name)
                if rec is None:
                    continue
                fns[li] = LCCMatvec(rec, packed=artifact.packed.get(name),
                                    interpret=interpret)
                found = True
            if found:
                ov[proj] = fns
        return ov or None

    # ------------------------------------------------------------------ API
    def submit(self, prompt: list[int]) -> int:
        """Prefill a prompt into a free slot; returns request id."""
        if not prompt:
            raise ValueError("empty prompt: decode needs at least one token")
        if len(prompt) > self.max_len:
            raise ValueError(f"prompt of {len(prompt)} tokens exceeds the "
                             f"engine's max_len={self.max_len} KV cache")
        free = np.where(~self.active)[0]
        if free.size == 0:
            raise RuntimeError("no free slots; call step() until one finishes")
        slot = int(free[0])
        rid = self._next_req
        self._next_req += 1
        if self.bulk_prefill and ("k" in self.state or "c_kv" in self.state):
            # one bulk forward writes the whole slot cache (and resets stale
            # kpos entries from the slot's previous occupant)
            self._prefill_slot(slot, prompt)
        else:
            # stateful families (ssm/hybrid) keep the tokenwise path: their
            # per-layer recurrent states live in scan-stacked layouts that a
            # bulk forward does not expose per-slot
            self._prefill_slot_tokenwise(slot, prompt)
        self.pos[slot] = len(prompt)
        self.active[slot] = True
        self.slot_req[slot] = rid
        self.results[rid] = GenerationResult(tokens=list(prompt),
                                             prompt_len=len(prompt), finished=False)
        return rid

    # -------------------------------------------------------------- prefill
    def _prefill_slot_tokenwise(self, slot: int, prompt: list[int]) -> None:
        """Legacy prefill: one decode step per prompt token (kept as the
        fallback for recurrent-state families and as the bulk path's
        equivalence/latency baseline in benchmarks)."""
        for t, tok in enumerate(prompt):
            _logits, self.state = self._decode(
                self.params, self.state,
                self._token_batch(slot, tok), self._pos_batch(slot, t))

    def _prefill_slot(self, slot: int, prompt: list[int]) -> None:
        """Bulk prefill: ONE ``api.prefill`` forward over the prompt writes
        the slot's KV cache at its positions.  Prompts are right-padded to
        power-of-two buckets so recompilation is bounded (log2(max_len)
        buckets); padded positions stay masked via kpos == -1."""
        plen = len(prompt)
        s_pad = min(self.max_len, max(8, 1 << (plen - 1).bit_length()))
        if s_pad not in self._prefill_fns:
            cfg = self.cfg
            self._prefill_fns[s_pad] = jax.jit(
                lambda p, t: api.prefill(p, cfg, {"tokens": t},
                                         collect_cache=True))
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :plen] = prompt
        _h, caches = self._prefill_fns[s_pad](self.params, jnp.asarray(toks))
        st = dict(self.state)
        if "k" in st:
            k_all, v_all = caches  # [L, 1, S_pad, Hkv, Dh]
            eff = st["k"].shape[2]  # ring size when windowed, else max_len
            ps = np.arange(max(0, plen - eff), plen)
            slots = ps % eff if self.cfg.attn_window is not None else ps
            kpos_row = np.full(eff, -1, np.int64)
            kpos_row[slots] = ps
            st["k"] = st["k"].at[:, slot, slots].set(
                k_all[:, 0, ps].astype(st["k"].dtype))
            st["v"] = st["v"].at[:, slot, slots].set(
                v_all[:, 0, ps].astype(st["v"].dtype))
        else:  # MLA latent cache
            c_kv, k_rope = caches  # [L, 1, S_pad, dc] / [L, 1, S_pad, Dr]
            eff = st["c_kv"].shape[2]
            ps = np.arange(plen)
            kpos_row = np.full(eff, -1, np.int64)
            kpos_row[:plen] = ps
            st["c_kv"] = st["c_kv"].at[:, slot, :plen].set(
                c_kv[:, 0, :plen].astype(st["c_kv"].dtype))
            st["k_rope"] = st["k_rope"].at[:, slot, :plen].set(
                k_rope[:, 0, :plen].astype(st["k_rope"].dtype))
        st["kpos"] = st["kpos"].at[:, slot].set(jnp.asarray(kpos_row, jnp.int32))
        self.state = st

    def step(self) -> None:
        """One decode step for every active slot."""
        if not self.active.any():
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            toks[slot, 0] = self.results[rid].tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos - 1, jnp.int32))
        logits = np.asarray(logits, np.float32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            nxt = self._sample(logits[slot])
            r = self.results[rid]
            r.tokens.append(int(nxt))
            self.pos[slot] += 1
            done = (self.eos is not None and nxt == self.eos) or \
                (len(r.tokens) - r.prompt_len >= self.max_new) or \
                (self.pos[slot] >= self.max_len)
            if done:
                r.finished = True
                self.active[slot] = False

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32
                 ) -> list[GenerationResult]:
        """Continuous-batched generation over a request list."""
        prev_max_new = self.max_new  # restored below: the per-call budget must
        self.max_new = max_new_tokens  # not leak into later standalone loops
        queue = list(enumerate(prompts))
        rid_map = {}
        try:
            while queue or self.active.any():
                while queue and (~self.active).any():
                    i, prompt = queue.pop(0)
                    rid_map[self.submit(prompt)] = i
                self.step()
        finally:
            self.max_new = prev_max_new
        out: list[GenerationResult | None] = [None] * len(prompts)
        for rid, i in rid_map.items():
            out[i] = self.results[rid]
        return out  # type: ignore[return-value]

    # -------------------------------------------------------------- helpers
    def _token_batch(self, slot: int, tok: int):
        t = np.zeros((self.n_slots, 1), np.int32)
        t[slot, 0] = tok
        return jnp.asarray(t)

    def _pos_batch(self, slot: int, pos: int):
        p = np.asarray(self.pos - 1, np.int64).clip(0)
        p[slot] = pos
        return jnp.asarray(p, jnp.int32)

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temp))


# ---------------------------------------------------------------- compression


class LCCMatvec:
    """One compressed projection as a fused-kernel matvec: x [K, B] -> [N, B].

    Prune (kept_columns gather) -> optional weight-sharing segment-sum (paper
    eq. (10)) -> the whole FP decomposition in a single ``lcc_chain_matmul``
    launch.  Built from a ``core.compress.CompressedDense`` record; pass
    ``packed=`` to reuse an artifact's pre-packed kernel buffers instead of
    re-packing the decomposition.
    """

    def __init__(self, cd, *, packed=None, block: int = 128,
                 interpret: bool | None = None):
        from repro.kernels import ops

        self.name = cd.name
        self.packed = (packed if packed is not None
                       else ops.pack_decomposition(cd.decomposition, block))
        self.kept = jnp.asarray(np.asarray(cd.kept_columns), jnp.int32)
        self.labels = (jnp.asarray(cd.shared.labels, jnp.int32)
                       if cd.shared is not None else None)
        self.n_clusters = cd.shared.n_clusters if cd.shared is not None else 0
        self.interpret = interpret
        # jit the whole chain (gather -> segment-sum -> fused kernel) so a
        # per-token decode loop pays one dispatch, not one per slice/stage
        self._fn = jax.jit(self._run)

    def _run(self, x: jnp.ndarray) -> jnp.ndarray:
        from repro.kernels import ops

        xk = x[self.kept]
        if self.labels is not None:
            xk = ops.segment_sum_tpu(self.labels, xk, self.n_clusters,
                                     interpret=self.interpret)
        return ops.apply_packed_decomposition(self.packed, xk,
                                              interpret=self.interpret)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        if x.ndim == 1:
            return self._fn(x[:, None])[:, 0]
        return self._fn(x)


def compress_ffn_for_serving(params, cfg: ArchConfig, compression=None, *,
                             report=None, interpret: bool | None = None,
                             build_matvecs: bool = True):
    """Legacy FFN-only wrapper over :func:`models.api.compress_model`.

    Returns ``(params_c, matvecs, report)`` for the FFN projections of a
    dense-FFN transformer: ``params_c`` are the full params with FFN weights
    replaced by their compressed dense equivalent, ``matvecs[proj][layer]``
    the :class:`LCCMatvec` kernels.  Other families are compressed through
    ``api.compress_model`` + ``ServingEngine(artifact=...)`` directly.
    """
    from repro import core

    if cfg.moe is not None or cfg.family in ("ssm", "hybrid") or cfg.enc_layers:
        raise ValueError(
            f"compress_ffn_for_serving wraps the dense-FFN fast path; family "
            f"{cfg.family!r} is served via models.api.compress_model(...) and "
            "ServingEngine(artifact=...)")
    if compression is None:
        compression = core.CompressionConfig(algorithm="fs", weight_sharing=True,
                                             max_share_rel_err=0.06)
    art = api.compress_model(params, cfg, compression, include="ffn.",
                             build_packed=build_matvecs)
    if report is not None:
        for lc in art.report.layers:
            report.add(lc)
    matvecs: dict[str, list[LCCMatvec]] = {}
    if build_matvecs:
        for proj in ("gate", "up", "down"):
            matvecs[proj] = [
                LCCMatvec(art.records[f"ffn.{proj}.l{li}"],
                          packed=art.packed.get(f"ffn.{proj}.l{li}"),
                          interpret=interpret)
                for li in range(cfg.n_layers)]
    return art.params, matvecs, art.report if report is None else report
