"""Batched serving engine: prefill + decode with slot-based continuous batching.

The engine keeps a fixed decode batch of ``n_slots``; finished sequences free
their slot and queued requests are prefilled into it (KV written at their
positions).  Greedy or temperature sampling.  Works for every decode-capable
family through models.api; the compressed-serving example swaps projection
matvecs for LCC kernels at the model level.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api, transformer

__all__ = ["ServingEngine", "GenerationResult"]


@dataclass
class GenerationResult:
    tokens: list[int]
    prompt_len: int
    finished: bool


class ServingEngine:
    def __init__(self, params, cfg: ArchConfig, *, n_slots: int = 8,
                 max_len: int = 512, eos_id: int | None = None,
                 temperature: float = 0.0, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos = eos_id
        self.temp = temperature
        self.key = jax.random.PRNGKey(seed)
        self.state = api.init_decode_state(cfg, n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self.results: dict[int, GenerationResult] = {}
        self.slot_req: dict[int, int] = {}
        self._next_req = 0
        self._decode = jax.jit(lambda p, s, t, pos: api.decode(p, cfg, s, t, pos))

    # ------------------------------------------------------------------ API
    def submit(self, prompt: list[int]) -> int:
        """Prefill a prompt into a free slot; returns request id."""
        free = np.where(~self.active)[0]
        if free.size == 0:
            raise RuntimeError("no free slots; call step() until one finishes")
        slot = int(free[0])
        rid = self._next_req
        self._next_req += 1
        # prefill token-by-token through decode (single-request path keeps the
        # cache layout identical; bulk prefill via forward() feeds training)
        for t, tok in enumerate(prompt):
            logits, self.state = self._decode(
                self.params, self.state,
                self._token_batch(slot, tok), self._pos_batch(slot, t))
        self.pos[slot] = len(prompt)
        self.active[slot] = True
        self.slot_req[slot] = rid
        self.results[rid] = GenerationResult(tokens=list(prompt),
                                             prompt_len=len(prompt), finished=False)
        self._last_logits = logits
        return rid

    def step(self) -> None:
        """One decode step for every active slot."""
        if not self.active.any():
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            toks[slot, 0] = self.results[rid].tokens[-1]
        logits, self.state = self._decode(self.params, self.state,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos - 1, jnp.int32))
        logits = np.asarray(logits, np.float32)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            nxt = self._sample(logits[slot])
            r = self.results[rid]
            r.tokens.append(int(nxt))
            self.pos[slot] += 1
            done = (self.eos is not None and nxt == self.eos) or \
                (len(r.tokens) - r.prompt_len >= self.max_new) or \
                (self.pos[slot] >= self.max_len)
            if done:
                r.finished = True
                self.active[slot] = False

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32
                 ) -> list[GenerationResult]:
        """Continuous-batched generation over a request list."""
        self.max_new = max_new_tokens
        queue = list(enumerate(prompts))
        rid_map = {}
        while queue or self.active.any():
            while queue and (~self.active).any():
                i, prompt = queue.pop(0)
                rid_map[self.submit(prompt)] = i
            self.step()
        out: list[GenerationResult | None] = [None] * len(prompts)
        for rid, i in rid_map.items():
            out[i] = self.results[rid]
        return out  # type: ignore[return-value]

    # -------------------------------------------------------------- helpers
    def _token_batch(self, slot: int, tok: int):
        t = np.zeros((self.n_slots, 1), np.int32)
        t[slot, 0] = tok
        return jnp.asarray(t)

    def _pos_batch(self, slot: int, pos: int):
        p = np.asarray(self.pos - 1, np.int64).clip(0)
        p[slot] = pos
        return jnp.asarray(p, jnp.int32)

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temp))
