"""Batched serving engine: prefill + decode with slot-based continuous batching.

The engine keeps a fixed decode batch of ``n_slots``; finished sequences free
their slot and queued requests are prefilled into it (one bulk ``api.prefill``
writes the slot's KV cache in a single forward).  Decoding is **device-side**:
one jitted dispatch per step fuses the forward pass, greedy/temperature
sampling (per-slot PRNG keys, so draws are independent of slot order and of
which other requests are in flight), position/budget bookkeeping and the
EOS/headroom ``done`` flags — the host receives a single small packed array
(sampled token + emit/done masks) per step instead of round-tripping logits.

Multi-device serving: pass ``mesh=`` and the engine places params with
:func:`repro.distributed.sharding.params_pspecs` (tensor-parallel on the
"model" axis where divisible, FSDP on "data" otherwise) and the KV/decode
state with :func:`~repro.distributed.sharding.decode_state_pspecs` (slots over
the batch axes), then jits the fused step with explicit in/out shardings so
every step runs partitioned without resharding-triggered recompiles.

Scheduling (queues, priorities, admission, streaming callbacks, failed-request
isolation) lives in :class:`repro.serving.scheduler.Scheduler`; ``generate()``
is a thin convenience wrapper over it.

Compressed serving is first-class, artifact-driven and family-agnostic:
compress offline with ``models.api.compress_model``, save the
:class:`~repro.core.artifact.CompressedModel`, and construct
``ServingEngine(artifact=art)``.  The engine builds a site-keyed
:class:`~repro.serving.executor.CompressedExecutor` over the artifact and the
model decode paths consult it *inside* the jitted step — attention q/k/v/o
(and MLA projections), FFN gate/up/down, per-expert MoE matrices (all experts
of a layer in ONE grouped launch), RWKV-6 time/channel mixes, Mamba2 in/out
projections and the whisper decoder all execute their LCC chains as fused
Pallas launches (``lcc_chain_matmul`` / ``lcc_group_matmul``, the shift-add
runtime the paper targets).  FS decompositions evaluate through their dense
equivalent; sites the artifact does not cover stay dense.
:func:`compress_ffn_for_serving` remains as the legacy FFN-only wrapper over
the same pipeline.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models import api
from repro.obs import MetricsRegistry, RequestTracer, StepProfiler
from repro.serving.executor import (CompressedExecutor, LCCMatvec,
                                    matvecs_from_artifact)
from repro.serving.kvpool import KVPool, empty_stats

__all__ = ["ServingEngine", "GenerationResult", "StepEvent", "LCCMatvec",
           "CompressedExecutor", "compress_ffn_for_serving"]


@dataclass
class GenerationResult:
    tokens: list[int]
    prompt_len: int
    finished: bool
    error: str | None = None
    # per-request telemetry the engine learned while serving this request
    # (prefill_s, cached_tokens, blocks_grown, cancelled, exhausted, ...);
    # the scheduler folds it into the request's span at retire
    stats: dict = field(default_factory=dict)


@dataclass(frozen=True)
class StepEvent:
    """One slot's outcome of a decode step: ``token is None`` means the slot
    finished without emitting (no decode headroom)."""
    rid: int
    token: int | None
    finished: bool


class ServingEngine:
    """``ServingEngine(params, cfg)`` serves raw weights; ``ServingEngine(
    artifact=compressed_model)`` serves a compression artifact (params and
    config come from the artifact, and every compressed site — any family —
    runs on the fused LCC kernel path unless ``use_kernel=False``).  Pass
    ``mesh=`` for sharded multi-device decode."""

    def __init__(self, params=None, cfg: ArchConfig | None = None, *,
                 artifact=None, n_slots: int = 8,
                 max_len: int = 512, eos_id: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 use_kernel: bool = True, bulk_prefill: bool = True,
                 interpret: bool | None = None, mesh=None,
                 kv_block: int | None = 16, kv_blocks: int | None = None,
                 prefix_cache: bool = True, metrics=None, tracer=None,
                 fence_every: int = 32):
        if artifact is not None:
            if cfg is None:
                cfg = artifact.config
            if params is None:
                params = artifact.params
        if params is None or cfg is None:
            raise ValueError("ServingEngine needs (params, cfg) or artifact=...")
        self.params = params
        self.cfg = cfg
        self.artifact = artifact
        self.n_slots = n_slots
        self.max_len = max_len
        # default per-request decode budget (submit()/Scheduler may override
        # per request); bounded by max_len anyway
        self.max_new = max_len
        self.eos = eos_id
        self.temp = temperature
        self.bulk_prefill = bulk_prefill
        self.mesh = mesh
        self._base_key = jax.random.PRNGKey(seed)
        # paged KV: attention families with bulk prefill keep their cache in a
        # block pool (kv_block=None restores the contiguous per-slot slabs);
        # ssm/hybrid recurrent state and whisper stay contiguous
        self.paged = (kv_block is not None and bulk_prefill
                      and api.paged_supported(cfg))
        self.pool: KVPool | None = None
        if self.paged:
            bs, mb, nb = api.paged_layout(cfg, max_len, kv_block, kv_blocks,
                                          n_slots)
            windowed = cfg.attn_window is not None
            self.pool = KVPool(
                n_slots=n_slots, n_blocks=nb - 1, block_size=bs, view_blocks=mb,
                # tail-extend prefill has no mrope path; windowed rings rewrite
                # shared prefixes as they wrap — both disable sharing
                prefix_cache=(prefix_cache and cfg.pos in ("rope", "none")),
                windowed=windowed)
            self.state = api.init_decode_state(cfg, n_slots, max_len,
                                               kv_block=kv_block,
                                               kv_blocks=kv_blocks)
            self._pool_leaves = ("c_kv", "k_rope") if "c_kv" in self.state else ("k", "v")
            self._tbl_host = np.zeros((n_slots, mb), np.int32)
            self._extend_fns: dict[int, object] = {}
        else:
            self.state = api.init_decode_state(cfg, n_slots, max_len)
        # host mirrors of the device-side per-slot control state
        self.pos = np.zeros(n_slots, np.int64)
        self.active = np.zeros(n_slots, bool)
        self._last_tok = np.zeros(n_slots, np.int32)
        self._new_count = np.zeros(n_slots, np.int32)
        self._max_new_arr = np.full(n_slots, self.max_new, np.int32)
        self._temp_arr = np.full(n_slots, temperature, np.float32)
        self._keys = np.array(
            jax.random.split(self._base_key, n_slots), np.uint32)
        self._ctrl_dev = None  # device copies of the submit-time-only arrays
        self._slot_dev = None  # device (last_tok, pos, active, new_count),
        # carried across steps; None => re-upload from the host mirrors
        self.results: dict[int, GenerationResult] = {}
        self.slot_req: dict[int, int] = {}
        self._next_req = 0
        self._prefill_fns: dict[int, object] = {}
        self.executor = (
            self._build_executor(artifact, interpret, mesh) if use_kernel
            else None)
        ex = self.executor
        self._decode = jax.jit(
            lambda p, s, t, pos: api.decode(p, cfg, s, t, pos, executor=ex))
        self.step_dispatches = 0  # jitted fused-step invocations (observability)
        # pallas_calls per traced decode step, keyed by input bucket
        # ("BxT"): retraces record under their own key and a warm retrace
        # can only raise a key's value (max), never clobber the cold count
        self._trace_launches: dict[str, int] = {}
        # telemetry: metrics=None -> fresh per-engine registry; metrics=False
        # -> fully off (the A/B baseline for overhead measurement); any
        # MetricsRegistry -> shared.  tracer=True builds a RequestTracer
        # publishing into the same registry; the scheduler reads engine.tracer.
        if metrics is False:
            self.metrics = None
        else:
            self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.profiler = (StepProfiler(fence_every=fence_every)
                         if self.metrics is not None else None)
        if tracer is True:
            self.tracer: RequestTracer | None = RequestTracer(
                metrics=self.metrics)
        else:
            self.tracer = tracer or None
        m = self.metrics
        if m is not None:
            # pre-resolved metric objects: the per-step hot path never walks
            # the registry's name table
            self._m_steps = m.counter(
                "serving_decode_steps_total", "fused decode step dispatches")
            self._m_tokens = m.counter(
                "serving_tokens_total", "decode tokens sampled")
            self._m_step_hist = m.histogram(
                "serving_decode_step_seconds",
                "fused decode step wall (host-synced)")
            self._m_prefills = m.counter(
                "serving_prefills_total", "prompt admissions by prefill kind",
                labels=("kind",))
            self._m_prefill_hist = m.histogram(
                "serving_prefill_seconds", "submit() prefill wall")
            self._m_launches = m.gauge(
                "serving_pallas_launches_per_step",
                "Pallas launches in one traced decode step",
                labels=("bucket",))
            self._m_grown = m.counter(
                "serving_blocks_grown_total",
                "KV blocks allocated mid-decode")
            self._m_exhausted = m.counter(
                "serving_pool_exhausted_total",
                "requests errored by KV pool exhaustion")
            self._m_pool = m.gauge(
                "serving_kv_pool", "KV block pool stats", labels=("stat",))
            m.gauge("serving_slots", "decode slots").set(n_slots)
            # the executor builds layer plans lazily at first trace, so this
            # gauge is refreshed alongside the launch counter at trace time
            self._m_plans = m.gauge(
                "serving_layer_plans", "distinct layer plans in the executor")
            self._m_plans.set(self.n_layer_plans)
            self._m_plan_fallbacks = m.counter(
                "serving_plan_fallbacks_total",
                "layer-plan builds that fell back to the per-region route",
                labels=("reason",))
        else:
            self._m_steps = self._m_tokens = self._m_step_hist = None
            self._m_prefills = self._m_prefill_hist = self._m_launches = None
            self._m_grown = self._m_exhausted = self._m_pool = None
            self._m_plans = self._m_plan_fallbacks = None
        self._fb_seen: set[str] = set()  # plan keys already counted
        self._step_fn = self._build_step_fn()

    @staticmethod
    def _build_executor(artifact, interpret, mesh=None):
        """Site-keyed :class:`CompressedExecutor` over the artifact — family
        agnostic (None when the artifact has no routable sites).  Layer plans
        stay on under a mesh: the plan call wraps itself in ``shard_map``
        (slot-split activations, replicated stage constants), so distributed
        serving keeps the one-launch-per-plan step too."""
        if artifact is None:
            return None
        ex = CompressedExecutor(artifact, interpret=interpret, mesh=mesh)
        return ex if ex.sites else None

    # ---------------------------------------------------------- fused step
    def _build_step_fn(self):
        """Jit the whole decode step — forward, sampling, bookkeeping — so
        ``step()`` costs one dispatch and one small device->host transfer."""
        cfg, ex, max_len = self.cfg, self.executor, self.max_len

        def fused(params, state, last_tok, pos, active, new_count,
                  max_new, temps, keys, eos):
            # a slot emits only with cache headroom AND budget left (the
            # pre-check makes max_new <= 0 finish without sampling)
            can_emit = (pos < max_len) & (new_count < max_new)
            emit = active & can_emit
            # non-emitting slots feed position -1: one_hot(-1) writes nothing
            # (attention_decode keeps negative positions out of ring caches
            # too), so free/finished slots never scribble on their cache
            toks = jnp.where(emit, last_tok, 0)[:, None]
            dpos = jnp.where(emit, pos - 1, -1).astype(jnp.int32)
            # launch accounting: this body runs at trace time, so the counter
            # delta around api.decode is exactly the pallas_calls one decode
            # step emits.  Record per input bucket and keep each bucket's max:
            # a warm retrace can undercount through inner-jit caches but a
            # real bucket change gets its own honest cold count
            t0 = dispatch.launch_count()
            logits, new_state = api.decode(params, cfg, state, toks, dpos,
                                           executor=ex)
            bucket = f"{toks.shape[0]}x{toks.shape[1]}"
            n_launch = max(dispatch.launch_count() - t0,
                           self._trace_launches.get(bucket, 0))
            self._trace_launches[bucket] = n_launch
            if self._m_launches is not None:
                self._m_launches.set(n_launch, bucket=bucket)
                self._m_plans.set(self.n_layer_plans)
            self._sync_plan_fallbacks()
            sub = jax.vmap(jax.random.fold_in)(keys, new_count)
            nxt = api.sample_tokens(logits.astype(jnp.float32), sub, temps)
            nxt = jnp.where(emit, nxt, last_tok)
            pos2 = pos + emit
            count2 = new_count + emit
            done = emit & (((eos >= 0) & (nxt == eos))
                           | (count2 >= max_new) | (pos2 >= max_len))
            done = done | (active & ~can_emit)
            packed = jnp.stack([nxt.astype(jnp.int32), emit.astype(jnp.int32),
                                done.astype(jnp.int32)])
            # carried device ctrl state: mirrors exactly the host-side updates
            # in step(), so the next step needs no H2D re-upload of it
            ctrl = (nxt, pos2, active & ~done, count2)  # nxt already carries
            # last_tok for non-emitting rows
            return new_state, packed, ctrl

        # the previous step's state dies the moment the new one lands, so
        # donate it: XLA scatters the KV write-back in place instead of
        # copying the whole block pool every step (~0.8ms at bench scale)
        if self.mesh is None:
            return jax.jit(fused, donate_argnums=(1,))
        from repro.distributed import sharding as shd

        self._param_sh = shd.named(self.mesh, shd.params_pspecs(self.params, self.mesh))
        self._state_sh = shd.named(self.mesh, shd.decode_state_pspecs(self.state, self.mesh))
        self.params = jax.device_put(self.params, self._param_sh)
        self.state = jax.device_put(self.state, self._state_sh)
        rep = NamedSharding(self.mesh, P())
        # explicit shardings: prefill-time state surgery can't change the step
        # signature, so the step never re-traces on a sharding flip
        return jax.jit(fused,
                       in_shardings=(self._param_sh, self._state_sh) + (rep,) * 8,
                       out_shardings=(self._state_sh, rep, (rep,) * 4),
                       donate_argnums=(1,))

    @property
    def pallas_launches_per_step(self) -> int:
        """Measured Pallas launches in one fused decode step — the max over
        every traced input bucket (0 before the first step traces; excludes
        prefill, which runs dense)."""
        return max(self._trace_launches.values(), default=0)

    @property
    def pallas_launches_by_bucket(self) -> dict:
        """Per-trace launch counts keyed by decode input bucket ("BxT")."""
        return dict(self._trace_launches)

    @property
    def n_layer_plans(self) -> int:
        """Distinct layer plans the executor built for this engine."""
        if self.executor is None:
            return 0
        return getattr(self.executor, "n_layer_plans", 0)

    # ------------------------------------------------------------------ API
    def validate_prompt(self, prompt: list[int]) -> str | None:
        """Why a prompt cannot be served (None when it can).  Single source of
        truth for ``submit()`` (raises) and the scheduler (errored result)."""
        if not prompt:
            return "empty prompt: decode needs at least one token"
        if len(prompt) > self.max_len:
            return (f"prompt of {len(prompt)} tokens exceeds the engine's "
                    f"max_len={self.max_len} KV cache")
        if (self.pool is not None and not self.pool.windowed
                and self.pool.blocks_for(len(prompt)) + 1 > self.pool.n_blocks):
            return (f"prompt of {len(prompt)} tokens can never fit the KV "
                    f"pool ({self.pool.n_blocks} blocks of "
                    f"{self.pool.block_size} tokens, one reserved for decode)")
        return None

    def can_admit(self, prompt: list[int]) -> bool:
        """Whether ``submit(prompt)`` would succeed *right now*: a free slot,
        and (paged) enough free or evictable blocks after prefix sharing.
        The scheduler's continuous-batching gate."""
        if self.active.all():
            return False
        return self.pool is None or self.pool.can_admit(prompt)

    def _sync_plan_fallbacks(self) -> None:
        """Publish newly-recorded plan fallbacks (executor builds plans lazily
        at trace time, so this runs alongside the launch accounting)."""
        ex = self.executor
        if ex is None:
            return
        for key, reason in getattr(ex, "plan_fallbacks", {}).items():
            if key not in self._fb_seen:
                self._fb_seen.add(key)
                if self._m_plan_fallbacks is not None:
                    self._m_plan_fallbacks.inc(1, reason=reason)

    def plan_stats(self) -> dict:
        """Layer-plan telemetry: plans built, measured launches per step, and
        every plan key that fell back to the per-region route with its reason
        string (``pool_stats()``-style — always the full key set)."""
        self._sync_plan_fallbacks()
        fallbacks = (dict(getattr(self.executor, "plan_fallbacks", {}))
                     if self.executor is not None else {})
        return {"n_layer_plans": self.n_layer_plans,
                "pallas_launches_per_step": self.pallas_launches_per_step,
                "fallbacks": fallbacks}

    def pool_stats(self) -> dict:
        """KV-pool telemetry.  Always the full key set — contiguous engines
        report every key zeroed (``n_blocks == 0`` distinguishes them) so
        callers never branch on engine kind.  Mirrored into the registry's
        ``serving_kv_pool{stat=...}`` gauge when metrics are enabled."""
        s = empty_stats() if self.pool is None else self.pool.stats()
        if self._m_pool is not None:
            for k, v in s.items():
                self._m_pool.set(v, stat=k)
        return s

    def submit(self, prompt: list[int], *, max_new: int | None = None,
               temperature: float | None = None) -> int:
        """Prefill a prompt into a free slot; returns request id.

        ``max_new`` / ``temperature`` override the engine defaults for this
        request only (the per-slot budget/temp arrays feed the fused step).
        """
        err = self.validate_prompt(prompt)
        if err is not None:
            raise ValueError(err)
        free = np.where(~self.active)[0]
        if free.size == 0:
            raise RuntimeError("no free slots; call step() until one finishes")
        slot = int(free[0])
        rid = self._next_req
        self._next_req += 1
        t_pre = time.perf_counter()
        cached_tokens = 0
        kind = "paged" if self.paged else (
            "bulk" if self.bulk_prefill
            and ("k" in self.state or "c_kv" in self.state) else "tokenwise")
        if self.paged:
            plan = self.pool.admit(slot, prompt)
            if plan is None:
                self._next_req -= 1
                raise RuntimeError(
                    f"insufficient free KV blocks for a {len(prompt)}-token "
                    f"prompt ({self.pool.available_blocks} available); step() "
                    "until a request finishes")
            self._prefill_slot_paged(slot, prompt, plan)
            self.pool.register_prefix(slot, prompt)
            cached_tokens = plan.cached_tokens
        elif kind == "bulk":
            # one bulk forward writes the whole slot cache (and rewrites the
            # full kpos row, so stale entries need no separate reset)
            self._prefill_slot(slot, prompt)
        else:
            # stateful families (ssm/hybrid) keep the tokenwise path: their
            # per-layer recurrent states live in scan-stacked layouts that a
            # bulk forward does not expose per-slot; the slot column is reset
            # first so the previous occupant's state/kpos never leaks
            self._reset_slot_state(slot)
            self._prefill_slot_tokenwise(slot, prompt)
        self.pos[slot] = len(prompt)
        self.active[slot] = True
        self._last_tok[slot] = prompt[-1]
        self._new_count[slot] = 0
        self._max_new_arr[slot] = self.max_new if max_new is None else max_new
        self._temp_arr[slot] = self.temp if temperature is None else temperature
        # request-keyed PRNG: draws depend on (seed, rid, step), never on which
        # slot the request landed in or what else is in flight
        self._keys[slot] = np.asarray(
            jax.random.fold_in(self._base_key, rid), np.uint32)
        self._ctrl_dev = None  # budget/temp/key arrays changed: re-upload once
        self._slot_dev = None  # host mirrors mutated: re-upload once
        self.slot_req[slot] = rid
        # host wall of the whole admission (dispatch + bookkeeping; the
        # device work may still be in flight — bench paths that want the
        # synced latency block on eng.state themselves)
        prefill_s = time.perf_counter() - t_pre
        if self._m_prefills is not None:
            self._m_prefills.inc(1, kind=kind)
            self._m_prefill_hist.observe(prefill_s)
        self.results[rid] = GenerationResult(
            tokens=list(prompt), prompt_len=len(prompt), finished=False,
            stats={"prefill_s": prefill_s, "prefill_kind": kind,
                   "cached_tokens": cached_tokens})
        return rid

    # -------------------------------------------------------------- prefill
    def _reset_slot_state(self, slot: int) -> None:
        """Clear one slot's column of every decode-state leaf (kpos-style
        position maps to -1, caches/recurrent states to 0) so a reused slot
        never sees its previous occupant's KV entries or SSM state."""
        st = dict(self.state)
        for name, v in st.items():
            if name.startswith("cross_"):
                continue  # whisper cross-KV is set per slot by the caller
            fill = -1 if "kpos" in name else 0
            st[name] = v.at[:, slot].set(jnp.asarray(fill, v.dtype))
        self.state = st

    def _merge_slot_state(self, old, new, slot: int):
        """Take ``new``'s batch column ``slot``, keep ``old`` elsewhere — the
        tokenwise prefill must not advance other slots' recurrent state."""
        return jax.tree.map(lambda o, n: o.at[:, slot].set(n[:, slot]),
                            old, new)

    def _prefill_slot_tokenwise(self, slot: int, prompt: list[int]) -> None:
        """Legacy prefill: one decode step per prompt token (kept as the
        fallback for recurrent-state families and as the bulk path's
        equivalence/latency baseline in benchmarks).  Decode rows are
        independent, so the loop runs on a scratch state and only the target
        slot's column is merged back — other slots never see the prefill."""
        old = scratch = self.state
        for t, tok in enumerate(prompt):
            _logits, scratch = self._decode(
                self.params, scratch,
                self._token_batch(slot, tok), self._pos_batch(slot, t))
        self.state = self._merge_slot_state(old, scratch, slot)

    def _prefill_slot(self, slot: int, prompt: list[int]) -> None:
        """Bulk prefill: ONE ``api.prefill`` forward over the prompt writes
        the slot's KV cache at its positions.  Prompts are right-padded to
        power-of-two buckets so recompilation is bounded (log2(max_len)
        buckets); padded positions stay masked via kpos == -1."""
        plen = len(prompt)
        s_pad = min(self.max_len, max(8, 1 << (plen - 1).bit_length()))
        if s_pad not in self._prefill_fns:
            cfg = self.cfg
            self._prefill_fns[s_pad] = jax.jit(
                lambda p, t: api.prefill(p, cfg, {"tokens": t},
                                         collect_cache=True))
        toks = np.zeros((1, s_pad), np.int32)
        toks[0, :plen] = prompt
        _h, caches = self._prefill_fns[s_pad](self.params, jnp.asarray(toks))
        st = dict(self.state)
        if "k" in st:
            k_all, v_all = caches  # [L, 1, S_pad, Hkv, Dh]
            eff = st["k"].shape[2]  # ring size when windowed, else max_len
            ps = np.arange(max(0, plen - eff), plen)
            slots = ps % eff if self.cfg.attn_window is not None else ps
            kpos_row = np.full(eff, -1, np.int64)
            kpos_row[slots] = ps
            st["k"] = st["k"].at[:, slot, slots].set(
                k_all[:, 0, ps].astype(st["k"].dtype))
            st["v"] = st["v"].at[:, slot, slots].set(
                v_all[:, 0, ps].astype(st["v"].dtype))
        else:  # MLA latent cache
            c_kv, k_rope = caches  # [L, 1, S_pad, dc] / [L, 1, S_pad, Dr]
            eff = st["c_kv"].shape[2]
            ps = np.arange(plen)
            kpos_row = np.full(eff, -1, np.int64)
            kpos_row[:plen] = ps
            st["c_kv"] = st["c_kv"].at[:, slot, :plen].set(
                c_kv[:, 0, :plen].astype(st["c_kv"].dtype))
            st["k_rope"] = st["k_rope"].at[:, slot, :plen].set(
                k_rope[:, 0, :plen].astype(st["k_rope"].dtype))
        st["kpos"] = st["kpos"].at[:, slot].set(jnp.asarray(kpos_row, jnp.int32))
        self.state = st

    # --------------------------------------------------------- paged prefill
    def _scatter_pool(self, st, name, tbl_row, vidx, vals):
        """Write per-token values ``vals`` [L, n, ...] into the pool at the
        slot's logical view indices ``vidx`` (block = table[v // bs], offset
        v % bs) — one scatter dispatch per leaf."""
        bs = self.pool.block_size
        blocks = tbl_row[vidx // bs]
        offs = vidx % bs
        st[name] = st[name].at[:, blocks, offs].set(vals.astype(st[name].dtype))

    def _prefill_slot_paged(self, slot: int, prompt: list[int], plan) -> None:
        """Apply an :class:`~repro.serving.kvpool.AdmitPlan`: install the
        block table row, device-copy the COW block, prefill only the
        non-cached tail (bulk forward when cold, ``api.prefill_extend``
        against the gathered resident prefix on a prefix hit), and scatter
        the fresh K/V into the slot's blocks."""
        st = dict(self.state)
        cfg, pool = self.cfg, self.pool
        bs, plen = pool.block_size, len(prompt)
        view = pool.view_blocks * bs  # == ring size when windowed
        tbl_row = plan.table
        self._tbl_host[slot] = tbl_row
        st["block_tbl"] = jnp.asarray(self._tbl_host)
        if plan.cow is not None:
            src, dst = plan.cow
            for name in self._pool_leaves:
                st[name] = st[name].at[:, dst].set(st[name][:, src])
        cached = plan.cached_tokens
        kpos_row = np.full(view, -1, np.int64)
        if cfg.attn_window is not None:  # ring layout, no prefix sharing
            ps = np.arange(max(0, plen - view), plen)
            vidx = ps % view
            kpos_row[vidx] = ps
        else:
            ps = np.arange(cached, plen)
            vidx = ps
            kpos_row[:plen] = np.arange(plen)
        if ps.size:  # uncached tail to prefill (cached == plen: nothing —
            # the first decode step recomputes the last token's K/V anyway)
            if cached == 0:
                s_pad = min(self.max_len, max(8, 1 << (plen - 1).bit_length()))
                if s_pad not in self._prefill_fns:
                    self._prefill_fns[s_pad] = jax.jit(
                        lambda p, t: api.prefill(p, cfg, {"tokens": t},
                                                 collect_cache=True))
                toks = np.zeros((1, s_pad), np.int32)
                toks[0, :plen] = prompt
                _h, caches = self._prefill_fns[s_pad](self.params,
                                                      jnp.asarray(toks))
                for name, c_all in zip(self._pool_leaves, caches):
                    self._scatter_pool(st, name, tbl_row, vidx, c_all[:, 0, ps])
            else:
                self._extend_tail(st, prompt, cached, tbl_row, vidx, view)
        st["kpos"] = st["kpos"].at[:, slot].set(jnp.asarray(kpos_row, jnp.int32))
        self.state = st

    def _extend_tail(self, st, prompt, cached, tbl_row, vidx, view) -> None:
        """Prefix-hit tail prefill: gather the resident prefix through the
        block table (the exact contiguous view), run the tail tokens against
        it in one bucketed jitted forward, scatter the tail K/V back."""
        cfg, plen = self.cfg, len(prompt)
        tl = plen - cached
        t_pad = max(8, 1 << (tl - 1).bit_length())
        if t_pad not in self._extend_fns:
            self._extend_fns[t_pad] = jax.jit(
                lambda p, t, pos, past, last: api.prefill_extend(
                    p, cfg, t, pos, past, last))
        toks = np.zeros((1, t_pad), np.int32)
        toks[0, :tl] = prompt[cached:]
        posn = np.full((1, t_pad), -1, np.int64)
        posn[0, :tl] = np.arange(cached, plen)
        past = {}
        for name in self._pool_leaves:
            pool_leaf = st[name]  # [L, Nb, bs, ...]
            g = pool_leaf[:, tbl_row]  # gather: [L, mb, bs, ...]
            past[name] = g.reshape(pool_leaf.shape[0], 1, view,
                                   *pool_leaf.shape[3:])
        pk = np.full((1, view), -1, np.int64)
        pk[0, :cached] = np.arange(cached)
        past["kpos"] = jnp.broadcast_to(
            jnp.asarray(pk, jnp.int32)[None], (cfg.n_layers, 1, view))
        _logits, tails = self._extend_fns[t_pad](
            self.params, jnp.asarray(toks), jnp.asarray(posn, jnp.int32),
            past, jnp.asarray([tl - 1], jnp.int32))
        for name, tail in tails.items():  # [L, 1, t_pad, ...]
            self._scatter_pool(st, name, tbl_row, vidx, tail[:, 0, :tl])

    def _release_slot(self, slot: int) -> None:
        """Return a retired slot's blocks to the pool (registered prefix
        blocks stay cached) and clear its table row."""
        self.pool.release(slot)
        self._tbl_host[slot] = 0
        self.state = {**self.state,
                      "block_tbl": jnp.asarray(self._tbl_host)}

    def cancel(self, rid: int) -> bool:
        """Stop an in-flight request (its slot frees on the spot); returns
        whether anything was cancelled.  The result keeps the tokens sampled
        so far and is marked finished."""
        for slot, r in self.slot_req.items():
            if r == rid and self.active[slot]:
                self.active[slot] = False
                self._slot_dev = None  # host mirrors mutated: re-upload once
                if self.paged:
                    self._release_slot(slot)
                self.results[rid].finished = True
                self.results[rid].stats["cancelled"] = True
                return True
        return False

    def step(self) -> list[StepEvent]:
        """One fused decode step for every active slot: exactly one jitted
        dispatch; the only device->host traffic is the packed [3, n_slots]
        (token, emit, done) array.  Returns this step's per-slot events."""
        events: list[StepEvent] = []
        if not self.active.any():
            return events
        if self.paged:
            events.extend(self._grow_blocks())
            if not self.active.any():
                return events
        eos = np.int32(-1 if self.eos is None else self.eos)
        if self._ctrl_dev is None:  # max_new/temps/keys only change at submit
            self._ctrl_dev = (jnp.asarray(self._max_new_arr),
                              jnp.asarray(self._temp_arr),
                              jnp.asarray(self._keys))
        max_new_d, temps_d, keys_d = self._ctrl_dev
        if self._slot_dev is None:  # first step after a host-side mutation
            self._slot_dev = (
                jnp.asarray(self._last_tok), jnp.asarray(self.pos, jnp.int32),
                jnp.asarray(self.active), jnp.asarray(self._new_count))
        t0 = self.profiler.begin() if self.profiler is not None else 0.0
        new_state, packed, self._slot_dev = self._step_fn(
            self.params, self.state, *self._slot_dev,
            max_new_d, temps_d, keys_d, eos)
        self.step_dispatches += 1
        self.state = new_state
        nxt, emit, done = np.asarray(packed)  # the one small host transfer
        if self.profiler is not None:
            # np.asarray above already synced the step, so no fence needed
            n_emit = int(emit.sum())
            dt = self.profiler.end(t0, tokens=n_emit)
            self._m_steps.inc()
            self._m_tokens.inc(n_emit)
            self._m_step_hist.observe(dt)
        for slot in np.where(self.active)[0]:
            rid = self.slot_req[slot]
            r = self.results[rid]
            tok: int | None = None
            if emit[slot]:
                tok = int(nxt[slot])
                r.tokens.append(tok)
                self._last_tok[slot] = tok
                self.pos[slot] += 1
                self._new_count[slot] += 1
            if done[slot]:
                r.finished = True
                self.active[slot] = False
                if self.paged:
                    self._release_slot(slot)
            events.append(StepEvent(rid=rid, token=tok, finished=bool(done[slot])))
        return events

    def _grow_blocks(self) -> list[StepEvent]:
        """Pre-step block growth: the upcoming step writes each active slot's
        K/V at view index ``pos - 1`` — allocate the covering block when the
        table has none (0 = null).  Windowed slots preallocate their whole
        ring at admit, so this is a no-op for them.  A slot the pool cannot
        grow finishes with an error (its blocks return to the pool)."""
        events: list[StepEvent] = []
        bs = self.pool.block_size
        view = self.pool.view_blocks * bs
        dirty = False
        for slot in np.where(self.active)[0]:
            bi = (int(self.pos[slot]) - 1) % view // bs
            if self._tbl_host[slot, bi] != 0:
                continue
            bid = self.pool.append_block(slot)
            if bid is None:
                rid = self.slot_req[slot]
                r = self.results[rid]
                r.finished = True
                r.error = ("KV block pool exhausted mid-decode "
                           f"({self.pool.in_use_blocks} blocks in use)")
                r.stats["exhausted"] = True
                if self._m_exhausted is not None:
                    self._m_exhausted.inc()
                self.active[slot] = False
                self._slot_dev = None
                self._release_slot(slot)
                events.append(StepEvent(rid=rid, token=None, finished=True))
                continue
            self._tbl_host[slot, bi] = bid
            r = self.results[self.slot_req[slot]]
            r.stats["blocks_grown"] = r.stats.get("blocks_grown", 0) + 1
            if self._m_grown is not None:
                self._m_grown.inc()
            dirty = True
        if dirty:
            self.state = {**self.state,
                          "block_tbl": jnp.asarray(self._tbl_host)}
        return events

    def generate(self, prompts: list[list[int]], max_new_tokens: int = 32, *,
                 temperature: float | None = None, on_token=None
                 ) -> list[GenerationResult]:
        """Continuous-batched generation over a request list (Scheduler-driven).

        Invalid prompts (empty / beyond the KV cache) do not abort the batch:
        they come back as ``GenerationResult(finished=True, error=...)`` while
        the rest of the batch completes.  ``on_token(rid, token)`` streams
        tokens as they are sampled.
        """
        from .scheduler import Scheduler

        sched = Scheduler(self)
        rids = [sched.enqueue(p, max_new=max_new_tokens, temperature=temperature,
                              on_token=on_token) for p in prompts]
        sched.run()
        return [sched.take_result(r) for r in rids]

    # -------------------------------------------------------------- helpers
    def _token_batch(self, slot: int, tok: int):
        t = np.zeros((self.n_slots, 1), np.int32)
        t[slot, 0] = tok
        return jnp.asarray(t)

    def _pos_batch(self, slot: int, pos: int):
        p = np.asarray(self.pos - 1, np.int64).clip(0)
        p[slot] = pos
        return jnp.asarray(p, jnp.int32)


# ---------------------------------------------------------------- compression


def compress_ffn_for_serving(params, cfg: ArchConfig, compression=None, *,
                             report=None, interpret: bool | None = None,
                             build_matvecs: bool = True):
    """Legacy FFN-only wrapper over :func:`models.api.compress_model`.

    Returns ``(params_c, matvecs, report)`` for the FFN projections of a
    dense-FFN transformer: ``params_c`` are the full params with FFN weights
    replaced by their compressed dense equivalent, ``matvecs[proj][layer]``
    the :class:`LCCMatvec` kernels (built through
    :func:`~repro.serving.executor.matvecs_from_artifact`).  Every family —
    and every non-FFN site — is served via ``api.compress_model`` +
    ``ServingEngine(artifact=...)`` directly.
    """
    from repro import core

    if cfg.moe is not None or cfg.family in ("ssm", "hybrid") or cfg.enc_layers:
        raise ValueError(
            f"compress_ffn_for_serving wraps the dense-FFN fast path; family "
            f"{cfg.family!r} is served via models.api.compress_model(...) and "
            "ServingEngine(artifact=...)")
    if compression is None:
        compression = core.CompressionConfig(algorithm="fs", weight_sharing=True,
                                             max_share_rel_err=0.06)
    art = api.compress_model(params, cfg, compression, include="ffn.",
                             build_packed=build_matvecs)
    if report is not None:
        for lc in art.report.layers:
            report.add(lc)
    matvecs: dict[str, list[LCCMatvec]] = {}
    if build_matvecs:
        table = matvecs_from_artifact(art, include="ffn.", interpret=interpret)
        for proj in ("gate", "up", "down"):
            matvecs[proj] = [table[f"ffn.{proj}.l{li}"]
                             for li in range(cfg.n_layers)]
    return art.params, matvecs, art.report if report is None else report
