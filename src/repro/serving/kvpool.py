"""Paged KV-cache memory subsystem: block pool allocator + prefix cache.

The serving engine's KV memory is a device-resident *block pool* — per layer,
``[n_blocks, block_size, ...]`` — instead of one contiguous ``max_len`` slab
per slot.  This module is the **host-side brain** of that pool: it owns the
free list, per-slot block tables, reference counts, the token-keyed prefix
cache and the LRU eviction policy.  It never touches device memory — the
engine applies the returned :class:`AdmitPlan` (gathers, scatters, block
copies) so the device step keeps its one-dispatch-per-step property.

Layout & invariants
-------------------
* Block ids are shared across layers: one allocation covers every layer's
  slice of the pool (``k[:, bid]`` is block ``bid`` in all L layers).
* Block id 0 is the reserved **null block**: never allocated, the write sink
  for inactive slots and the gather source for unallocated table entries
  (masked out by ``kpos == -1``).
* A block is in exactly one of three states: **free** (on the free list),
  **in use** (``ref > 0``; held by one or more running slots), or **cached**
  (``ref == 0`` but registered in the prefix cache; LRU-evictable).
* Decode only ever writes a slot's *tail* block, and tails are never shared:
  prefix sharing covers full prompt blocks (read-only while shared), and a
  partially-filled cached block is reused via **copy-on-write** — the sharer
  gets its own device copy before any write can land.

Prefix cache
------------
Full prompt blocks register under their exact token chain
(``tuple(prompt[:(i+1)*bs])`` — value-keyed, so no hash collisions and no
dangling references when parents are evicted).  Admission walks the chain and
reuses every matching full block (incref, zero prefill cost); if the chain
covers all full blocks and some cached sibling block *starts with* the prompt
remainder, that block is reused copy-on-write and the whole prompt is served
from cache.  Only the unmatched tail pays prefill.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

__all__ = ["KVPool", "AdmitPlan", "POOL_STAT_KEYS", "empty_stats"]

# the full stats() key set — contiguous (pool-less) engines report the same
# keys zeroed, so dashboards and CI assertions never branch on engine kind
POOL_STAT_KEYS = ("n_blocks", "block_size", "free_blocks", "cached_blocks",
                  "in_use_blocks", "peak_in_use_blocks", "prefix_queries",
                  "prefix_hit_blocks", "prefix_hit_tokens", "prefix_hit_rate",
                  "cow_copies", "evictions")


def empty_stats() -> dict:
    """Zeroed :meth:`KVPool.stats` shape for engines without a block pool."""
    return {k: 0.0 if k == "prefix_hit_rate" else 0 for k in POOL_STAT_KEYS}


@dataclass
class AdmitPlan:
    """Host-side admission decision, applied to device memory by the engine."""
    table: np.ndarray  # [view_blocks] int32 block ids (0 = unallocated/null)
    cached_tokens: int  # leading tokens already resident (skip their prefill)
    shared: list[int] = field(default_factory=list)  # reused read-only blocks
    new: list[int] = field(default_factory=list)  # freshly allocated blocks
    cow: tuple[int, int] | None = None  # (src, dst): device-copy src -> dst


class KVPool:
    """Free-list block allocator + prefix cache over a paged KV pool.

    ``n_blocks`` counts usable blocks (ids ``1..n_blocks``; id 0 is the null
    block and is not the pool's to give out).  ``view_blocks`` is the block-
    table width — ``ceil(view_tokens / block_size)`` logical blocks per slot.
    """

    def __init__(self, *, n_slots: int, n_blocks: int, block_size: int,
                 view_blocks: int, prefix_cache: bool = True,
                 windowed: bool = False):
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.view_blocks = view_blocks
        self.windowed = windowed
        # a ring cache rewrites its prefix as it wraps: cached blocks would go
        # stale the moment the window slides, so sharing is disabled
        self.prefix_cache = prefix_cache and not windowed
        self._free: list[int] = list(range(n_blocks, 0, -1))  # pop() -> low ids
        self._ref = np.zeros(n_blocks + 1, np.int32)
        self._slot_blocks: list[list[int]] = [[] for _ in range(n_slots)]
        # prefix cache: token-chain -> block id, plus reverse index for evict
        self._children: dict[tuple, dict[tuple, int]] = {}
        self._block_key: dict[int, tuple[tuple, tuple]] = {}  # bid -> (parent, toks)
        self._lru: OrderedDict[int, None] = OrderedDict()  # cached, ref == 0
        # telemetry
        self.prefix_queries = 0
        self.prefix_hit_blocks = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0
        self.evictions = 0
        self.peak_in_use = 0

    # ------------------------------------------------------------- accounting
    @property
    def capacity_tokens(self) -> int:
        return self.n_blocks * self.block_size

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._lru)

    @property
    def in_use_blocks(self) -> int:
        return self.n_blocks - self.free_blocks - self.cached_blocks

    @property
    def available_blocks(self) -> int:
        """Blocks an admission could obtain: free + LRU-evictable."""
        return self.free_blocks + self.cached_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks, "block_size": self.block_size,
            "free_blocks": self.free_blocks, "cached_blocks": self.cached_blocks,
            "in_use_blocks": self.in_use_blocks,
            "peak_in_use_blocks": self.peak_in_use,
            "prefix_queries": self.prefix_queries,
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hit_blocks
                                / max(1, self.prefix_queries)),
            "cow_copies": self.cow_copies, "evictions": self.evictions,
        }

    # ------------------------------------------------------------- allocation
    def _evict_one(self) -> int | None:
        """Drop the least-recently-used cached block from the prefix cache."""
        if not self._lru:
            return None
        bid, _ = self._lru.popitem(last=False)
        parent, toks = self._block_key.pop(bid)
        kids = self._children.get(parent)
        if kids is not None and kids.get(toks) == bid:
            del kids[toks]
            if not kids:
                del self._children[parent]
        self.evictions += 1
        return bid

    def _alloc(self) -> int | None:
        if self._free:
            return self._free.pop()
        return self._evict_one()

    def _hold(self, bid: int) -> None:
        """Take a reference; a cached block leaves the LRU (no longer evictable)."""
        if self._ref[bid] == 0:
            self._lru.pop(bid, None)
        self._ref[bid] += 1

    def _drop(self, bid: int) -> None:
        self._ref[bid] -= 1
        assert self._ref[bid] >= 0, f"block {bid} over-released"
        if self._ref[bid] == 0:
            if bid in self._block_key:
                self._lru[bid] = None  # stays resident, evictable
            else:
                self._free.append(bid)

    # -------------------------------------------------------------- admission
    def _match_prefix(self, prompt: list[int]):
        """Walk the cache chain: (matched full-block ids, cow source or None).

        The COW source is a cached block whose first ``len(prompt) % bs``
        tokens equal the prompt remainder — reusable only through a private
        copy, since the new request will write into it."""
        bs = self.block_size
        f, r = len(prompt) // bs, len(prompt) % bs
        matched: list[int] = []
        key: tuple = ()
        for i in range(f):
            toks = tuple(prompt[i * bs:(i + 1) * bs])
            bid = self._children.get(key, {}).get(toks)
            if bid is None:
                return matched, None
            matched.append(bid)
            key = key + toks
        cow_src = None
        if r:
            tail = tuple(prompt[f * bs:])
            for toks, bid in self._children.get(key, {}).items():
                if toks[:r] == tail:
                    cow_src = bid
                    break
        return matched, cow_src

    def admit_cost(self, prompt: list[int]) -> int:
        """Blocks an admission would allocate (after prefix sharing).  The
        count includes one reserve block of decode headroom — ``admit``
        really allocates it, so concurrent requests cannot starve each
        other's first growth block.  Pure query — no refcounts move."""
        plen = len(prompt)
        if self.windowed:
            return self.view_blocks
        if not self.prefix_cache:
            return self.blocks_for(plen) + 1
        matched, cow_src = self._match_prefix(prompt)
        cached = plen if (cow_src is not None
                          and len(matched) == plen // self.block_size) \
            else len(matched) * self.block_size
        fresh = min(self.blocks_for(plen - cached) + 1,  # +1 decode reserve,
                    self.view_blocks - len(matched)      # capped by the table
                    - (cow_src is not None))
        return fresh + (cow_src is not None)

    def can_admit(self, prompt: list[int]) -> bool:
        return self.admit_cost(prompt) <= self.available_blocks

    def admit(self, slot: int, prompt: list[int]) -> AdmitPlan | None:
        """Reserve blocks for a prompt: reuse cached prefix blocks, allocate
        the rest.  Returns None (state unchanged) when the pool cannot supply
        enough blocks even after eviction."""
        assert not self._slot_blocks[slot], f"slot {slot} still holds blocks"
        bs, plen = self.block_size, len(prompt)
        matched: list[int] = []
        cow_src = None
        if self.prefix_cache and not self.windowed:
            self.prefix_queries += 1
            matched, cow_src = self._match_prefix(prompt)
        for bid in matched:  # pin before allocating: eviction must skip these
            self._hold(bid)
        if cow_src is not None:
            self._hold(cow_src)
        cached = len(matched) * bs
        cow = None
        new: list[int] = []
        # +1: the decode-headroom reserve block, capped so the table never
        # overflows (rings never grow — their whole view is allocated here)
        if self.windowed:
            want = self.view_blocks
        else:
            cow_n = cow_src is not None
            want = min(self.blocks_for(plen - cached) - cow_n + 1,
                       self.view_blocks - len(matched) - cow_n)
        ok = True
        if cow_src is not None:
            dst = self._alloc()
            if dst is None:
                ok = False
            else:
                cow = (cow_src, dst)
                cached = plen  # the copy carries the whole prompt remainder
        if ok:
            for _ in range(max(0, want)):
                bid = self._alloc()
                if bid is None:
                    ok = False
                    break
                new.append(bid)
        if cow_src is not None:
            self._drop(cow_src)  # pin released; stays cached either way
        if not ok:  # rollback — admission is all-or-nothing
            for bid in new + ([cow[1]] if cow else []):
                self._free.append(bid)
            for bid in matched:
                self._drop(bid)
            return None
        owned = matched + ([cow[1]] if cow else []) + new
        for bid in owned[len(matched):]:
            self._ref[bid] = 1
        table = np.zeros(self.view_blocks, np.int32)
        table[:len(owned)] = owned
        self._slot_blocks[slot] = owned
        self.prefix_hit_blocks += len(matched) + (cow is not None)
        self.prefix_hit_tokens += cached
        self.cow_copies += cow is not None
        self.peak_in_use = max(self.peak_in_use, self.in_use_blocks)
        return AdmitPlan(table=table, cached_tokens=min(cached, plen),
                         shared=matched, new=new, cow=cow)

    def append_block(self, slot: int) -> int | None:
        """Grow a slot by one decode block; None when the pool is exhausted."""
        if len(self._slot_blocks[slot]) >= self.view_blocks:
            return None
        bid = self._alloc()
        if bid is None:
            return None
        self._ref[bid] = 1
        self._slot_blocks[slot].append(bid)
        self.peak_in_use = max(self.peak_in_use, self.in_use_blocks)
        return bid

    # ----------------------------------------------------- cache registration
    def register_prefix(self, slot: int, prompt: list[int]) -> None:
        """Publish a slot's full prompt blocks into the prefix cache (called
        once the blocks hold real K/V, i.e. right after prefill).  Blocks
        whose chain position is already cached keep the existing entry."""
        if not self.prefix_cache:
            return
        bs = self.block_size
        key: tuple = ()
        for i in range(len(prompt) // bs):
            toks = tuple(prompt[i * bs:(i + 1) * bs])
            bid = self._slot_blocks[slot][i]
            kids = self._children.setdefault(key, {})
            if toks not in kids and bid not in self._block_key:
                kids[toks] = bid
                self._block_key[bid] = (key, toks)
            key = key + toks

    def release(self, slot: int) -> None:
        """Retire a slot: every held block drops one reference.  Registered
        blocks at ref 0 stay cached (LRU-evictable); the rest go back to the
        free list."""
        for bid in self._slot_blocks[slot]:
            self._drop(bid)
        self._slot_blocks[slot] = []
