"""Training loop: sharded train step, grad accumulation, checkpoint/resume.

``make_train_step`` builds the jitted SPMD step for any registered arch:
loss (models.api) -> grads -> clip -> optimizer, with optional
  * gradient accumulation (scan over microbatches),
  * int8 error-feedback cross-pod gradient compression (shard_map over "pod",
    GSPMD auto inside the pod),
  * ProxSGD group-lasso regularization (the paper's eq. (7), first-class).

State/parameters carry NamedShardings from distributed.sharding (FSDP + TP +
ZeRO); inputs shard over ("pod","data").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from repro.distributed.compress_grads import compressed_psum
from repro.models import api
from repro.optim.optimizers import Optimizer, clip_by_global_norm

__all__ = ["TrainState", "init_train_state", "make_train_step",
           "record_step_metrics"]


def record_step_metrics(registry, metrics: dict, *, step=None) -> None:
    """Publish one train step's metric dict (``loss``, ``grad_norm``, and —
    under ProxSGD — ``dead_groups`` / ``prox_penalty``) into an
    ``repro.obs`` registry as ``train_<name>`` gauges plus the
    ``train_steps_total`` counter.  Values may still be device arrays; the
    caller decides when to sync (call this where the loop already prints, so
    telemetry never forces an extra device round-trip)."""
    if registry is None:
        return
    registry.counter("train_steps_total", "recorded train steps").inc()
    if step is not None:
        registry.gauge("train_step", "last recorded optimizer step").set(
            int(step))
    for k, v in metrics.items():
        try:
            fv = float(v)
        except (TypeError, ValueError):
            continue  # non-scalar extras stay out of the registry
        registry.gauge(f"train_{k}", f"train step metric {k!r}").set(fv)


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray
    error_fb: Any | None = None  # gradient-compression residuals
    prox_report: Any | None = None  # per-site sparsity/group-norm summary


def init_train_state(key, cfg: ArchConfig, optimizer: Optimizer,
                     grad_compression: bool = False, n_pods: int = 2,
                     prox_specs=None) -> TrainState:
    params = api.init_params(key, cfg)
    opt_state = optimizer.init(params)
    # error-feedback residuals are PER POD (leading pod axis, sharded on "pod")
    efb = jax.tree.map(lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params) \
        if grad_compression else None
    # the initial report fixes the state's tree structure so checkpoint
    # templates and the jitted step agree from step 0
    report = None
    if prox_specs:
        from repro.training.regularize import sparsity_report
        report = sparsity_report(params, prox_specs)
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32), error_fb=efb,
                      prox_report=report)


def abstract_train_state(cfg: ArchConfig, optimizer: Optimizer,
                         grad_compression: bool = False, prox_specs=None):
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, optimizer,
                                 grad_compression, prox_specs=prox_specs))


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, *,
                    lr: float = 3e-4, grad_clip: float = 1.0,
                    accum_steps: int = 1, grad_compression: bool = False,
                    mesh: Mesh | None = None, unroll: bool = False,
                    prox_specs=None):
    """Returns step(state, batch) -> (state, metrics). jit-able / pjit-ready.

    With ``accum_steps > 1`` the batch's leading dim must be divisible; the
    microbatch loop is a scan (compute/comm of consecutive microbatches
    overlap under XLA's scheduler since the grad psum of microbatch i is
    independent of microbatch i+1's forward).
    """

    def loss_fn(params, batch):
        return api.train_loss(params, cfg, batch, unroll=unroll)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

        micros = jax.tree.map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:]),
            batch)
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tot_l, tot_g), _ = jax.lax.scan(micro, (jnp.zeros((), jnp.float32), z), micros)
        return tot_l / accum_steps, jax.tree.map(lambda g: g / accum_steps, tot_g)

    def apply_update(state: TrainState, loss, grads):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = optimizer.update(grads, state.opt_state, state.params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm}
        report = state.prox_report
        if prox_specs:
            from repro.training.regularize import sparsity_report
            report = sparsity_report(params, prox_specs)
            metrics["dead_groups"] = sum(v["dead"] for v in report.values())
            metrics["prox_penalty"] = sum(v["penalty"] for v in report.values())
        new = TrainState(params=params, opt_state=opt_state, step=state.step + 1,
                         error_fb=state.error_fb, prox_report=report)
        return new, metrics

    if not grad_compression:
        def step(state: TrainState, batch):
            loss, grads = grads_of(state.params, batch)
            return apply_update(state, loss, grads)
        return step

    assert mesh is not None and "pod" in mesh.shape, \
        "grad compression targets the cross-pod all-reduce; need a pod axis"
    n_pods = mesh.shape["pod"]

    def step(state: TrainState, batch):
        # 1) per-pod grads: vmap over a leading pod axis (model compute stays
        #    under plain GSPMD — partial-manual tracing around gathers trips an
        #    XLA SPMD partitioner CHECK, so only the reduction is manual)
        from jax.sharding import NamedSharding
        podded = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:]),
                NamedSharding(mesh, P("pod", "data"))),
            batch)
        losses, grads = jax.vmap(jax.value_and_grad(loss_fn),
                                 in_axes=(None, 0))(state.params, podded)
        grads = jax.tree.map(
            lambda g: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, P("pod"))), grads)

        # 2) int8 error-feedback psum across pods (elementwise body only)
        def reduce_pods(g, e):
            g0 = jax.tree.map(lambda a: a[0], g)
            e0 = jax.tree.map(lambda a: a[0], e)
            gh, eh = compressed_psum(g0, e0, "pod")
            return gh, jax.tree.map(lambda a: a[None], eh)

        fn = compat.shard_map(reduce_pods, mesh=mesh,
                              in_specs=(P("pod"), P("pod")),
                              out_specs=(P(), P("pod")),
                              check_vma=False, axis_names=frozenset({"pod"}))
        grads, new_efb = fn(grads, state.error_fb)
        state = TrainState(params=state.params, opt_state=state.opt_state,
                           step=state.step, error_fb=new_efb,
                           prox_report=state.prox_report)
        return apply_update(state, losses.mean(), grads)

    return step
