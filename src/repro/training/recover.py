"""Post-compression recovery fine-tuning on a :class:`CompressedModel`.

The paper's eq. (9) retrains tied (shared) weights after clustering; Deep
Compression shows the same prune -> retrain loop is where most of the
compression ratio survives.  Here recovery runs *after* LCC decomposition, on
the artifact itself: the frozen shift-add chains stay bitwise-fixed and a
trainable **dense residual in codebook space** rides on top.

Per dense unit the residual ``delta`` has shape [N, C] where C is the packed
decomposition's input width — the shared codebook size for weight-shared
sites, the kept-column count otherwise.  The training-time effective map is

    W_eff = W_frozen + delta[:, labels]        (shared: cluster-tied, eq. (9))
    W_eff = W_frozen + delta                   (unshared)

built through ``compress_adapters.rebind_site_traced`` so the loss is the
family's own forward on the rebound params; gradients flow straight through
the frozen base to ``delta`` (the straight-through estimator — the chains act
as a constant).  For shared sites ``delta[:, labels]`` makes every column of a
cluster share one residual column, so its gradient is the *sum over the
cluster* — exactly the tied-weight gradient of eq. (9).

``write_back`` sparsifies the trained residual under an adds budget (CSD
adds of the residual <= ``residual_frac`` x the unit's LCC adds), then writes
it into every artifact surface at once — ``records[*].effective``, an extra
dense slice on the packed decomposition (``apply_packed_decomposition`` sums
dense slices on top of the fused chains, so serving is exact), the
dense-effective ``params``, and the cost report (``stage_adds['recover']``).
``ServingEngine(artifact=...)`` then serves the recovered model unchanged.

Note: ``CompressedDense.apply`` (the numpy decomposition-only reference path)
does not see the residual; the artifact's effective/params/packed surfaces —
everything serving reads — do.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compress import CompressedDense
from repro.core.csd import adds_csd_matrix
from repro.models import compress_adapters
from repro.optim.optimizers import adamw

__all__ = ["RecoverState", "recoverable_sites", "make_recover_step",
           "recover_artifact", "write_back"]


@jax.tree_util.register_dataclass
@dataclass
class RecoverState:
    deltas: dict[str, jnp.ndarray]  # unit name -> [N, C] codebook-space residual
    opt_state: Any
    step: int


def recoverable_sites(artifact) -> list[tuple[Any, CompressedDense]]:
    """Dense sites of the artifact's family that have a compressed record —
    the units recovery can fine-tune (conv records stay frozen)."""
    sites = compress_adapters.sites_for(artifact.params, artifact.config)
    out = []
    for s in sites:
        rec = artifact.records.get(s.name)
        if isinstance(s, compress_adapters.DenseSite) and \
                isinstance(rec, CompressedDense):
            out.append((s, rec))
    return out


def _site_weight_traced(params, site) -> jnp.ndarray:
    """Traced mirror of ``DenseSite.weight``: the [N, K] y = W x view."""
    a = params
    for k in site.path:
        a = a[k]
    for i in site.index:
        a = a[i]
    return jnp.swapaxes(a, -1, -2) if site.transpose else a


def _expand_delta(delta: jnp.ndarray, rec: CompressedDense,
                  k_orig: int) -> jnp.ndarray:
    """[N, C] codebook residual -> [N, K_orig] original input space."""
    dk = delta[:, jnp.asarray(np.asarray(rec.shared.labels), jnp.int32)] \
        if rec.shared is not None else delta
    kept = jnp.asarray(np.asarray(rec.kept_columns), jnp.int32)
    if kept.shape[0] == k_orig:
        return dk  # keep-in-place pruning / nothing pruned
    return jnp.zeros((delta.shape[0], k_orig), delta.dtype).at[:, kept].set(dk)


def _codebook_width(rec: CompressedDense) -> int:
    return (rec.shared.n_clusters if rec.shared is not None
            else int(rec.kept_columns.size))


def init_deltas(artifact) -> dict[str, jnp.ndarray]:
    return {s.name: jnp.zeros((rec.effective.shape[0], _codebook_width(rec)),
                              jnp.float32)
            for s, rec in recoverable_sites(artifact)}


def make_recover_step(artifact, loss_fn: Callable, *, lr: float = 1e-3,
                      optimizer=None):
    """Build ``(state0, step)`` for recovery fine-tuning.

    ``loss_fn(params, batch) -> scalar`` is the family's own training loss
    (e.g. ``models.mlp.mlp_loss``-style); it sees params with every
    recoverable site rebound to ``frozen + delta``.  Only the deltas train.
    """
    sites = recoverable_sites(artifact)
    base_params = artifact.params
    k_orig = {s.name: int(np.asarray(s.weight(base_params)).shape[1])
              for s, _ in sites}
    opt = optimizer if optimizer is not None else adamw()
    deltas0 = init_deltas(artifact)
    state0 = RecoverState(deltas=deltas0, opt_state=opt.init(deltas0), step=0)

    def rebound(deltas):
        params = base_params
        for s, rec in sites:
            w = _site_weight_traced(params, s)
            d = _expand_delta(deltas[s.name], rec, k_orig[s.name])
            params = compress_adapters.rebind_site_traced(params, s, w + d)
        return params

    def loss_of(deltas, batch):
        return loss_fn(rebound(deltas), batch)

    @jax.jit
    def _jstep(state: RecoverState, batch):
        loss, grads = jax.value_and_grad(loss_of)(state.deltas, batch)
        deltas, opt_state = opt.update(grads, state.opt_state, state.deltas, lr)
        return RecoverState(deltas=deltas, opt_state=opt_state,
                            step=state.step + 1), loss

    def step(state: RecoverState, batch) -> tuple[RecoverState, jnp.ndarray]:
        return _jstep(state, batch)

    step.rebound_params = rebound  # for eval during/after recovery
    return state0, step


def _sparsify_to_budget(d: np.ndarray, max_adds: int, frac_bits: int
                        ) -> np.ndarray:
    """Zero small residual entries until the residual's CSD adds fit
    ``max_adds`` (coarse quantile search — the residual is a correction, not
    a reconstruction, so precision of the cut is not critical)."""
    if adds_csd_matrix(d, frac_bits) <= max_adds:
        return d
    mags = np.abs(d[d != 0.0])
    for q in (50.0, 75.0, 87.5, 93.75, 96.9, 98.4, 99.2, 99.6, 99.8):
        cut = np.percentile(mags, q)
        trial = np.where(np.abs(d) >= cut, d, 0.0)
        if adds_csd_matrix(trial, frac_bits) <= max_adds:
            return trial
    return np.zeros_like(d)


def write_back(artifact, deltas: dict[str, jnp.ndarray], *,
               residual_frac: float = 0.15) -> dict:
    """Write trained residuals into every artifact surface (in place).

    The residual is sparsified so its shift-add cost stays below
    ``residual_frac`` of the unit's LCC adds, then applied identically to
    ``records[name].effective``, the packed decomposition (extra dense slice
    over the full codebook span), and the dense-effective ``params``; the
    report gains ``stage_adds['recover']`` per touched unit.  Returns a
    summary dict per unit.
    """
    rows = {lc.name: lc for lc in artifact.report.layers}
    summary: dict[str, dict] = {}
    for site, rec in recoverable_sites(artifact):
        d = np.asarray(deltas.get(site.name), np.float64) \
            if site.name in deltas else None
        if d is None or not np.any(d):
            continue
        cfg = artifact.unit_config_for(site.name)
        lcc_adds = rec.decomposition.num_adds()
        budget = max(1, int(residual_frac * max(lcc_adds, 1)))
        d = _sparsify_to_budget(d, budget, cfg.frac_bits)
        r_adds = adds_csd_matrix(d, cfg.frac_bits)
        nnz = int(np.count_nonzero(d))
        if nnz == 0:
            summary[site.name] = {"nnz": 0, "recover_adds": 0}
            continue

        # records: effective is kept-column space
        dk = d[:, rec.shared.labels] if rec.shared is not None else d
        rec.effective = rec.effective + dk

        # packed: one extra dense slice spanning the whole codebook input
        pk = artifact.packed.get(site.name)
        if pk is not None:
            extra = ((0, pk.in_dim), jnp.asarray(d, jnp.float32))
            artifact.packed[site.name] = replace(pk, dense=pk.dense + (extra,))

        # params: re-derive the dense-effective leaf from the updated record
        # (zero-expanded, exactly like api.compress_model built it) so params
        # and records stay bitwise-consistent after the single f64->f32 cast
        w = site.weight(artifact.params)
        full = np.zeros_like(w)
        full[:, rec.kept_columns] = rec.effective
        artifact.params = compress_adapters.rebind_site(
            artifact.params, site, full)

        row = rows.get(site.name)
        if row is not None:
            row.stage_adds["recover"] = int(row.stage_adds.get("lcc", 0)) + r_adds
            row.stage_bytes["recover"] = 6 * nnz  # int16 (r,c) + po2 code
            row.extra["recovered"] = True
        summary[site.name] = {"nnz": nnz, "recover_adds": int(r_adds),
                              "lcc_adds": int(lcc_adds)}
    return summary


def recover_artifact(artifact, loss_fn: Callable, batches, *,
                     lr: float = 1e-3, optimizer=None,
                     residual_frac: float = 0.15,
                     progress: Callable | None = None) -> dict:
    """Fine-tune an artifact's residuals over ``batches`` and write back.

    ``batches`` is any iterable of loss-fn batches (one optimizer step each).
    Returns {"losses": [...], "units": write_back summary}.  The artifact is
    updated in place; save it again to persist the recovered values.
    """
    state, step = make_recover_step(artifact, loss_fn, lr=lr,
                                    optimizer=optimizer)
    losses: list[float] = []
    for i, batch in enumerate(batches):
        state, loss = step(state, batch)
        losses.append(float(loss))
        if progress is not None and (i % 20 == 0):
            progress(f"recover step {i}: loss {losses[-1]:.5f}")
    units = write_back(artifact, state.deltas, residual_frac=residual_frac)
    return {"losses": losses, "units": units}
