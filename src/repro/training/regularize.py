"""Compression-aware regularization: ProxSGD group layouts from the adapters.

The paper's Algorithm-1 step 1 (group-lasso regularized training) only pays
off if the groups the prox zeroes are *exactly* the groups the compressor
later slices: dense columns = input neurons (Sec. III-B) and conv input
channels under the eq. (11) FK/PK row stacking.  Those groups are already
enumerated once, per family, by ``models.compress_adapters`` — this module
derives :class:`repro.optim.optimizers.GroupSpec` records from the same site
registry, so training and compression can never disagree about the layout.

Also the per-site sparsity/group-norm report: a traceable summary emitted
into the train state every step (``sparsity_report``), and a host-side
detailed view for drivers (``detailed_group_report``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import GroupSpec, spec_group_norms

__all__ = ["site_group_specs", "sparsity_report", "detailed_group_report",
           "dead_group_fraction"]


def site_group_specs(params, cfg, lam: float,
                     include=None) -> tuple[GroupSpec, ...]:
    """One :class:`GroupSpec` per regularized *leaf*, derived from the
    family's compression-adapter sites.

    Stacked sites (layer/expert axes) share a leaf, so they collapse into one
    spec whose group view covers every stacked copy at once — the prox is the
    same row-wise operator either way.  ``include`` filters site names
    (callable or prefix string), mirroring ``api.compress_model``.
    """
    from repro.models import compress_adapters

    sites = compress_adapters.sites_for(params, cfg)
    if include is not None:
        keep = include if callable(include) else lambda n: n.startswith(include)
        sites = [s for s in sites if keep(s.name)]
    specs: list[GroupSpec] = []
    seen: set[tuple] = set()
    for s in sites:
        if s.path in seen:
            continue  # stacked siblings share the leaf: one spec covers all
        seen.add(s.path)
        if isinstance(s, compress_adapters.ConvSite):
            kind = "conv_in_channels"
        else:
            kind = "in_rows" if s.transpose else "in_cols"
        name = "/".join(str(k) for k in s.path)
        specs.append(GroupSpec(name=name, path=s.path, lam=lam, kind=kind))
    return tuple(specs)


def sparsity_report(params, specs) -> dict:
    """Traceable per-site summary for the train state: per spec the group
    count, exact-zero ("dead") group count, and the group-norm statistics the
    eq. (6) penalty is made of.  Scalars only, so checkpoints stay small."""
    report = {}
    for gs in specs:
        leaf = params
        for k in gs.path:
            leaf = leaf[k]
        norms = spec_group_norms(leaf, gs.kind)
        report[gs.name] = {
            "groups": jnp.asarray(norms.shape[0], jnp.int32),
            "dead": jnp.sum(norms == 0.0).astype(jnp.int32),
            "min_norm": jnp.min(norms),
            "mean_norm": jnp.mean(norms),
            "penalty": gs.lam * jnp.sum(norms),
        }
    return report


def dead_group_fraction(report: dict) -> float:
    """Fraction of exactly-zero groups across every reported site."""
    dead = sum(int(v["dead"]) for v in report.values())
    total = sum(int(v["groups"]) for v in report.values())
    return dead / max(total, 1)


def detailed_group_report(params, specs) -> dict[str, np.ndarray]:
    """Host-side full per-group norms (numpy) per spec name, for drivers that
    want the whole distribution rather than the train-state scalars."""
    out = {}
    for gs in specs:
        leaf = params
        for k in gs.path:
            leaf = leaf[k]
        out[gs.name] = np.asarray(spec_group_norms(jnp.asarray(leaf), gs.kind))
    return out
