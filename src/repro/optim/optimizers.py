"""Optimizers from scratch (no optax in this environment).

SGD(+momentum), AdamW, and ProxSGD — the paper's training rule (eq. (7)):
a gradient step followed by the group-lasso proximal operator (eq. (8)) on the
regularized matrices.  All are pytree-in/pytree-out with explicit state, so
optimizer state inherits parameter sharding (ZeRO via the sharding policy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.group_lasso import group_prox_rows

__all__ = ["sgd", "adamw", "prox_sgd", "global_norm", "clip_by_global_norm",
           "step_decay", "cosine_warmup", "Optimizer"]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, lr) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        params = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                              params, mu)
        return params, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)

        def upd(p, m, v):
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                step = step + lr * weight_decay * p32
            return (p32 - step).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def prox_sgd(momentum: float = 0.9,
             prox_spec: dict[str, tuple[float, str]] | None = None) -> Optimizer:
    """Paper eq. (7): SGD step then block soft threshold on regularized weights.

    prox_spec: {path-substring: (lambda, mode)}, mode in {"columns", "rows"} —
    which axis forms the groups ("columns" = input neurons, the dense-layer
    choice of Sec. III-B).  Threshold = lr * lambda (the eq. (8) scaling).
    """
    base = sgd(momentum)
    spec = prox_spec or {}

    def update(grads, state, params, lr):
        params, state = base.update(grads, state, params, lr)
        if not spec:
            return params, state
        flat = jax.tree_util.tree_flatten_with_path(params)
        leaves = []
        for path, leaf in flat[0]:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for pat, (lam, mode) in spec.items():
                if pat in name and leaf.ndim == 2:
                    t = lr * lam
                    if mode == "columns":
                        leaf = group_prox_rows(leaf.T, t).T
                    else:
                        leaf = group_prox_rows(leaf, t)
                    break
            leaves.append(leaf)
        params = jax.tree_util.tree_unflatten(flat[1], leaves)
        return params, state

    return Optimizer(base.init, update)


def step_decay(base_lr: float, decay: float = 0.95, every: int = 10):
    """The paper's MLP schedule: x0.95 every 10 epochs."""
    def lr(epoch):
        return base_lr * decay ** (epoch // every)
    return lr


def cosine_warmup(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * base_lr + (1 - floor) * base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
