"""Optimizers from scratch (no optax in this environment).

SGD(+momentum), AdamW, and ProxSGD — the paper's training rule (eq. (7)):
a gradient step followed by the group-lasso proximal operator (eq. (8)) on the
regularized matrices.  All are pytree-in/pytree-out with explicit state, so
optimizer state inherits parameter sharding (ZeRO via the sharding policy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.group_lasso import group_prox_rows

__all__ = ["sgd", "adamw", "prox_sgd", "global_norm", "clip_by_global_norm",
           "step_decay", "cosine_warmup", "Optimizer", "GroupSpec",
           "spec_group_view", "spec_group_norms", "apply_spec_prox"]


@dataclass(frozen=True)
class GroupSpec:
    """One regularized leaf and its group layout (derived from the same
    compression adapters the pipeline slices, see
    ``repro.training.regularize.site_group_specs``).

    kind:
      ``in_rows``          stored [..., K, N] (``dense_init`` layout): groups
                           are the input neurons = rows of the stored leaf;
      ``in_cols``          stored [..., N, K] (the paper's y = W x layout):
                           groups are columns of the stored leaf;
      ``conv_in_channels`` conv kernel [N, K, O, O]: groups are input channels
                           (the eq. (11) FK/PK row stacking — all rows of
                           input channel k share one group).
    """

    name: str
    path: tuple
    lam: float
    kind: str


def spec_group_view(leaf: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Reshape a leaf so groups are rows of a 2-D [G, M] view (invertible by
    :func:`_spec_unview` with the original shape)."""
    if kind == "in_rows":
        return leaf.reshape(-1, leaf.shape[-1])
    if kind == "in_cols":
        swapped = jnp.swapaxes(leaf, -1, -2)
        return swapped.reshape(-1, swapped.shape[-1])
    if kind == "conv_in_channels":
        moved = jnp.moveaxis(leaf, 1, 0)  # [K, N, O, O]
        return moved.reshape(moved.shape[0], -1)
    raise ValueError(f"unknown group kind {kind!r}")


def _spec_unview(a2: jnp.ndarray, kind: str, shape: tuple) -> jnp.ndarray:
    if kind == "in_rows":
        return a2.reshape(shape)
    if kind == "in_cols":
        swapped_shape = shape[:-2] + (shape[-1], shape[-2])
        return jnp.swapaxes(a2.reshape(swapped_shape), -1, -2)
    if kind == "conv_in_channels":
        moved = a2.reshape((shape[1], shape[0]) + shape[2:])
        return jnp.moveaxis(moved, 0, 1)
    raise ValueError(f"unknown group kind {kind!r}")


def spec_group_norms(leaf: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Per-group l2 norms [G] of a leaf under a spec's group layout."""
    a2 = spec_group_view(leaf.astype(jnp.float32), kind)
    return jnp.sqrt(jnp.sum(a2 * a2, axis=-1))


def apply_spec_prox(leaf: jnp.ndarray, kind: str, thresh,
                    use_kernel: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Block soft threshold on a leaf's groups.  ``use_kernel=True`` routes
    through the fused ``kernels.group_prox`` Pallas kernel (interpret-mode
    fallback off-TPU via ``kernels.dispatch.resolve_interpret``)."""
    a2 = spec_group_view(leaf, kind)
    if use_kernel:
        from repro.kernels.group_prox import group_prox

        out = group_prox(a2, thresh, interpret=interpret)
    else:
        out = group_prox_rows(a2, thresh)
    return _spec_unview(out, kind, leaf.shape)


def _tree_get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _tree_set(tree, path, value):
    if not path:
        return value
    k, rest = path[0], path[1:]
    if isinstance(tree, list):
        out = list(tree)
        out[k] = _tree_set(tree[k], rest, value)
        return out
    out = dict(tree)
    out[k] = _tree_set(tree[k], rest, value)
    return out


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]  # (grads, state, params, lr) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def sgd(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        params = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                              params, mu)
        return params, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)

        def upd(p, m, v):
            step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                step = step + lr * weight_decay * p32
            return (p32 - step).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def prox_sgd(momentum: float = 0.9,
             prox_spec: dict[str, tuple[float, str]] | None = None,
             specs: tuple[GroupSpec, ...] | list[GroupSpec] = (),
             use_kernel: bool = True,
             interpret: bool | None = None) -> Optimizer:
    """Paper eq. (7): SGD step then block soft threshold on regularized weights.

    Two ways to name the regularized groups:

    * ``specs`` — structured :class:`GroupSpec` records (one per leaf, exact
      path + group layout), normally derived from the compression adapters via
      ``repro.training.regularize.site_group_specs`` so ProxSGD regularizes
      exactly the groups the compressor will slice.  The prox runs through the
      fused ``kernels.group_prox`` Pallas kernel (``use_kernel=False`` falls
      back to the plain jnp path; ``interpret`` overrides kernel dispatch).
    * ``prox_spec`` — the legacy substring form {path-substring:
      (lambda, mode)}, mode in {"columns", "rows"} ("columns" = input neurons,
      the dense-layer choice of Sec. III-B), applied to 2-D leaves only.

    Threshold = lr * lambda (the eq. (8) scaling) in both forms.
    """
    base = sgd(momentum)
    spec = prox_spec or {}
    specs = tuple(specs)

    def update(grads, state, params, lr):
        params, state = base.update(grads, state, params, lr)
        for gs in specs:
            leaf = _tree_get(params, gs.path)
            leaf = apply_spec_prox(leaf, gs.kind, lr * gs.lam,
                                   use_kernel=use_kernel, interpret=interpret)
            params = _tree_set(params, gs.path, leaf)
        if not spec:
            return params, state
        flat = jax.tree_util.tree_flatten_with_path(params)
        leaves = []
        for path, leaf in flat[0]:
            name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for pat, (lam, mode) in spec.items():
                if pat in name and leaf.ndim == 2:
                    t = lr * lam
                    if mode == "columns":
                        leaf = group_prox_rows(leaf.T, t).T
                    else:
                        leaf = group_prox_rows(leaf, t)
                    break
            leaves.append(leaf)
        params = jax.tree_util.tree_unflatten(flat[1], leaves)
        return params, state

    return Optimizer(base.init, update)


def step_decay(base_lr: float, decay: float = 0.95, every: int = 10):
    """The paper's MLP schedule: x0.95 every 10 epochs."""
    def lr(epoch):
        return base_lr * decay ** (epoch // every)
    return lr


def cosine_warmup(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * base_lr + (1 - floor) * base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
