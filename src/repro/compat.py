"""jax version-compatibility shims.

The baked container pins jax 0.4.37 while parts of this codebase were written
against the >= 0.6 API surface.  Everything version-gated lives here so the
rest of the repo imports one stable spelling:

* ``make_mesh(shapes, names)`` — newer jax grew an ``axis_types=`` kwarg and
  ``jax.sharding.AxisType``; 0.4.37 has neither (every axis is implicitly
  "auto"), so we only pass ``axis_types`` when the enum exists.
* ``shard_map(...)`` — ``jax.shard_map`` with ``check_vma=`` / ``axis_names=``
  on new jax; ``jax.experimental.shard_map.shard_map`` with ``check_rep=`` /
  ``auto=`` (the complement of ``axis_names``) on 0.4.x.
* ``manual_axis_names()`` — mesh axes that are Manual at the current trace
  point (``jax.sharding.get_abstract_mesh`` on new jax; 0.4.x has no abstract
  mesh, so nothing is ever reported Manual — matching its semantics, where
  sharding constraints inside ``shard_map`` bodies are simply invalid and the
  caller must avoid them by construction).
* ``set_global_mesh(mesh)`` — ``jax.sharding.set_mesh`` when present, no-op
  otherwise (0.4.x has no global mesh; explicit ``Mesh`` context managers and
  ``NamedSharding`` cover the same programs).
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map", "manual_axis_names", "set_global_mesh"]


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with every axis auto-typed, on any supported jax."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, devices=devices, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    """Portable ``shard_map``.

    ``axis_names`` (new-jax spelling) is the set of mesh axes that are manual
    inside the body; on 0.4.x it becomes ``auto = mesh.axis_names - axis_names``.
    ``check_vma`` maps onto 0.4.x's ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    mapped = _shard_map(f, **kwargs)
    if not kwargs.get("auto"):
        return mapped

    # 0.4.x's eager shard_map raises a bare NotImplementedError for auto axes;
    # surface the actual requirement instead
    def _jit_required(*args, **kw):
        try:
            return mapped(*args, **kw)
        except NotImplementedError as e:
            raise NotImplementedError(
                "shard_map with axis_names= (partially-auto axes) only runs "
                "under jax.jit on jax<0.5 — wrap the call in jax.jit") from e
    return _jit_required


def manual_axis_names() -> set:
    """Mesh axes that are Manual at the current trace point (may be empty)."""
    get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract_mesh is None:
        return set()
    try:
        am = get_abstract_mesh()
        if am is None or not am.axis_names:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if t == jax.sharding.AxisType.Manual}
    except Exception:
        return set()


def set_global_mesh(mesh) -> None:
    """``jax.sharding.set_mesh`` when the running jax has a global mesh."""
    if hasattr(jax.sharding, "set_mesh"):
        jax.sharding.set_mesh(mesh)
