"""Metrics registry: counters, gauges, bounded-bucket histograms.

Design constraints, in order:

1. **Hot-loop cheap.**  The serving engine records 2 counters + 1 histogram
   per fused decode step; a metric update is one dict write under an RLock
   (sub-microsecond), and callers pre-resolve their metric objects once so
   the per-step path never touches the registry's name table.
2. **Thread-safe.**  The scheduler's streaming callbacks, the metrics HTTP
   thread and the pipeline's event stream may all touch the registry
   concurrently; every mutation and every export walks under one registry
   RLock, so exports are consistent snapshots.
3. **Stdlib only.**  Export is Prometheus text (``to_prometheus``) served by
   an ``http.server`` thread (:func:`start_metrics_server`) or a JSON
   snapshot (``snapshot`` / :func:`dump_metrics`); :func:`parse_prometheus`
   closes the round trip for tests and offline tooling.

Labels are declared at metric creation (``labels=("kind",)``) and passed as
keywords on update (``c.inc(1, kind="cache_hit")``).  Histograms use fixed
ascending bucket edges (``le`` semantics: an observation lands in the first
bucket whose edge is >= the value) so memory is bounded regardless of the
observation stream.
"""
from __future__ import annotations

import json
import threading
from bisect import bisect_left

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "parse_prometheus", "get_global", "merged_snapshot",
           "dump_metrics", "start_metrics_server", "DEFAULT_TIME_BUCKETS"]

# seconds-scale latency edges: 0.5ms decode steps through 30s prefills
DEFAULT_TIME_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                        0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _esc(s: str) -> str:
    return str(s).replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unesc(s: str) -> str:
    return (s.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\"))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, label_names, lock):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = lock
        self._vals: dict[tuple, float] = {}

    def _key(self, labels: dict) -> tuple:
        if not labels and not self.label_names:
            return ()
        if set(labels) != set(self.label_names):
            raise ValueError(f"{self.name}: labels {sorted(labels)} != "
                             f"declared {sorted(self.label_names)}")
        return tuple(str(labels[k]) for k in self.label_names)

    def get(self, **labels) -> float:
        with self._lock:
            return self._vals.get(self._key(labels), 0.0)

    @property
    def value(self) -> float:
        """No-label convenience accessor."""
        return self.get()

    def values(self) -> list[dict]:
        with self._lock:
            return [{"labels": dict(zip(self.label_names, k)), "value": v}
                    for k, v in self._vals.items()]


class Counter(_Metric):
    kind = "counter"

    def inc(self, n: float = 1.0, **labels) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (n={n})")
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + n


class Gauge(_Metric):
    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = float(v)

    def inc(self, n: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._vals[k] = self._vals.get(k, 0.0) + n

    def dec(self, n: float = 1.0, **labels) -> None:
        self.inc(-n, **labels)


class Histogram(_Metric):
    """Bounded-bucket histogram: fixed ascending edges + an implicit +Inf
    bucket; per label-set state is ``(bucket counts, sum, count)``."""

    kind = "histogram"

    def __init__(self, name, help, label_names, lock,
                 buckets=DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, label_names, lock)
        edges = tuple(float(b) for b in buckets)
        if not edges or any(nxt <= prev for nxt, prev in zip(edges[1:], edges)):
            raise ValueError(f"{name}: bucket edges must ascend, got {edges}")
        self.buckets = edges

    def observe(self, v: float, **labels) -> None:
        k = self._key(labels)
        i = bisect_left(self.buckets, v)  # le semantics: v == edge lands here
        with self._lock:
            st = self._vals.get(k)
            if st is None:
                st = self._vals[k] = [[0] * (len(self.buckets) + 1), 0.0, 0]
            st[0][i] += 1
            st[1] += v
            st[2] += 1

    def values(self) -> list[dict]:
        with self._lock:
            out = []
            for k, (counts, total, n) in self._vals.items():
                cum, acc = {}, 0
                for edge, c in zip(self.buckets, counts):
                    acc += c
                    cum[_fmt(edge)] = acc
                cum["+Inf"] = acc + counts[-1]
                out.append({"labels": dict(zip(self.label_names, k)),
                            "count": n, "sum": total, "buckets": cum})
            return out


class MetricsRegistry:
    """Name-keyed metric store; ``counter``/``gauge``/``histogram`` are
    get-or-create, so independent subsystems can share one registry without
    coordinating registration order."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_make(self, cls, name, help, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labels, self._lock,
                                              **kw)
                return m
            if not isinstance(m, cls) or m.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} with "
                    f"labels {m.label_names}, requested {cls.kind} with "
                    f"{tuple(labels)}")
            return m

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._get_or_make(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help, labels,
                                 buckets=buckets)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._metrics

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    # ------------------------------------------------------------------ export
    def snapshot(self) -> dict:
        """JSON-able ``{name: {type, help, values}}`` consistent snapshot."""
        with self._lock:
            return {name: {"type": m.kind, "help": m.help,
                           "values": m.values()}
                    for name, m in sorted(self._metrics.items())}

    def to_prometheus(self) -> str:
        lines: list[str] = []

        def series(name, labels, v):
            if labels:
                lab = ",".join(f'{k}="{_esc(val)}"'
                               for k, val in sorted(labels.items()))
                lines.append(f"{name}{{{lab}}} {_fmt(v)}")
            else:
                lines.append(f"{name} {_fmt(v)}")

        with self._lock:
            for name, m in sorted(self._metrics.items()):
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                for row in m.values():
                    if m.kind == "histogram":
                        for edge, c in row["buckets"].items():
                            series(f"{name}_bucket",
                                   {**row["labels"], "le": edge}, c)
                        series(f"{name}_sum", row["labels"], row["sum"])
                        series(f"{name}_count", row["labels"], row["count"])
                    else:
                        series(name, row["labels"], row["value"])
        return "\n".join(lines) + "\n"

    def flat(self) -> dict:
        """``{(series_name, sorted-label-tuple): value}`` — the exact map
        :func:`parse_prometheus` recovers from ``to_prometheus`` output."""
        out: dict[tuple, float] = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                for row in m.values():
                    if m.kind == "histogram":
                        for edge, c in row["buckets"].items():
                            lab = dict(row["labels"], le=edge)
                            out[(f"{name}_bucket",
                                 tuple(sorted(lab.items())))] = float(c)
                        lab = tuple(sorted(row["labels"].items()))
                        out[(f"{name}_sum", lab)] = float(row["sum"])
                        out[(f"{name}_count", lab)] = float(row["count"])
                    else:
                        out[(name, tuple(sorted(row["labels"].items())))] = \
                            float(row["value"])
        return out


def parse_prometheus(text: str) -> dict:
    """Parse Prometheus text back to ``{(name, sorted-label-tuple): value}``.

    Supports exactly what :meth:`MetricsRegistry.to_prometheus` emits (which
    is the standard text exposition format for counters/gauges/histograms).
    """
    out: dict[tuple, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name, rest = line.split("{", 1)
            lab_str, val_str = rest.rsplit("}", 1)
            labels = {}
            # split on '," ' boundaries without breaking escaped quotes
            for part in lab_str.split('",'):
                k, _, v = part.partition('="')
                labels[k.strip()] = _unesc(v.rstrip('"'))
            key = (name, tuple(sorted(labels.items())))
        else:
            name, _, val_str = line.partition(" ")
            key = (name, ())
        v = val_str.strip()
        out[key] = float("inf") if v == "+Inf" else float(v)
    return out


# --------------------------------------------------------------------- global
# Process-wide registry for publishers with no natural owner (the kernel
# dispatch layer's live Pallas launch counter).  Engine/pipeline registries
# stay per-instance so tests and concurrent engines don't share counters;
# exports merge both via merged_snapshot / start_metrics_server.
_GLOBAL = MetricsRegistry()


def get_global() -> MetricsRegistry:
    return _GLOBAL


def merged_snapshot(registries) -> dict:
    """Union of several registries' snapshots (later registries win on a
    name collision — pass the most specific one last)."""
    out: dict = {}
    for reg in registries:
        out.update(reg.snapshot())
    return out


def dump_metrics(path: str, registries, **sections) -> None:
    """Write ``{"metrics": merged snapshot, **sections}`` as JSON — the
    on-disk format ``--metrics-out`` produces across every launch driver."""
    payload = {"metrics": merged_snapshot(registries)}
    payload.update(sections)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")


def start_metrics_server(registries, port: int = 0, host: str = "127.0.0.1"):
    """Serve ``GET /metrics`` (Prometheus text) from a daemon thread.

    Returns the live ``ThreadingHTTPServer`` — read ``.server_port`` when
    ``port=0`` picked an ephemeral one, call ``.shutdown()`` to stop.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    regs = list(registries)

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path not in ("/", "/metrics"):
                self.send_error(404)
                return
            body = "".join(r.to_prometheus() for r in regs).encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # keep the serving console clean
            pass

    srv = ThreadingHTTPServer((host, port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="obs-metrics-http").start()
    return srv
