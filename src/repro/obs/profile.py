"""Step profiling and the live roofline.

:class:`StepProfiler` is a bounded wall-time ring buffer for the engine's
fused decode step.  Host wall-clock alone under-reports async dispatch, so
every ``fence_every``-th sample the profiler calls ``jax.block_until_ready``
on the value the caller hands it *before* reading the clock — those samples
carry the true device latency while the rest stay free.  (The serving engine
already syncs each step when it pulls sampled tokens to host, so every sample
is honest there; the fencing matters for callers that keep steps in flight.)

:func:`roofline` is the pure function behind ``BENCH_serving.json``'s
roofline section: per-site shift-add budget from an artifact's
:class:`~repro.core.cost.ModelCostReport` joined with a measured decode
throughput into achieved adds/s.  :func:`live_roofline` feeds it from a
*running* engine — artifact from the executor, tok/s from the engine's own
profiler, launch counts from the per-bucket registry — so the table no
longer requires the offline bench path (ROADMAP Open item 1 asks exactly
for this to localize the remaining gap to dense).
"""
from __future__ import annotations

import time
from collections import deque

__all__ = ["StepProfiler", "roofline", "live_roofline"]


def _pct(sorted_vals, q: float) -> float | None:
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[int(i)]


class StepProfiler:
    """Ring buffer of per-step wall times with periodic device fencing.

    Usage (the engine's step loop)::

        t0 = prof.begin()
        out = step_fn(...)
        prof.end(t0, tokens=n_active, fence=out)

    ``fence`` is only synced on every ``fence_every``-th sample; pass
    ``fence=None`` to never sync (pure host timing).
    """

    def __init__(self, capacity: int = 4096, fence_every: int = 32,
                 clock=time.perf_counter):
        self.capacity = int(capacity)
        self.fence_every = max(0, int(fence_every))
        self.clock = clock
        self._ring: deque = deque(maxlen=self.capacity)  # (wall_s, tokens, fenced)
        self._n = 0          # lifetime samples (ring may have dropped old ones)
        self._fenced = 0

    def begin(self) -> float:
        return self.clock()

    def end(self, t0: float, tokens: int = 0, fence=None) -> float:
        self._n += 1
        fenced = (fence is not None and self.fence_every
                  and self._n % self.fence_every == 0)
        if fenced:
            import jax
            jax.block_until_ready(fence)
            self._fenced += 1
        dt = self.clock() - t0
        self._ring.append((dt, int(tokens), fenced))
        return dt

    def __len__(self) -> int:
        return len(self._ring)

    @property
    def total_steps(self) -> int:
        return self._n

    def summary(self) -> dict:
        """Aggregates over the samples currently in the ring."""
        samples = list(self._ring)
        if not samples:
            return {"steps": 0, "total_steps": self._n, "fenced": self._fenced,
                    "tok_s": None, "mean_ms": None, "p50_ms": None,
                    "p99_ms": None}
        walls = sorted(s[0] for s in samples)
        total_wall = sum(walls)
        total_tok = sum(s[1] for s in samples)
        return {
            "steps": len(samples),
            "total_steps": self._n,
            "fenced": self._fenced,
            "tok_s": (total_tok / total_wall) if total_wall > 0 else None,
            "mean_ms": total_wall / len(walls) * 1e3,
            "p50_ms": _pct(walls, 0.50) * 1e3,
            "p99_ms": _pct(walls, 0.99) * 1e3,
        }


def roofline(artifact, decode_tok_s, *, pallas_launches=None,
             n_layer_plans=None, mode: str | None = None,
             arch: str | None = None) -> dict:
    """Per-site shift-add budget x measured throughput -> achieved adds/s.

    Same shape as the ``roofline`` sections in ``BENCH_serving.json``, so
    live-engine output and offline-bench output diff cleanly.
    """
    rep = artifact.report
    total_lcc = rep.total_stage("lcc")
    tok_s = None if decode_tok_s is None else float(decode_tok_s)
    sec = {
        "mode": mode, "arch": arch,
        "total_baseline_adds": rep.total_baseline(),
        "total_lcc_adds": total_lcc,
        "decode_tok_s_n8": round(tok_s, 2) if tok_s is not None else None,
        "pallas_launches": pallas_launches,
        "n_layer_plans": n_layer_plans,
        "achieved_adds_per_s": (round(tok_s * total_lcc)
                                if tok_s is not None else None),
        "sites": [{"site": l.name, "baseline_adds": l.baseline_adds,
                   "lcc_adds": l.stage_adds.get("lcc"),
                   "ratio": (round(l.ratio("lcc"), 2)
                             if l.stage_adds.get("lcc") else None),
                   "achieved_adds_per_s": (
                       round(tok_s * l.stage_adds["lcc"])
                       if tok_s is not None and l.stage_adds.get("lcc")
                       else None)}
                  for l in rep.layers],
    }
    stats = getattr(artifact, "pipeline_stats", None) or {}
    waste = stats.get("padding_waste")
    if waste:
        sec["padding_waste"] = waste
    seg = stats.get("segment_layout")
    if seg:
        sec["segment_layout"] = seg
    return sec


def live_roofline(engine) -> dict | None:
    """Roofline table from a *running* compressed engine's own telemetry:
    artifact from the executor, tok/s from ``engine.profiler``, launch count
    from the per-bucket trace registry.  ``None`` for dense engines or when
    the profiler hasn't accumulated any decode steps yet."""
    art = getattr(engine, "artifact", None)
    prof = getattr(engine, "profiler", None)
    if art is None or prof is None:
        return None
    summ = prof.summary()
    if not summ["steps"]:
        return None
    sec = roofline(
        art, summ["tok_s"],
        pallas_launches=engine.pallas_launches_per_step,
        n_layer_plans=engine.n_layer_plans,
        mode="live", arch=getattr(engine.cfg, "name", None))
    sec["profiler"] = summ
    return sec
