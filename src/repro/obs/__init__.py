"""Unified telemetry: metrics registry, request tracing, step profiling.

Three pillars, all stdlib-only (no prometheus_client / opentelemetry):

* :mod:`repro.obs.metrics` — a thread-safe :class:`MetricsRegistry` of
  counters, gauges and bounded-bucket histograms, cheap enough for the
  serving host loop, exported as Prometheus text or JSON.  The serving
  engine, scheduler, KV pool, compression pipeline and trainer all publish
  into it, replacing the ad-hoc stat dicts that used to live on each.
* :mod:`repro.obs.trace` — per-request :class:`Span` lifecycle
  (enqueue -> admit -> prefill -> decode marks -> retire) yielding TTFT,
  time-per-output-token, queue wait and block-growth stalls, dumped as JSONL.
* :mod:`repro.obs.profile` — :class:`StepProfiler` wall-time ring buffer with
  periodic ``block_until_ready`` fencing, plus the live roofline that ties an
  artifact's per-site shift-add budget to the throughput a *running* engine
  achieves (the same table ``BENCH_serving.json`` tracks offline).

Dependency rule: ``obs`` imports nothing from the rest of ``repro`` (jax only
lazily, for fencing), so any layer — including ``kernels.dispatch`` — may
publish into it without cycles.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               dump_metrics, get_global, merged_snapshot,
                               parse_prometheus, start_metrics_server)
from repro.obs.profile import StepProfiler, live_roofline, roofline
from repro.obs.trace import RequestTracer, Span

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "parse_prometheus",
    "get_global", "merged_snapshot", "dump_metrics", "start_metrics_server",
    "RequestTracer", "Span", "StepProfiler", "roofline", "live_roofline",
]
