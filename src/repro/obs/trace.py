"""Per-request span tracing for the serving stack.

A :class:`Span` follows one request through its whole lifecycle::

    enqueue -> admit -> (prefill meta) -> token ... token -> retire
       |         |                          |                  |
    queue wait   +-- TTFT ------------------+    time/output-token (TPOT)

The scheduler drives the lifecycle (it owns the request namespace); the
engine contributes per-request facts — prefill wall, prefix-cache hit tokens,
decode-time block growth — through ``GenerationResult.stats``, which the
scheduler folds into the span's ``meta`` at retire.  Every ``mark_every``-th
token the span records a decode mark ``(n_tokens, t)``, so a long generation
shows its pacing, not just its endpoints.

Span ids are tracer-allocated (monotonic) rather than request ids: request id
namespaces restart per scheduler, and one engine may serve several scheduler
generations (``generate()`` builds a fresh one per call).

``dump_jsonl`` writes one JSON object per span — completed spans first, then
any still-open ones (``status == "open"``), so "zero unclosed spans" is a
grep away for CI.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field

__all__ = ["Span", "RequestTracer"]

_TERMINAL = ("ok", "error", "cancelled")


def _pct(sorted_vals: list[float], q: float) -> float | None:
    """Nearest-rank percentile over an ascending list (stdlib-only)."""
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[int(i)]


@dataclass
class Span:
    sid: int
    rid: int
    prompt_len: int
    enqueue_t: float
    admit_t: float | None = None
    first_token_t: float | None = None
    last_token_t: float | None = None
    retire_t: float | None = None
    n_tokens: int = 0
    status: str = "open"
    error: str | None = None
    marks: list = field(default_factory=list)  # [(n_tokens, t_abs), ...]
    meta: dict = field(default_factory=dict)

    # ------------------------------------------------------------- derived
    @property
    def queue_wait_s(self) -> float | None:
        return None if self.admit_t is None else self.admit_t - self.enqueue_t

    @property
    def ttft_s(self) -> float | None:
        """Time to first token, measured from arrival (enqueue)."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.enqueue_t)

    @property
    def tpot_s(self) -> float | None:
        """Mean time per output token after the first."""
        if self.first_token_t is None or self.n_tokens < 2:
            return None
        return (self.last_token_t - self.first_token_t) / (self.n_tokens - 1)

    @property
    def e2e_s(self) -> float | None:
        return None if self.retire_t is None else self.retire_t - self.enqueue_t

    def to_dict(self) -> dict:
        t0 = self.enqueue_t
        d = {"sid": self.sid, "rid": self.rid, "prompt_len": self.prompt_len,
             "status": self.status, "error": self.error,
             "n_tokens": self.n_tokens,
             "queue_wait_s": self.queue_wait_s, "ttft_s": self.ttft_s,
             "tpot_s": self.tpot_s, "e2e_s": self.e2e_s,
             "marks": [{"tokens": n, "t_s": t - t0} for n, t in self.marks]}
        d.update(self.meta)
        return d


class RequestTracer:
    """Span factory + sink.  Pass ``metrics=`` (a
    :class:`~repro.obs.metrics.MetricsRegistry`) to additionally publish
    TTFT / TPOT / queue-wait histograms and per-status request counters as
    spans retire; ``clock=`` is injectable for deterministic tests."""

    def __init__(self, *, mark_every: int = 8, metrics=None,
                 clock=time.perf_counter):
        self.mark_every = max(1, int(mark_every))
        self.clock = clock
        self._lock = threading.RLock()
        self._next_sid = 0
        self._open: dict[int, Span] = {}
        self.completed: list[Span] = []
        self._m = None
        if metrics is not None:
            self._m = {
                "ttft": metrics.histogram(
                    "serving_ttft_seconds", "time to first token (arrival)"),
                "tpot": metrics.histogram(
                    "serving_tpot_seconds", "time per output token"),
                "queue": metrics.histogram(
                    "serving_queue_wait_seconds", "enqueue -> admit wait"),
                "requests": metrics.counter(
                    "serving_requests_total", "retired requests by status",
                    labels=("status",)),
            }

    # -------------------------------------------------------------- lifecycle
    def enqueue(self, rid: int, prompt_len: int) -> int:
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
            self._open[sid] = Span(sid=sid, rid=rid, prompt_len=prompt_len,
                                   enqueue_t=self.clock())
        return sid

    def admit(self, sid: int) -> None:
        s = self._open.get(sid)
        if s is not None and s.admit_t is None:
            s.admit_t = self.clock()

    def token(self, sid: int) -> None:
        s = self._open.get(sid)
        if s is None:
            return
        t = self.clock()
        if s.first_token_t is None:
            s.first_token_t = t
        s.last_token_t = t
        s.n_tokens += 1
        if s.n_tokens % self.mark_every == 0:
            s.marks.append((s.n_tokens, t))

    def annotate(self, sid: int, **meta) -> None:
        s = self._open.get(sid)
        if s is not None:
            s.meta.update(meta)

    def retire(self, sid: int, status: str = "ok",
               error: str | None = None) -> Span | None:
        """Close a span exactly once (a second retire is a no-op, so a
        cancel racing a natural finish cannot double-count)."""
        if status not in _TERMINAL:
            raise ValueError(f"retire status {status!r} not in {_TERMINAL}")
        with self._lock:
            s = self._open.pop(sid, None)
            if s is None:
                return None
            s.retire_t = self.clock()
            s.status = status
            s.error = error
            self.completed.append(s)
        if self._m is not None:
            self._m["requests"].inc(1, status=status)
            if s.queue_wait_s is not None:
                self._m["queue"].observe(s.queue_wait_s)
            if s.ttft_s is not None:
                self._m["ttft"].observe(s.ttft_s)
            if s.tpot_s is not None:
                self._m["tpot"].observe(s.tpot_s)
        return s

    # ---------------------------------------------------------------- queries
    @property
    def open_count(self) -> int:
        with self._lock:
            return len(self._open)

    def spans(self, status: str | None = None) -> list[Span]:
        with self._lock:
            spans = list(self.completed)
            if status is None or status == "open":
                spans += list(self._open.values())
        if status is not None:
            spans = [s for s in spans if s.status == status]
        return spans

    def summary(self) -> dict:
        """Aggregate percentiles over completed spans (seconds)."""
        with self._lock:
            done = list(self.completed)
            n_open = len(self._open)
        by_status: dict[str, int] = {}
        for s in done:
            by_status[s.status] = by_status.get(s.status, 0) + 1

        def stats(vals):
            vals = sorted(v for v in vals if v is not None)
            return {"p50": _pct(vals, 0.50), "p99": _pct(vals, 0.99),
                    "n": len(vals)}

        return {
            "completed": len(done), "open": n_open, "by_status": by_status,
            "queue_wait_s": stats(s.queue_wait_s for s in done),
            "ttft_s": stats(s.ttft_s for s in done),
            "tpot_s": stats(s.tpot_s for s in done),
            "e2e_s": stats(s.e2e_s for s in done),
            "tokens": sum(s.n_tokens for s in done),
        }

    def dump_jsonl(self, path: str) -> int:
        """Write every span (completed, then open) as JSONL; returns the
        number of still-open spans so callers can assert on leaks."""
        spans = self.spans()
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), default=str) + "\n")
        return sum(s.status == "open" for s in spans)
