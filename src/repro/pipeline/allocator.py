"""Adds-budget allocator: per-unit rate allocation under a global additions
budget (the paper's whole objective — minimize adds — made a first-class
constraint, in the spirit of Deep Compression's per-layer rate allocation).

Search strategy
---------------
Every unit gets a small **candidate ladder** of configs ordered cheap->rich
(:func:`candidate_ladder`): the knobs are the LCC algorithm (FS compresses
harder than FP at equal fidelity), the fidelity target (``snr_offset_db``
against the CSD-matched SNR), the per-row term budget ``s_terms``, the prune
threshold and the weight-sharing acceptance bound.  All (unit x level)
candidates are evaluated through the pipeline's job graph — fully parallel,
and content-addressed so repeated levels and re-runs are free — yielding the
exact per-unit cost curve (``lcc`` adds from the :class:`ModelCostReport`)
and quality curve (achieved SNR).

Selection is the classic marginal-utility greedy for rate allocation: start
every unit at its cheapest level, then repeatedly apply the single upgrade
with the best  d(quality)/d(adds)  ratio that still fits the budget, where
quality is achieved SNR weighted by the unit's signal energy (a unit holding
10x the energy of another contributes 10x per dB to end-to-end fidelity).
Upgrades that *save* adds without losing quality are taken unconditionally.

The ladder is discrete, so the greedy alone can leave slack of up to one
upgrade step.  A final **trim** pass closes it by binary-searching three
continuous dials per remaining unit — the shared-cluster count (the bridge
across the ladder's biggest structural jump, sharing vs none), the current
level's ``snr_offset_db`` upward, and the next level's downward — and keeping
whichever spends the most leftover budget.  Each probe re-evaluates a single
unit (every other unit is a content-addressed cache hit), so the search lands
within ``trim_tol`` (default 5%) of the requested budget whenever the dials
have that much range.
"""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core.compress import (CompressedDense, CompressibleDense,
                                 CompressionConfig)

__all__ = ["candidate_ladder", "allocate_budget"]

_SNR_CAP_DB = 120.0  # exact reconstructions report inf; cap for arithmetic


def _prune_at_least(base_tol: float, floor: float) -> float:
    """Raise the pruning threshold magnitude to ``floor``, preserving the
    keep-in-place convention (negative tolerance, see
    ``core.compress.prune_columns``)."""
    mag = max(abs(base_tol), floor)
    return -mag if base_tol < 0 else mag


def candidate_ladder(base: CompressionConfig) -> list[CompressionConfig]:
    """Cheap->rich per-unit plans derived from ``base``.

    level 0  FS at -9 dB, aggressive pruning, sharing always accepted — the
             adds floor;
    level 1  sparsity-first: the base knobs with group-lasso-scale pruning —
             for regularized-trained weights this harvests dead groups (0-add
             skips in the prune-aware planner) before spending on FP terms;
    level 2  FS at -4.5 dB with the base structural knobs;
    level 3  ``base`` itself (CSD-matched fidelity — the paper's operating
             point);
    level 4  one extra matching-pursuit term per row at +3 dB — the fidelity
             ceiling, for units the budget lets run rich.
    """
    return [
        replace(base, algorithm="fs", snr_offset_db=base.snr_offset_db - 9.0,
                prune_tol=_prune_at_least(base.prune_tol, 1e-4),
                max_share_rel_err=None),
        replace(base, prune_tol=_prune_at_least(base.prune_tol, 1e-3)),
        replace(base, algorithm="fs", snr_offset_db=base.snr_offset_db - 4.5),
        base,
        replace(base, s_terms=base.s_terms + 1,
                snr_offset_db=base.snr_offset_db + 3.0),
    ]


def _unit_energy(u) -> float:
    a = u.weight if isinstance(u, CompressibleDense) else u.kernel
    return float(np.sum(np.asarray(a, np.float64) ** 2))


def _achieved_snr_db(rec) -> float:
    if isinstance(rec, CompressedDense):
        snr = rec.decomposition.meta.get("achieved_snr_db")
    else:  # conv record: mean over the decomposed channels
        snrs = [d.meta.get("achieved_snr_db")
                for d in rec["decompositions"].values()]
        snrs = [s for s in snrs if s is not None]
        snr = float(np.mean(snrs)) if snrs else None
    if snr is None or not np.isfinite(snr):
        return _SNR_CAP_DB
    return min(float(snr), _SNR_CAP_DB)


def allocate_budget(units, budget_adds: int, base: CompressionConfig,
                    evaluate, emit=None, trim_tol: float = 0.05,
                    trim_probes: int = 6, max_trim_units: int | None = None
                    ) -> tuple[dict, dict]:
    """Choose one ladder level per unit so total ``lcc`` adds fit
    ``budget_adds`` at max energy-weighted SNR.

    ``evaluate(plans, tag)`` runs the job graph for one full per-unit plan
    assignment and returns ``(records, report)`` — the runner supplies it with
    the shared worker pool + cache, so the search is parallel and the final
    assembly re-uses every decomposition it produced.

    Returns ``(plans, info)``: the chosen per-unit configs and a summary dict
    (levels, adds/SNR curves, the landed total).
    """
    ladder = candidate_ladder(base)
    names = [u.name for u in units]
    energy = {u.name: _unit_energy(u) for u in units}
    e_tot = max(sum(energy.values()), 1e-30)

    # exact per-unit cost/quality curves: one pipeline evaluation per level
    adds = {n: [] for n in names}   # adds[name][level]
    util = {n: [] for n in names}   # energy-weighted SNR
    for lvl, cfg in enumerate(ladder):
        records, report = evaluate({n: cfg for n in names}, f"lvl{lvl}")
        rows = {l.name: l for l in report.layers}
        for n in names:
            adds[n].append(int(rows[n].stage_adds["lcc"]))
            util[n].append(energy[n] / e_tot * _achieved_snr_db(records[n]))

    # marginal-utility greedy, one single-level upgrade at a time
    level = {n: 0 for n in names}
    total = sum(adds[n][0] for n in names)
    if total > budget_adds and emit:
        emit("budget", detail=f"budget {budget_adds} below the adds floor "
                              f"{total}; emitting the floor plan")
    upgraded = True
    while upgraded:
        upgraded = False
        # free upgrades first: cheaper-or-equal and at least as good
        for n in names:
            l = level[n]
            while (l + 1 < len(ladder)
                   and adds[n][l + 1] - adds[n][l] <= 0
                   and util[n][l + 1] >= util[n][l]):
                total += adds[n][l + 1] - adds[n][l]
                l += 1
                level[n] = l
                upgraded = True
        # best paid upgrade that fits
        best, best_score = None, 0.0
        for n in names:
            l = level[n]
            if l + 1 >= len(ladder):
                continue
            da = adds[n][l + 1] - adds[n][l]
            du = util[n][l + 1] - util[n][l]
            if da <= 0 or du <= 0 or total + da > budget_adds:
                continue
            score = du / da
            if best is None or score > best_score:
                best, best_score = n, score
        if best is not None:
            total += adds[best][level[best] + 1] - adds[best][level[best]]
            level[best] += 1
            upgraded = True

    plans = {n: ladder[level[n]] for n in names}
    cur_adds = {n: adds[n][level[n]] for n in names}

    # ------------------------------------------------------- trim the slack
    # binary-search the continuous fidelity dial of the largest units whose
    # level is below the ceiling, spending the leftover budget
    tol = max(1.0, trim_tol * budget_adds)
    trimmed: dict[str, dict] = {}

    def probe(n, cand, tag):
        _, rep = evaluate({**plans, n: cand}, tag)
        a = next(l for l in rep.layers if l.name == n).stage_adds["lcc"]
        return a, total - cur_adds[n] + a

    if budget_adds - total > tol:
        order = sorted((n for n in names if level[n] < len(ladder) - 1),
                       key=lambda n: -cur_adds[n])
        if max_trim_units is not None:
            order = order[:max_trim_units]
        n_cols = {u.name: int(np.asarray(u.weight).shape[1]) for u in units
                  if isinstance(u, CompressibleDense)}
        for n in order:
            if budget_adds - total <= tol:
                break
            best = None  # (cfg, unit adds, new total)

            def keep(cand, a, new_total):
                nonlocal best
                if new_total <= budget_adds and (best is None or a > best[1]):
                    best = (cand, a, new_total)
                return new_total <= budget_adds

            cur_cfg = plans[n]
            # dial 1: cluster count — the continuous bridge between "a few
            # shared centroids" and "no sharing" (share_clusters >= K), the
            # biggest single adds step in the ladder
            if n in n_cols and cur_cfg.weight_sharing:
                hi_c = max(2, n_cols[n])
                hi_cfg = replace(cur_cfg, share_clusters=hi_c,
                                 max_share_rel_err=None)
                a, nt = probe(n, hi_cfg, f"trim:{n}:c{hi_c}")
                if keep(hi_cfg, a, nt):
                    pass  # even the unshared end fits: take it outright
                else:
                    lo_c = 2
                    for _ in range(trim_probes):
                        mid = (lo_c + hi_c) // 2
                        cand = replace(cur_cfg, share_clusters=mid,
                                       max_share_rel_err=None)
                        a, nt = probe(n, cand, f"trim:{n}:c{mid}")
                        if keep(cand, a, nt):
                            lo_c = mid
                        else:
                            hi_c = mid
                        if hi_c - lo_c <= 1:
                            break
            # dial 2: the current level's fidelity UP toward the budget line
            lo, hi = 0.0, 12.0
            for _ in range(trim_probes):
                mid = (lo + hi) / 2.0
                cand = replace(cur_cfg,
                               snr_offset_db=cur_cfg.snr_offset_db + mid)
                a, nt = probe(n, cand, f"trim:{n}:{mid:+.2f}dB")
                lo, hi = (mid, hi) if keep(cand, a, nt) else (lo, mid)
            # dial 3: the NEXT level's fidelity DOWN to just under the line —
            # structural knobs (sharing acceptance, fs/fp, s_terms) between
            # levels move adds in jumps no in-level dial can bridge
            nxt_cfg = ladder[level[n] + 1]
            lo, hi = 0.0, 15.0
            cand = replace(nxt_cfg, snr_offset_db=nxt_cfg.snr_offset_db - hi)
            a, nt = probe(n, cand, f"trim:{n}:next-{hi:.0f}dB")
            if keep(cand, a, nt):  # the next structure can fit at all
                for _ in range(trim_probes):
                    mid = (lo + hi) / 2.0
                    cand = replace(nxt_cfg,
                                   snr_offset_db=nxt_cfg.snr_offset_db - mid)
                    a, nt = probe(n, cand, f"trim:{n}:next-{mid:.2f}dB")
                    lo, hi = (lo, mid) if keep(cand, a, nt) else (mid, hi)
            if best is not None and best[1] > cur_adds[n]:
                plans[n], cur_adds[n], total = best[0], best[1], best[2]
                # record the winning dial's actual knobs (any of the three
                # dials may have won — algorithm/s_terms/clusters/offset)
                trimmed[n] = {"algorithm": best[0].algorithm,
                              "s_terms": best[0].s_terms,
                              "snr_offset_db": round(best[0].snr_offset_db, 3),
                              "share_clusters": best[0].share_clusters}

    info = {
        "budget_adds": int(budget_adds),
        "landed_adds": int(total),
        "levels": dict(level),  # pre-trim greedy levels; ``trimmed`` entries
                                # override these units' executed knobs
        "trimmed": trimmed,
        "ladder_size": len(ladder),
        "adds_curves": {n: list(map(int, adds[n])) for n in names},
    }
    if emit:
        emit("budget", detail=f"landed {total} adds of {budget_adds} budget "
                              f"({total / max(budget_adds, 1):.1%}); levels "
                              f"{sorted(set(level.values()))}")
    return plans, info
