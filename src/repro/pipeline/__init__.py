"""Parallel, resumable, budget-driven compression pipeline (Algorithm 1 as a
job graph).

The offline compression stage is itself a pipeline problem (Deep Compression,
EIE): per-unit rate allocation plus an embarrassingly-parallel inner loop.
This package turns ``core.compress`` into exactly that:

* :mod:`jobs` — a **planner** that walks compressible units and emits a job
  graph at column-slice granularity (dense) / channel granularity (conv);
* :mod:`runner` — a **worker pool** executing slice jobs (process-based) with
  a content-addressed cache and resume-after-kill via the msgpack+crc32
  ``Checkpointer``;
* :mod:`allocator` — an **adds-budget allocator** searching per-unit knobs to
  hit a global additions budget at max SNR;
* :mod:`cache` — the content-addressed slice-result store;
* :mod:`events` — structured progress events for long-run observability.

``core.compress.compress_model_params`` is a thin serial wrapper over
:func:`run_pipeline`, and ``models.api.compress_model`` passes ``n_workers``/
``budget_adds`` straight through, so every existing call site rides the same
code path.  Parallel output is bitwise-identical to serial output regardless
of worker count or completion order (sort-by-job-id reduction).
"""
from .allocator import allocate_budget, candidate_ladder  # noqa: F401
from .cache import SliceCache  # noqa: F401
from .events import CompressionEvent  # noqa: F401
from .jobs import Planner, SliceJob  # noqa: F401
from .runner import PipelineResult, run_pipeline  # noqa: F401

__all__ = ["run_pipeline", "PipelineResult", "CompressionEvent", "SliceCache",
           "Planner", "SliceJob", "allocate_budget", "candidate_ladder"]
