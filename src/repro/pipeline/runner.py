"""Pipeline runner: worker fan-out, cached execution, resumable runs.

Execution model
---------------
1. resolve per-unit plans (explicit ``plans`` > resumed manifest > the
   adds-budget allocator > one global config);
2. :class:`~repro.pipeline.jobs.Planner` prepares units and emits the job
   graph (column-slice / conv-channel granularity);
3. jobs not satisfied by the content-addressed cache run on a process pool
   (``n_workers``); every completed job is published to the cache immediately,
   so a killed run loses at most the jobs in flight;
4. deterministic reduction: units in planner order, slices sorted by job id —
   output is bitwise-identical to the serial path regardless of worker count
   or completion order.

Resume
------
``run_dir`` holds a msgpack+crc32 ``Checkpointer`` manifest recording the
chosen per-unit plans and a content hash per unit.  ``resume=True`` restores
the manifest (so a budget run does not re-search), verifies the hashes, and
re-executes the job graph — completed slices come straight from the cache.
"""
from __future__ import annotations

import atexit
import json
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core.compress import (CompressibleDense, CompressionConfig,
                                 finish_conv, finish_dense)
from repro.core.cost import ModelCostReport

from .allocator import allocate_budget
from .cache import SliceCache, job_key
from .events import EventEmitter
from .jobs import Planner, execute_job, execute_job_batch

__all__ = ["PipelineResult", "run_pipeline"]

_MANIFEST_VERSION = 1


@dataclass
class PipelineResult:
    """What a pipeline run produced: the same ``(records, report)`` surface as
    ``compress_model_params`` plus the per-unit plans and run statistics."""

    records: dict
    report: ModelCostReport
    unit_configs: dict[str, CompressionConfig]
    stats: dict = field(default_factory=dict)
    budget_info: dict | None = None


def _unit_hash(u) -> str:
    a = u.weight if isinstance(u, CompressibleDense) else u.kernel
    return job_key(a, {"unit": u.name})


def _save_manifest(run_dir: str, units, plans, budget_adds, sub, base) -> None:
    from repro.checkpoint.checkpointer import Checkpointer

    man = {
        "version": _MANIFEST_VERSION,
        "units": [u.name for u in units],
        "unit_hash": {u.name: _unit_hash(u) for u in units},
        "plans": {n: asdict(c) for n, c in plans.items()},
        "base": asdict(base),
        "budget_adds": budget_adds,
        "conv_channel_subsample": sub,
    }
    tree = {"manifest": np.frombuffer(json.dumps(man).encode(), np.uint8).copy()}
    Checkpointer(run_dir).save(0, tree, blocking=True)


def _load_manifest(run_dir: str) -> dict | None:
    from repro.checkpoint.checkpointer import Checkpointer

    ckpt = Checkpointer(run_dir)
    for step in reversed(ckpt.all_steps()):
        try:
            flat = ckpt.restore_flat(step)
            man = json.loads(np.asarray(flat["manifest"], np.uint8)
                             .tobytes().decode())
        except Exception as e:  # corrupted manifest: fall back / fresh run
            print(f"[pipeline] manifest step {step} unreadable ({e})")
            continue
        if man.get("version") == _MANIFEST_VERSION:
            return man
    return None


_forkserver_preloaded = False
_executors: dict[int, ProcessPoolExecutor] = {}


def _make_executor(n_workers: int) -> ProcessPoolExecutor:
    """Worker pool on a forkserver context: the forkserver imports the job
    module (and its jax dependency chain) ONCE before any XLA threads exist
    in it, then every worker forks cheaply from that clean single-threaded
    process — avoiding both fork-from-threaded-jax deadlocks and a per-worker
    jax re-import (spawn is the non-POSIX fallback)."""
    global _forkserver_preloaded
    methods = multiprocessing.get_all_start_methods()
    if "forkserver" in methods:
        if not _forkserver_preloaded:
            multiprocessing.set_forkserver_preload(["repro.pipeline.jobs"])
            _forkserver_preloaded = True
        ctx = multiprocessing.get_context("forkserver")
    else:
        ctx = multiprocessing.get_context("spawn")
    return ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx)


def _get_executor(n_workers: int) -> ProcessPoolExecutor:
    """Warm pool per worker count, reused across runs (slice jobs are pure
    functions, so worker reuse is free); rebuilt if a worker died."""
    ex = _executors.get(n_workers)
    if ex is None or getattr(ex, "_broken", False):
        ex = _make_executor(n_workers)
        _executors[n_workers] = ex
    return ex


@atexit.register
def _shutdown_executors() -> None:
    for ex in _executors.values():
        ex.shutdown(wait=False, cancel_futures=True)
    _executors.clear()


def _execute_jobs(jobs, cache: SliceCache, executor, emit) -> tuple[dict, dict]:
    """Run every job, cache-first.  Returns ({job_id: piece}, {job_id: wall})."""
    results: dict[int, object] = {}
    walls: dict[int, float] = {}
    pending = []
    dups: dict[int, list] = {}  # representative job_id -> identical jobs
    by_key: dict[str, object] = {}
    for j in jobs:
        piece = cache.get(j.cache_key)
        if piece is not None:
            results[j.job_id] = piece
            walls[j.job_id] = 0.0
            emit("cache_hit", unit=j.unit, detail=f"job {j.job_id}")
            continue
        rep = by_key.get(j.cache_key)
        if rep is not None:  # tied/shared weights: coalesce identical jobs
            cache.misses -= 1  # reclassified: counted a hit when it settles
            dups.setdefault(rep.job_id, []).append(j)
        else:
            by_key[j.cache_key] = j
            pending.append(j)

    def settle(j, piece, wall):
        cache.put(j.cache_key, piece)  # durable before we move on
        results[j.job_id] = piece
        walls[j.job_id] = wall
        emit("slice_done", unit=j.unit, wall_s=wall, detail=f"job {j.job_id}")
        for d in dups.get(j.job_id, ()):
            results[d.job_id] = piece
            walls[d.job_id] = 0.0
            cache.hits += 1
            emit("cache_hit", unit=d.unit, detail=f"job {d.job_id}")

    if not pending:
        return results, walls
    if executor is not None:
        # chunk to ~4 batches per worker: big enough to amortize submit/IPC,
        # small enough to keep the pool load-balanced on skewed job sizes
        n_workers = executor._max_workers
        chunk = max(1, len(pending) // (n_workers * 4))
        batches = [pending[i:i + chunk] for i in range(0, len(pending), chunk)]
        futs = {executor.submit(
                    execute_job_batch,
                    [(j.kind, j.mat, j.knobs) for j in b]): b
                for b in batches}
        for fut in as_completed(futs):
            for j, (piece, wall) in zip(futs[fut], fut.result()):
                settle(j, piece, wall)
    else:
        for j in pending:
            piece, wall = execute_job(j.kind, j.mat, j.knobs)
            settle(j, piece, wall)
    return results, walls


def _reduce(planned, results, walls, conv_channel_subsample, emit,
            finish_memo: dict | None = None):
    """Sort-by-job-id reduction, unit by unit in planner order.

    ``finish_memo`` (shared across allocator probes) memoizes the finish
    stage per (unit, plan): a trim probe changes ONE unit's plan, so the
    other units' records/cost rows — including the O(N*K) dense
    reconstruction behind ``achieved_snr_db`` — are reused, not recomputed.
    """
    from .jobs import _plan_cache_token

    report = ModelCostReport()
    records: dict[str, object] = {}
    for pu in planned:
        t0 = time.time()
        token = _plan_cache_token(pu.name, pu.cfg)
        memoized = finish_memo.get(token) if finish_memo is not None else None
        if memoized is not None:
            rec, row = memoized
            report.add(row)
        elif pu.kind == "dense":
            from repro.core.lcc import expand_slice_piece, zero_slice_piece

            # rebuild by slice index: skipped (all-dead) slices get the
            # canonical zero piece, shrunk jobs are re-addressed to full slice
            # width — both pure functions of the plan, so the reduction stays
            # bitwise-deterministic at any worker count
            n_rows = pu.prep.target.shape[0]
            by_index: dict[int, object] = {}
            for j in sorted(pu.jobs, key=lambda j: j.job_id):
                piece = results[j.job_id]
                if j.keep is not None:
                    c0, c1 = pu.prep.col_slices[j.index]
                    piece = expand_slice_piece(piece, j.keep, c1 - c0)
                by_index[j.index] = piece
            pieces = [
                by_index[si] if si in by_index
                else zero_slice_piece(pu.cfg.algorithm, n_rows, c1 - c0)
                for si, (c0, c1) in enumerate(pu.prep.col_slices)
            ]
            rec = finish_dense(pu.prep, pieces, pu.cfg, report)
            row = report.layers[-1]
        else:
            decs = {j.index: results[j.job_id] for j in pu.jobs}
            rec = finish_conv(pu.prep, decs, pu.cfg, report,
                              conv_channel_subsample)
            row = report.layers[-1]
        if finish_memo is not None and memoized is None:
            finish_memo.pop(token, None)
            finish_memo[token] = (rec, row)
            while len(finish_memo) > max(32, 2 * len(planned)):
                finish_memo.pop(next(iter(finish_memo)))
        records[pu.name] = rec
        emit("unit_done", unit=pu.name,
             wall_s=pu.prep_wall_s + sum(walls[j.job_id] for j in pu.jobs)
             + (time.time() - t0),
             adds_before=row.baseline_adds,
             adds_after=row.stage_adds.get("lcc"))
    return records, report


def run_pipeline(
    units,
    compression: CompressionConfig | None = None,
    *,
    plans: dict[str, CompressionConfig] | None = None,
    budget_adds: int | None = None,
    n_workers: int = 1,
    cache_dir: str | None = None,
    run_dir: str | None = None,
    resume: bool = False,
    conv_channel_subsample: int | None = None,
    progress=None,
    metrics=None,
) -> PipelineResult:
    """Algorithm 1 over ``units`` as a parallel, resumable job graph.

    ``compression`` is the global base config (as ``compress_model_params``
    took); ``plans`` overrides it per unit; ``budget_adds`` invokes the
    allocator to *choose* per-unit plans under a global additions budget.
    ``n_workers <= 1`` executes in-process — the serial baseline the parallel
    path is bitwise-checked against.  ``metrics=`` publishes the event stream
    and the final run stats into an ``repro.obs`` registry.
    """
    t_start = time.time()
    emitter = EventEmitter(progress, metrics=metrics)
    base = compression if compression is not None else CompressionConfig()
    cache = SliceCache(cache_dir)
    if run_dir is not None and cache_dir is None:
        # resumable runs need durable slice results; default next to the manifest
        cache = SliceCache(os.path.join(run_dir, "slice_cache"))
    planner = Planner(conv_channel_subsample=conv_channel_subsample)
    budget_info = None
    by_name = {u.name: u for u in units}
    if len(by_name) != len(units):
        raise ValueError("duplicate unit names in the pipeline input")

    # ---------------------------------------------------------------- plans
    if plans is None and resume and run_dir is not None:
        man = _load_manifest(run_dir)
        if man is not None:
            if man["units"] != [u.name for u in units]:
                raise ValueError(
                    "resume manifest unit list does not match the model: "
                    f"{man['units']} vs {[u.name for u in units]}")
            stale = [n for n, h in man["unit_hash"].items()
                     if _unit_hash(by_name[n]) != h]
            if stale:
                raise ValueError(f"resume manifest weight hashes differ for "
                                 f"{stale}; refusing to mix runs")
            # resuming replays the RECORDED plans; a changed base config or
            # budget would silently not apply, so refuse like a weight mismatch
            if man.get("base") != asdict(base):
                raise ValueError(
                    "resume manifest was recorded under a different "
                    "compression config; rerun without --resume (or with the "
                    "original --config flags)")
            if man.get("budget_adds") != budget_adds:
                raise ValueError(
                    f"resume manifest budget {man.get('budget_adds')} != "
                    f"requested {budget_adds}; rerun without --resume to "
                    "re-allocate")
            if man.get("conv_channel_subsample") != conv_channel_subsample:
                raise ValueError(
                    f"resume manifest conv_channel_subsample "
                    f"{man.get('conv_channel_subsample')} != requested "
                    f"{conv_channel_subsample}; rerun without --resume")
            plans = {n: CompressionConfig(**d) for n, d in man["plans"].items()}
            budget_info = {"budget_adds": man.get("budget_adds"),
                           "resumed": True}
            emitter("resume", detail=f"{len(plans)} unit plans from manifest; "
                                     f"{len(cache)} cached slices")
    executor = _get_executor(n_workers) if n_workers > 1 else None
    try:
        if plans is None and budget_adds is not None:
            finish_memo: dict = {}

            def evaluate(eval_plans, tag):
                planned = planner.plan(units, eval_plans)
                results, walls = _execute_jobs(
                    [j for pu in planned for j in pu.jobs], cache, executor,
                    EventEmitter(None))
                records, report = _reduce(planned, results, walls,
                                          conv_channel_subsample,
                                          EventEmitter(None), finish_memo)
                emitter("budget", detail=f"evaluated candidate {tag}: "
                        f"{report.total_stage('lcc')} adds")
                return records, report

            plans, budget_info = allocate_budget(units, budget_adds, base,
                                                 evaluate, emit=emitter)
        if plans is None:
            plans = {u.name: base for u in units}
        missing = [u.name for u in units if u.name not in plans]
        if missing:
            raise KeyError(f"no plan for units {missing}")
        if run_dir is not None:
            _save_manifest(run_dir, units, plans, budget_adds,
                           conv_channel_subsample, base)

        # --------------------------------------------------------- execute
        planned = planner.plan(units, plans, emit=emitter)
        all_jobs = [j for pu in planned for j in pu.jobs]
        emitter("plan", detail=f"{len(planned)} units -> {len(all_jobs)} jobs "
                               f"({n_workers} workers)")
        # snapshot so stats report the FINAL pass's hit rate, not the
        # allocator's search traffic (tracked separately below)
        h0, m0 = cache.hits, cache.misses
        results, walls = _execute_jobs(all_jobs, cache, executor, emitter)
        records, report = _reduce(planned, results, walls,
                                  conv_channel_subsample, emitter)
    except Exception:
        # a dead pool must not poison the next run; _get_executor rebuilds
        if executor is not None and getattr(executor, "_broken", False):
            executor.shutdown(wait=False, cancel_futures=True)
            _executors.pop(n_workers, None)
        raise

    wall = time.time() - t_start
    stats = {
        "units": len(planned),
        "jobs": len(all_jobs),
        "workers": n_workers,
        "dead_groups": sum(pu.dead_groups for pu in planned),
        "skipped_jobs": sum(len(pu.skipped) for pu in planned),
        "shrunk_jobs": sum(pu.shrunk for pu in planned),
        "cache_hits": cache.hits - h0,
        "cache_misses": cache.misses - m0,
        "wall_s": round(wall, 4),
        "units_per_s": round(len(planned) / wall, 4) if wall > 0 else None,
    }
    if h0 or m0:  # allocator search traffic, reported separately
        stats["search_cache_hits"] = h0
        stats["search_cache_misses"] = m0
    if metrics is not None:
        g = metrics.gauge("pipeline_run", "final pipeline run stats",
                          labels=("stat",))
        for k, v in stats.items():
            if isinstance(v, (int, float)) and v is not None:
                g.set(v, stat=k)
    return PipelineResult(records=records, report=report, unit_configs=plans,
                          stats=stats, budget_info=budget_info)
