"""Job-graph planner: compressible units -> content-addressed slice jobs.

The planner runs the cheap, inherently-sequential *prepare* stage per unit
(prune + affinity-propagation clustering + slice planning, see
``core.compress.prepare_dense`` / ``prepare_conv``) and emits one job per
column slice (dense) or per input channel (conv) — the hot sequential loop of
``lcc_decompose`` today, and embarrassingly parallel by construction: slices
only meet again in the final sum over slice outputs.

Every job is a pure function of (matrix, knobs), carries a deterministic
``job_id`` (unit order x slice order) for the sort-by-job-id reduction, and a
:func:`repro.pipeline.cache.job_key` content address so tied/shared weights
and re-runs are free.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass

import numpy as np

from dataclasses import field as _field

from repro.core.compress import (CompressibleConv, CompressibleDense,
                                 CompressionConfig, PreparedConv,
                                 PreparedDense, conv_channel_decompose,
                                 prepare_conv, prepare_dense, slice_job_plan)
from repro.core.lcc import lcc_decompose_slice

from .cache import job_key

__all__ = ["SliceJob", "PlannedUnit", "Planner", "execute_job",
           "execute_job_batch"]

# knob subset a conv-channel job needs (must be CompressionConfig field names)
_CONV_KNOBS = ("algorithm", "s_terms", "frac_bits", "target_snr_db",
               "snr_offset_db", "slice_width", "max_factors",
               "max_terms_per_row")


@dataclass
class SliceJob:
    """One decomposition job: ``mat`` under ``knobs``.

    kind 'dense_slice': one column slice of a prepared dense target
    (``knobs['target_snr_db']`` is already resolved, so the job never sees the
    whole matrix).  kind 'conv_channel': one input channel's FK/PK matrix.
    """

    job_id: int
    unit: str
    kind: str  # 'dense_slice' | 'conv_channel'
    index: int  # slice index (dense) or channel id (conv)
    mat: np.ndarray
    knobs: dict
    cache_key: str
    keep: np.ndarray | None = None  # shrunk dense job: surviving column
                                    # offsets within the slice; mat is
                                    # compacted to them


@dataclass
class PlannedUnit:
    name: str
    kind: str  # 'dense' | 'conv'
    cfg: CompressionConfig
    prep: PreparedDense | PreparedConv
    jobs: list[SliceJob]
    prep_wall_s: float
    skipped: list[int] = _field(default_factory=list)  # all-dead slice indices
    shrunk: int = 0  # jobs compacted to surviving columns
    dead_groups: int = 0  # dead columns (dense) / channels (conv) detected


def execute_job(kind: str, mat: np.ndarray, knobs: dict):
    """Run one job (worker entry point — top-level for pickling).  Returns
    ``(piece, wall_seconds)``; the piece is an LCCChain/FSProgram for dense
    slices, a whole LCCDecomposition for conv channels."""
    t0 = time.time()
    if kind == "dense_slice":
        piece = lcc_decompose_slice(
            mat, knobs["algorithm"], knobs["target_snr_db"],
            s_terms=knobs["s_terms"], max_factors=knobs["max_factors"],
            max_terms_per_row=knobs["max_terms_per_row"])
    elif kind == "conv_channel":
        piece = conv_channel_decompose(mat, CompressionConfig(**knobs))
    else:
        raise ValueError(f"unknown job kind {kind!r}")
    return piece, time.time() - t0


def execute_job_batch(batch: list[tuple[str, np.ndarray, dict]]):
    """Run a chunk of jobs in one worker round-trip (amortizes the per-future
    submit/pickle overhead, which otherwise dominates at ~10ms/job)."""
    return [execute_job(kind, mat, knobs) for kind, mat, knobs in batch]


def _plan_cache_token(name: str, cfg: CompressionConfig) -> str:
    return name + "|" + json.dumps(asdict(cfg), sort_keys=True, default=str)


class Planner:
    """Walks units in order, prepares each under its per-unit plan, and emits
    the flat job list with globally sequential ids.

    ``prep_memo`` (shared across allocator candidate evaluations and the final
    assembly pass) memoizes the prepare stage per (unit, config), so the
    clustering work is paid once per distinct plan, not once per evaluation.
    """

    def __init__(self, conv_channel_subsample: int | None = None,
                 prep_memo: dict | None = None):
        self.conv_channel_subsample = conv_channel_subsample
        self.prep_memo = prep_memo if prep_memo is not None else {}

    def plan(self, units, plans: dict[str, CompressionConfig],
             emit=None) -> list[PlannedUnit]:
        planned: list[PlannedUnit] = []
        jid = 0
        for u in units:
            cfg = plans[u.name]
            token = _plan_cache_token(u.name, cfg)
            t0 = time.time()
            prep = self.prep_memo.get(token)
            fresh = prep is None
            if emit:  # even when the prepare stage is memoized: an observed
                emit("unit_start", unit=u.name)  # pass still walks the unit
            if isinstance(u, CompressibleDense):
                if prep is None:
                    prep = prepare_dense(u.name, u.weight, cfg)
                jobs = []
                skipped: list[int] = []
                shrunk = 0
                dead = 0
                entries = slice_job_plan(prep, cfg)
                have = {e[0] for e in entries}
                for si, (c0, c1) in enumerate(prep.col_slices):
                    if si not in have:
                        skipped.append(si)
                        dead += c1 - c0
                        if emit:
                            emit("skip", unit=u.name,
                                 detail=f"slice {si}: all {c1 - c0} columns "
                                        "dead, 0 adds")
                for si, (c0, c1), mat, keep in entries:
                    mat = np.ascontiguousarray(mat)
                    if keep is not None:
                        shrunk += 1
                        dead += (c1 - c0) - int(keep.size)
                    knobs = {"algorithm": cfg.algorithm,
                             "target_snr_db": prep.target_snr_db,
                             "s_terms": cfg.s_terms,
                             "max_factors": cfg.max_factors,
                             "max_terms_per_row": cfg.max_terms_per_row}
                    jobs.append(SliceJob(
                        job_id=jid, unit=u.name, kind="dense_slice", index=si,
                        mat=mat, knobs=knobs,
                        cache_key=job_key(mat, {"kind": "dense_slice", **knobs}),
                        keep=keep))
                    jid += 1
                kind = "dense"
            elif isinstance(u, CompressibleConv):
                if prep is None:
                    prep = prepare_conv(u.name, u.kernel, cfg,
                                        self.conv_channel_subsample)
                jobs = []
                skipped = []
                shrunk = 0
                dead = prep.kernel_shape[1] - len(prep.ch_nonzero)
                if dead and emit:
                    emit("skip", unit=u.name,
                         detail=f"{dead} dead input channels dropped, 0 adds")
                cfg_d = asdict(cfg)
                knobs = {k: cfg_d[k] for k in _CONV_KNOBS}
                for ch in prep.sel:
                    mat = np.ascontiguousarray(prep.mats[ch])
                    jobs.append(SliceJob(
                        job_id=jid, unit=u.name, kind="conv_channel", index=ch,
                        mat=mat, knobs=knobs,
                        cache_key=job_key(mat, {"kind": "conv_channel", **knobs})))
                    jid += 1
                kind = "conv"
            else:
                raise TypeError(f"unknown compressible unit {type(u)}")
            self.prep_memo.pop(token, None)  # refresh insertion order (FIFO)
            self.prep_memo[token] = prep
            planned.append(PlannedUnit(
                name=u.name, kind=kind, cfg=cfg, prep=prep, jobs=jobs,
                prep_wall_s=(time.time() - t0) if fresh else 0.0,
                skipped=skipped, shrunk=shrunk, dead_groups=dead))
        # bound the memo: a budget search probes ~20 configs per unit, and a
        # prepared unit can hold a full-matrix target — evict oldest (prepare
        # is recomputable; eviction only costs a re-cluster on a rare revisit).
        # ~2 entries per unit keeps the current plan set plus one probe plan
        # resident, i.e. about one extra model copy, not four
        cap = max(32, 2 * len(units))
        while len(self.prep_memo) > cap:
            self.prep_memo.pop(next(iter(self.prep_memo)))
        return planned
