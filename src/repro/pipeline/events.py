"""Structured progress events for long compression runs.

``compress_model_params``'s old progress callback received a bare unit-name
string; pipeline consumers need machine-readable progress (unit, wall-time,
adds before/after, cache activity) to make multi-hour runs observable from
the CLI.  ``str(event)`` renders the human line, so ``progress=print`` — and
every old callback that only formats its argument — keeps working.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

__all__ = ["CompressionEvent", "EventEmitter"]


@dataclass
class CompressionEvent:
    """One pipeline observation.

    kind:
      ``plan``        — job graph built (detail: totals)
      ``unit_start``  — a unit entered the prepare stage
      ``slice_done``  — one slice/channel job finished (possibly from cache)
      ``unit_done``   — a unit fully reduced; adds_before/adds_after filled
      ``cache_hit``   — a job was satisfied from the content-addressed cache
      ``resume``      — a run manifest was restored (detail: what was reused)
      ``budget``      — the allocator chose per-unit plans (detail: totals)
    """

    kind: str
    unit: str = ""
    wall_s: float = 0.0
    adds_before: int | None = None  # CSD shift-add baseline of the unit
    adds_after: int | None = None  # compressed ('lcc' stage) adds
    detail: str = ""
    t: float = field(default_factory=time.time)

    def __str__(self) -> str:
        parts = [self.kind]
        if self.unit:
            parts.append(self.unit)
        if self.kind == "unit_done" and self.adds_before is not None:
            ratio = (self.adds_before / self.adds_after
                     if self.adds_after else float("inf"))
            parts.append(f"adds {self.adds_before}->{self.adds_after} "
                         f"({ratio:.2f}x) in {self.wall_s:.2f}s")
        elif self.wall_s:
            parts.append(f"{self.wall_s:.2f}s")
        if self.detail:
            parts.append(self.detail)
        return "  ".join(parts)


class EventEmitter:
    """Nil-safe fan-out to the user's progress callback.

    With ``metrics=`` (a :class:`~repro.obs.metrics.MetricsRegistry`) every
    event also increments ``pipeline_events_total{kind}`` and ``slice_done``
    walls feed the ``pipeline_job_wall_seconds`` histogram — so a multi-hour
    compression run is observable from the same registry as everything else.
    """

    def __init__(self, progress=None, metrics=None):
        self.progress = progress
        self._m_events = self._m_wall = None
        if metrics is not None:
            self._m_events = metrics.counter(
                "pipeline_events_total", "compression events by kind",
                labels=("kind",))
            self._m_wall = metrics.histogram(
                "pipeline_job_wall_seconds", "per-job compression wall")

    def __call__(self, kind: str, **kw) -> None:
        if self._m_events is not None:
            self._m_events.inc(1, kind=kind)
            if kind == "slice_done" and self._m_wall is not None:
                self._m_wall.observe(kw.get("wall_s", 0.0))
        if self.progress is not None:
            self.progress(CompressionEvent(kind=kind, **kw))
