"""Content-addressed store for slice/channel decomposition results.

Key = sha256(weight bytes + canonical knob JSON): re-runs, resumed runs and
tied/shared weights (identical matrices under the same plan) are free.  Each
entry is one msgpack file whose array leaves carry the checkpointer's crc32
envelope, written atomically (tmp + rename), so a SIGKILL mid-``put`` can
never publish a torn entry — the property the resume path relies on.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any

import msgpack
import numpy as np

from repro.checkpoint.checkpointer import _pack_leaf, _unpack_leaf
from repro.core.lcc import FSProgram, LCCChain, LCCDecomposition, LCCFactor

__all__ = ["SliceCache", "job_key", "piece_to_tree", "piece_from_tree"]

_SALT = b"lcc-job-v1"  # bump when decomposition semantics change


def job_key(mat: np.ndarray, knobs: dict) -> str:
    """Content address of one decomposition job: matrix bytes + knobs."""
    a = np.ascontiguousarray(np.asarray(mat, np.float64))
    h = hashlib.sha256(_SALT)
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    h.update(json.dumps(knobs, sort_keys=True, default=str).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# piece <-> plain tree (msgpack-able: scalars + _pack_leaf array envelopes)
# ---------------------------------------------------------------------------


def piece_to_tree(piece) -> dict:
    if isinstance(piece, LCCChain):
        return {"kind": "fp", "in_dim": piece.in_dim,
                "factors": [{"idx": _pack_leaf(f.idx), "exp": _pack_leaf(f.exp),
                             "sign": _pack_leaf(f.sign), "in_dim": f.in_dim}
                            for f in piece.factors]}
    if isinstance(piece, FSProgram):
        return {"kind": "fs", "n_inputs": piece.n_inputs,
                "nodes": _pack_leaf(np.asarray(piece.nodes, np.int64).reshape(-1, 6)),
                "outputs": _pack_leaf(np.asarray(piece.outputs, np.int64))}
    if isinstance(piece, LCCDecomposition):
        return {"kind": "dec", "shape": list(piece.shape),
                "col_slices": [list(cs) for cs in piece.col_slices],
                "algorithm": piece.algorithm,
                "target_snr_db": piece.target_snr_db,
                "meta": {k: v for k, v in piece.meta.items()
                         if isinstance(v, (int, float, str, bool, type(None)))},
                "slices": [piece_to_tree(s) for s in piece.slices]}
    raise TypeError(f"cannot serialize {type(piece)}")


def piece_from_tree(tree: dict):
    kind = tree["kind"]
    if kind == "fp":
        return LCCChain(
            factors=[LCCFactor(idx=np.asarray(_unpack_leaf(f["idx"]), np.int32),
                               exp=np.asarray(_unpack_leaf(f["exp"]), np.int8),
                               sign=np.asarray(_unpack_leaf(f["sign"]), np.int8),
                               in_dim=int(f["in_dim"]))
                     for f in tree["factors"]],
            in_dim=int(tree["in_dim"]))
    if kind == "fs":
        return FSProgram(
            n_inputs=int(tree["n_inputs"]),
            nodes=np.asarray(_unpack_leaf(tree["nodes"]), np.int64).reshape(-1, 6),
            outputs=np.asarray(_unpack_leaf(tree["outputs"]), np.int64))
    if kind == "dec":
        dec = LCCDecomposition(
            shape=tuple(tree["shape"]),
            col_slices=[tuple(cs) for cs in tree["col_slices"]],
            slices=[piece_from_tree(s) for s in tree["slices"]],
            algorithm=tree["algorithm"],
            target_snr_db=float(tree["target_snr_db"]))
        dec.meta.update(tree.get("meta", {}))
        return dec
    raise ValueError(f"unknown cached piece kind {kind!r}")


class SliceCache:
    """Filesystem cache keyed by :func:`job_key`; ``None`` directory disables
    persistence but keeps an in-memory map (same-run dedup of tied weights)."""

    def __init__(self, directory: str | None):
        self.dir = directory
        self.mem: dict[str, Any] = {}
        self.hits = 0
        self.misses = 0
        if directory is not None:
            os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.msgpack")

    def get(self, key: str):
        if key in self.mem:
            self.hits += 1
            return piece_from_tree(self.mem[key])
        if self.dir is not None and os.path.exists(self._path(key)):
            try:
                with open(self._path(key), "rb") as f:
                    tree = msgpack.unpackb(f.read(), raw=False)
                piece = piece_from_tree(tree)  # crc-verified per leaf
            except Exception:
                self.misses += 1
                return None  # torn/corrupt entry: recompute and overwrite
            self.mem[key] = tree
            self.hits += 1
            return piece
        self.misses += 1
        return None

    def put(self, key: str, piece) -> None:
        tree = piece_to_tree(piece)
        self.mem[key] = tree
        if self.dir is None:
            return
        path = self._path(key)
        tmp = path + f".tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(tree, use_bin_type=True))
        os.replace(tmp, path)  # atomic publish

    def __len__(self) -> int:
        if self.dir is None:
            return len(self.mem)
        return sum(1 for n in os.listdir(self.dir) if n.endswith(".msgpack"))
