"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --checkpoint-dir /tmp/ckpt --resume
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --devices 8 --mesh 2x4 --grad-compression --elastic-demo

    # the paper's full loop on the MLP (Sec. IV-A): prox-regularized training
    # -> prune-aware budgeted compression -> recovery fine-tune -> fused serve
    PYTHONPATH=src python -m repro.launch.train --arch mlp --prox \
        --lambda 0.1 --epochs 12 --compress-out /tmp/mlp_run --recover 60 \
        --compress-config algorithm=fp prune_tol=-1e-6 weight_sharing=false

Features: any registered arch (--arch), reduced or full config, sharded SPMD
step on an explicit mesh, ProxSGD group-lasso regularization (the paper's
Algorithm-1 step 1) with compression-aware group layouts (--prox derives the
regularized groups from the same adapter sites the compressor slices), async
checkpoint + auto-resume, int8 cross-pod gradient compression, an
elastic-restart demo (simulated pod loss -> remesh -> reshard -> continue),
and — for --arch mlp — the training -> compression -> recovery handoff that
closes the paper's Algorithm-1 loop in one command.  On real hardware the
same flags apply; --devices N exists to exercise multi-device semantics on
host platform devices.
"""
import os
import sys

# device count must be pinned before jax initializes (same rule as dryrun.py)
if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch, reduced_config
from repro.data.synthetic import MarkovLM
from repro.distributed import sharding
from repro.distributed.act_shard import mesh_context
from repro.distributed.elastic import plan_for_devices, reshard_tree
from repro.optim.optimizers import adamw, cosine_warmup, prox_sgd
from repro.obs import MetricsRegistry, dump_metrics, get_global
from repro.training.trainer import (TrainState, init_train_state,
                                    make_train_step, record_step_metrics)


def build_mesh(spec: str | None):
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return compat.make_mesh(dims, axes)


def mlp_main(args) -> None:
    """--arch mlp: the paper's Sec. IV-A loop end to end.

    1. (optionally prox-regularized) training on MNIST-scale stroke digits,
       groups derived from the compression adapters so the prox zeroes exactly
       what the compressor slices;
    2. prune-aware budgeted compression via the parallel pipeline (dead input
       columns become 0-add skipped/shrunk slice jobs);
    3. post-compression recovery fine-tuning of the artifact's dense residual
       (frozen chains fixed), written back into every artifact surface;
    4. fused-serving check (whole-chain LCC kernel) + ``train_stats.json``.
    """
    import json

    metrics = MetricsRegistry() if args.metrics_out else None

    from repro.data.mnist_like import train_test
    from repro.data.synthetic import batches
    from repro.models import api
    from repro.models.mlp import (MLPConfig, init_mlp, mlp_accuracy,
                                  mlp_forward_compressed, mlp_loss)
    from repro.optim.optimizers import prox_sgd, step_decay
    from repro.training import regularize

    batch = 128 if args.batch is None else args.batch
    lr0 = 0.08 if args.lr is None else args.lr
    cfg = MLPConfig(hidden=args.hidden)
    (xs, ys), (xte, yte) = train_test(args.train_n, args.test_n, seed=args.seed)
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)
    params = init_mlp(jax.random.PRNGKey(args.seed), hidden=cfg.hidden)

    specs = regularize.site_group_specs(params, cfg, args.lam,
                                        include=args.prox_include) \
        if args.prox else ()
    opt = prox_sgd(momentum=0.9, specs=specs)
    state = opt.init(params)
    lr = step_decay(lr0, 0.95, 3)
    grad = jax.jit(jax.grad(mlp_loss))
    upd = jax.jit(lambda g, s, p, l: opt.update(g, s, p, l))
    t0 = time.time()
    for ep in range(args.epochs):
        for xb, yb in batches(xs, ys, batch, seed=ep):
            g = grad(params, jnp.asarray(xb), jnp.asarray(yb))
            params, state = upd(g, state, params, lr(ep))
        if specs and (ep % 3 == 0 or ep == args.epochs - 1):
            rep = regularize.sparsity_report(params, specs)
            print(f"epoch {ep:3d}  dead groups "
                  f"{regularize.dead_group_fraction(rep):.1%}  penalty "
                  f"{sum(float(v['penalty']) for v in rep.values()):.3f}",
                  flush=True)
    acc = float(mlp_accuracy(params, xte_j, yte_j))
    if metrics is not None:
        metrics.gauge("train_accuracy", "held-out accuracy by stage",
                      labels=("stage",)).set(acc, stage="dense")
    stats = {"arch": "mlp", "hidden": cfg.hidden, "prox": bool(args.prox),
             "lam": args.lam, "epochs": args.epochs, "batch": batch,
             "train_wall_s": round(time.time() - t0, 2),
             "accuracy": {"dense": acc}}
    if specs:
        rep = regularize.sparsity_report(params, specs)
        stats["dead_group_fraction"] = round(
            regularize.dead_group_fraction(rep), 4)
        stats["sparsity"] = {k: {kk: float(vv) for kk, vv in v.items()}
                             for k, v in rep.items()}
    print(f"train: accuracy {acc:.3f} in {stats['train_wall_s']}s"
          + (f", dead groups {stats['dead_group_fraction']:.1%}"
             if specs else ""))

    if not args.compress_out:
        if args.metrics_out:
            dump_metrics(args.metrics_out, [get_global(), metrics])
            print(f"wrote {args.metrics_out}")
        return

    # ---- handoff to the compression pipeline (launch/compress layout) ----
    from repro.launch.compress import parse_compression

    compression = parse_compression(args.compress_config)
    chatty = {"plan", "skip", "unit_done", "budget", "resume"}

    def progress(ev):
        if ev.kind in chatty:
            print(f"[{ev.kind}] {ev}", flush=True)

    t0 = time.time()
    art = api.compress_model(
        params, cfg, compression, include=args.include,
        n_workers=args.workers, budget_adds=args.budget,
        cache_dir=os.path.join(args.compress_out, "cache"),
        run_dir=os.path.join(args.compress_out, "run"),
        progress=progress, metrics=metrics)
    ps = art.pipeline_stats
    stats["pipeline"] = {k: int(ps.get(k, 0)) for k in
                         ("units", "jobs", "dead_groups", "skipped_jobs",
                          "shrunk_jobs", "cache_hits", "cache_misses")}
    stats["adds"] = {"baseline": int(art.report.total_baseline()),
                     "lcc": int(art.report.total_stage("lcc"))}
    stats["compress_wall_s"] = round(time.time() - t0, 2)
    acc_c = float(mlp_accuracy(art.params, xte_j, yte_j))
    stats["accuracy"]["compressed"] = acc_c
    if metrics is not None:
        metrics.gauge("train_accuracy", "held-out accuracy by stage",
                      labels=("stage",)).set(acc_c, stage="compressed")
    print(f"compress: adds {stats['adds']['baseline']} -> "
          f"{stats['adds']['lcc']} (dead groups {ps['dead_groups']}, "
          f"skipped {ps['skipped_jobs']} jobs, shrunk {ps['shrunk_jobs']}); "
          f"accuracy {acc_c:.3f}")

    if args.recover > 0:
        from repro.training.recover import recover_artifact

        def loss_fn(p, b):
            return mlp_loss(p, b[0], b[1])

        def rec_batches():
            n, ep = 0, 0
            while n < args.recover:
                for xb, yb in batches(xs, ys, batch, seed=1000 + ep):
                    if n >= args.recover:
                        return
                    yield jnp.asarray(xb), jnp.asarray(yb)
                    n += 1
                ep += 1

        t0 = time.time()
        res = recover_artifact(art, loss_fn, rec_batches(),
                               lr=args.recover_lr,
                               residual_frac=args.residual_frac,
                               progress=lambda m: print(f"[recover] {m}",
                                                        flush=True))
        acc_r = float(mlp_accuracy(art.params, xte_j, yte_j))
        residual = sum(u.get("recover_adds", 0) for u in res["units"].values())
        stats["accuracy"]["recovered"] = acc_r
        stats["adds"]["recover_residual"] = int(residual)
        stats["adds"]["total_with_recover"] = stats["adds"]["lcc"] + int(residual)
        stats["recover"] = {"steps": len(res["losses"]),
                            "loss_first": round(res["losses"][0], 5),
                            "loss_last": round(res["losses"][-1], 5),
                            "units": res["units"],
                            "wall_s": round(time.time() - t0, 2)}
        print(f"recover: loss {stats['recover']['loss_first']:.4f} -> "
              f"{stats['recover']['loss_last']:.4f} over "
              f"{len(res['losses'])} steps; accuracy {acc_r:.3f} "
              f"(+{residual} residual adds)")

    # fused-serving check: fc1 through the packed whole-chain LCC kernel
    pk = art.packed.get("fc1")
    if pk is not None:
        logits = mlp_forward_compressed(art.params, pk, xte_j[:256])
        acc_f = float((jnp.argmax(logits, -1) == yte_j[:256]).mean())
        stats["accuracy"]["fused"] = acc_f
        print(f"serve: fused fc1 kernel accuracy {acc_f:.3f} (256 samples)")

    art.save(os.path.join(args.compress_out, "artifact"))
    with open(os.path.join(args.compress_out, "train_stats.json"), "w") as f:
        json.dump(stats, f, indent=2)
        f.write("\n")
    print(f"artifact -> {os.path.join(args.compress_out, 'artifact')}")
    if args.metrics_out:
        dump_metrics(args.metrics_out, [get_global(), metrics])
        print(f"wrote {args.metrics_out}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None,
                    help="default: 8 (LM), 128 (mlp)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None,
                    help="default: 3e-3 (LM), 0.08 (mlp)")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 or 2x2x2")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--group-lasso", type=float, default=0.0,
                    help="legacy: lambda for ProxSGD on FFN input columns "
                         "(substring spec; prefer --prox)")
    ap.add_argument("--prox", action="store_true",
                    help="ProxSGD with group layouts derived from the "
                         "compression-adapter sites (paper eq. 7/11)")
    ap.add_argument("--lambda", dest="lam", type=float, default=0.1,
                    help="group-lasso strength for --prox")
    ap.add_argument("--prox-include", default=None,
                    help="site-name prefix filter for --prox (e.g. 'fc1')")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--elastic-demo", action="store_true",
                    help="simulate losing half the devices mid-run and recover")
    # --arch mlp: the full train -> compress -> recover -> serve loop
    ap.add_argument("--epochs", type=int, default=12, help="mlp: train epochs")
    ap.add_argument("--hidden", type=int, default=300, help="mlp: hidden width")
    ap.add_argument("--train-n", type=int, default=4000,
                    help="mlp: training examples (mnist_like)")
    ap.add_argument("--test-n", type=int, default=1000,
                    help="mlp: held-out examples")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-out", default=None,
                    help="mlp: run dir; triggers the compression handoff")
    ap.add_argument("--compress-config", nargs="*", default=[],
                    metavar="KEY=VAL",
                    help="mlp: CompressionConfig overrides (launch.compress)")
    ap.add_argument("--budget", type=int, default=None,
                    help="mlp: global adds budget (allocator)")
    ap.add_argument("--workers", type=int, default=1,
                    help="mlp: pipeline worker processes")
    ap.add_argument("--include", default=None,
                    help="mlp: compression unit-name prefix filter")
    ap.add_argument("--recover", type=int, default=0,
                    help="mlp: post-compression recovery fine-tune steps")
    ap.add_argument("--recover-lr", type=float, default=2e-3)
    ap.add_argument("--residual-frac", type=float, default=0.15,
                    help="recovery residual adds budget as a fraction of the "
                         "unit's LCC adds")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's metrics snapshot as JSON at exit")
    args = ap.parse_args()

    if args.arch == "mlp":
        return mlp_main(args)
    args.batch = 8 if args.batch is None else args.batch
    args.lr = 3e-3 if args.lr is None else args.lr

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=256)
    mesh = build_mesh(args.mesh)
    if args.grad_compression and (mesh is None or "pod" not in mesh.shape):
        raise SystemExit("--grad-compression needs a mesh with a pod axis (e.g. 2x2x2)")

    prox_specs = None
    if args.prox:
        from repro.models import api
        from repro.training.regularize import site_group_specs

        prox_specs = site_group_specs(api.abstract_params(cfg), cfg, args.lam,
                                      include=args.prox_include)
        opt = prox_sgd(momentum=0.9, specs=prox_specs)
        print(f"[prox] {len(prox_specs)} site-derived group specs "
              f"(lambda {args.lam})")
    elif args.group_lasso > 0:
        opt = prox_sgd(momentum=0.9, prox_spec={"ffn": (args.group_lasso, "columns")})
    else:
        opt = adamw(weight_decay=0.01)
    lr_fn = cosine_warmup(args.lr, warmup=10, total=args.steps)

    lm = MarkovLM(vocab=cfg.vocab, k=8, seed=0)
    metrics = MetricsRegistry() if args.metrics_out else None
    ck = Checkpointer(args.checkpoint_dir, keep=3) if args.checkpoint_dir else None

    def fresh_state():
        return init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                grad_compression=args.grad_compression,
                                prox_specs=prox_specs)

    def place(state, mesh):
        if mesh is None:
            return state
        specs = sharding.params_pspecs(state, mesh)
        return jax.device_put(state, sharding.named(mesh, specs))

    state = fresh_state()
    start_step = 0
    if ck and args.resume:
        s, restored = ck.restore_latest(state)
        if s is not None:
            state, start_step = restored, s + 1
            print(f"[resume] restored checkpoint step {s}")
    state = place(state, mesh)

    def make_step(mesh):
        step = make_train_step(cfg, opt, lr=args.lr, accum_steps=args.accum_steps,
                               grad_compression=args.grad_compression, mesh=mesh,
                               prox_specs=prox_specs)
        return jax.jit(step)

    step_fn = make_step(mesh)
    ctx = mesh_context(mesh)
    with ctx:
        if mesh is not None:
            compat.set_global_mesh(mesh)
        t0 = time.time()
        i = start_step
        while i < args.steps:
            try:
                b = lm.batch(args.batch, args.seq, seed=i)
                state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
                if i % 10 == 0 or i == args.steps - 1:
                    tok_s = args.batch * args.seq * max(i - start_step, 1) / (time.time() - t0)
                    # record where the loop already syncs to print, so
                    # telemetry adds no extra device round-trips
                    record_step_metrics(metrics, m, step=i)
                    if metrics is not None:
                        metrics.gauge("train_tok_s",
                                      "training throughput").set(tok_s)
                    prox = (f"  dead {int(m['dead_groups'])}  "
                            f"pen {float(m['prox_penalty']):.2f}"
                            if "dead_groups" in m else "")
                    print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                          f"gnorm {float(m['grad_norm']):.2f}  tok/s {tok_s:.0f}"
                          + prox, flush=True)
                if ck and i % args.checkpoint_every == 0 and i > start_step:
                    ck.save(i, state)
                if args.elastic_demo and i == args.steps // 2 and mesh is not None \
                        and len(mesh.devices.flatten()) > 2:
                    raise RuntimeError("simulated pod failure")
                i += 1
            except RuntimeError as e:
                if "simulated" not in str(e):
                    raise
                # elastic recovery: shrink mesh, reshard, continue
                survivors = jax.devices()[: max(len(jax.devices()) // 2, 2)]
                plan = plan_for_devices(len(survivors),
                                        model_parallel=min(2, len(survivors)),
                                        multi_pod_threshold=1 << 30)
                new_mesh = plan.build(survivors)
                print(f"[elastic] {e}; remeshing {mesh.shape} -> {new_mesh.shape} "
                      f"and resharding state", flush=True)
                host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
                specs = sharding.params_pspecs(state, new_mesh)
                state = reshard_tree(host, new_mesh, specs)
                mesh = new_mesh
                args.grad_compression = False  # single pod left
                step_fn = make_step(None)
                compat.set_global_mesh(mesh)
                from repro.distributed import act_shard
                act_shard.set_mesh(mesh)  # activation constraints follow the new mesh
                i += 1
        if ck:
            ck.save(args.steps - 1, state, blocking=True)
            print(f"[checkpoint] final save at step {args.steps - 1}")
    print(f"done: {args.steps - start_step} steps in {time.time() - t0:.1f}s")
    if args.metrics_out:
        dump_metrics(args.metrics_out, [get_global(), metrics])
        print(f"wrote {args.metrics_out}")


if __name__ == "__main__":
    main()
