"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --checkpoint-dir /tmp/ckpt --resume
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --devices 8 --mesh 2x4 --grad-compression --elastic-demo

Features: any registered arch (--arch), reduced or full config, sharded SPMD
step on an explicit mesh, ProxSGD group-lasso regularization (the paper's
Algorithm-1 step 1), async checkpoint + auto-resume, int8 cross-pod gradient
compression, and an elastic-restart demo (simulated pod loss -> remesh ->
reshard -> continue).  On real hardware the same flags apply; --devices N
exists to exercise multi-device semantics on host platform devices.
"""
import os
import sys

# device count must be pinned before jax initializes (same rule as dryrun.py)
if "--devices" in sys.argv:
    _n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch, reduced_config
from repro.data.synthetic import MarkovLM
from repro.distributed import sharding
from repro.distributed.act_shard import mesh_context
from repro.distributed.elastic import plan_for_devices, reshard_tree
from repro.optim.optimizers import adamw, cosine_warmup, prox_sgd
from repro.training.trainer import TrainState, init_train_state, make_train_step


def build_mesh(spec: str | None):
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return compat.make_mesh(dims, axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 or 2x2x2")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--group-lasso", type=float, default=0.0,
                    help="lambda for ProxSGD on FFN input columns (paper eq. 7)")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--elastic-demo", action="store_true",
                    help="simulate losing half the devices mid-run and recover")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=256)
    mesh = build_mesh(args.mesh)
    if args.grad_compression and (mesh is None or "pod" not in mesh.shape):
        raise SystemExit("--grad-compression needs a mesh with a pod axis (e.g. 2x2x2)")

    if args.group_lasso > 0:
        opt = prox_sgd(momentum=0.9, prox_spec={"ffn": (args.group_lasso, "columns")})
    else:
        opt = adamw(weight_decay=0.01)
    lr_fn = cosine_warmup(args.lr, warmup=10, total=args.steps)

    lm = MarkovLM(vocab=cfg.vocab, k=8, seed=0)
    ck = Checkpointer(args.checkpoint_dir, keep=3) if args.checkpoint_dir else None

    def fresh_state():
        return init_train_state(jax.random.PRNGKey(0), cfg, opt,
                                grad_compression=args.grad_compression)

    def place(state, mesh):
        if mesh is None:
            return state
        specs = sharding.params_pspecs(state, mesh)
        return jax.device_put(state, sharding.named(mesh, specs))

    state = fresh_state()
    start_step = 0
    if ck and args.resume:
        s, restored = ck.restore_latest(state)
        if s is not None:
            state, start_step = restored, s + 1
            print(f"[resume] restored checkpoint step {s}")
    state = place(state, mesh)

    def make_step(mesh):
        step = make_train_step(cfg, opt, lr=args.lr, accum_steps=args.accum_steps,
                               grad_compression=args.grad_compression, mesh=mesh)
        return jax.jit(step)

    step_fn = make_step(mesh)
    ctx = mesh_context(mesh)
    with ctx:
        if mesh is not None:
            compat.set_global_mesh(mesh)
        t0 = time.time()
        i = start_step
        while i < args.steps:
            try:
                b = lm.batch(args.batch, args.seq, seed=i)
                state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
                if i % 10 == 0 or i == args.steps - 1:
                    tok_s = args.batch * args.seq * max(i - start_step, 1) / (time.time() - t0)
                    print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                          f"gnorm {float(m['grad_norm']):.2f}  tok/s {tok_s:.0f}",
                          flush=True)
                if ck and i % args.checkpoint_every == 0 and i > start_step:
                    ck.save(i, state)
                if args.elastic_demo and i == args.steps // 2 and mesh is not None \
                        and len(mesh.devices.flatten()) > 2:
                    raise RuntimeError("simulated pod failure")
                i += 1
            except RuntimeError as e:
                if "simulated" not in str(e):
                    raise
                # elastic recovery: shrink mesh, reshard, continue
                survivors = jax.devices()[: max(len(jax.devices()) // 2, 2)]
                plan = plan_for_devices(len(survivors),
                                        model_parallel=min(2, len(survivors)),
                                        multi_pod_threshold=1 << 30)
                new_mesh = plan.build(survivors)
                print(f"[elastic] {e}; remeshing {mesh.shape} -> {new_mesh.shape} "
                      f"and resharding state", flush=True)
                host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
                specs = sharding.params_pspecs(state, new_mesh)
                state = reshard_tree(host, new_mesh, specs)
                mesh = new_mesh
                args.grad_compression = False  # single pod left
                step_fn = make_step(None)
                compat.set_global_mesh(mesh)
                from repro.distributed import act_shard
                act_shard.set_mesh(mesh)  # activation constraints follow the new mesh
                i += 1
        if ck:
            ck.save(args.steps - 1, state, blocking=True)
            print(f"[checkpoint] final save at step {args.steps - 1}")
    print(f"done: {args.steps - start_step} steps in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
