import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Placeholder host devices exist ONLY for the dry-run (smoke tests/benches see
# the real single device).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Per cell, two passes (DESIGN.md Sec. 6):
  1. compile pass — full config, scan-over-layers: proves the sharding config
     is coherent (the deliverable) and yields memory_analysis().
  2. cost pass — two reduced-depth *unrolled* lowerings (L1, L2); per-layer
     cost = (c2-c1)/(L2-L1); extrapolated to the full depth.  Yields accurate
     HLO FLOPs / bytes and the collective schedule parsed from the HLO text
     (while bodies are undercounted by cost_analysis, hence the unroll).

Results append to a JSON file consumed by benchmarks/roofline.py and
EXPERIMENTS.md.  Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out dryrun_results.json
"""
import argparse
import json
import re
import time
import traceback
from dataclasses import replace

import jax
import numpy as np

from repro.configs import ARCHS, SHAPE_CELLS, cell_supported, input_specs
from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding
from repro.distributed.act_shard import mesh_context
from repro.launch.mesh import HW, make_production_mesh
from repro.models import api
from repro.models import flops as aflops
from repro.optim.optimizers import adamw
from repro.training.trainer import TrainState, make_train_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


# ---------------------------------------------------------------------------
# HLO text parsing: per-device collective link bytes (ring accounting)
# ---------------------------------------------------------------------------


def _shape_bytes(segment: str) -> int:
    tot = 0
    for dt, dims in re.findall(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]",
                               segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-device ICI link bytes by op kind (ring model):
    AG/A2A (n-1)/n * out, RS (n-1) * out, AR 2(n-1)/n * out, CP out."""
    per_kind: dict[str, float] = {k: 0.0 for k in _COLL}
    counts: dict[str, int] = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:%[\w.-]+|\w[\w.-]*) = (.*?)\s+(all-gather-start|all-gather|"
                     r"all-reduce-start|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute-start|collective-permute)\(", ls)
        if not m:
            continue
        restype, op = m.groups()
        kind = op.replace("-start", "")
        n = _group_size(ls, n_devices)
        if n <= 1:
            continue
        out_bytes = _shape_bytes(restype)
        if kind == "all-gather":
            link = (n - 1) / n * out_bytes
        elif kind == "all-reduce":
            link = 2 * (n - 1) / n * out_bytes
        elif kind == "reduce-scatter":
            link = (n - 1) * out_bytes
        elif kind == "all-to-all":
            link = (n - 1) / n * out_bytes
        else:  # collective-permute
            link = float(out_bytes)
        per_kind[kind] += link
        counts[kind] += 1
    per_kind["total"] = sum(per_kind[k] for k in _COLL)
    return {"link_bytes": per_kind, "counts": counts}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def apply_variant(cfg: ArchConfig, variant: str | None) -> ArchConfig:
    """§Perf hillclimb levers, selectable per run (see EXPERIMENTS.md §Perf)."""
    if not variant:
        return cfg
    for v in variant.split("+"):
        if v == "causal_skip":
            cfg = replace(cfg, causal_chunk_skip=True)
        elif v == "remat_off":
            cfg = replace(cfg, remat=False)
        elif v.startswith("qchunk"):
            cfg = replace(cfg, q_chunk=int(v[len("qchunk"):]))
        elif v.startswith("ssmchunk"):
            cfg = replace(cfg, ssm_chunk=int(v[len("ssmchunk"):]))
        elif v == "moe_manual":
            cfg = replace(cfg, moe_manual=True)
        elif v == "ws_decode":
            pass  # handled in build_cell (sharding, not model math)
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg


def build_cell(cfg: ArchConfig, cell: ShapeCell, mesh, *, unroll: bool,
               variant: str | None = None):
    """Returns (fn, args, in_shardings)."""
    cfg = apply_variant(cfg, variant)
    ws_decode = variant is not None and "ws_decode" in variant
    specs = input_specs(cfg, cell)
    if cell.kind == "train":
        opt = adamw()
        step = make_train_step(cfg, opt, unroll=unroll)
        state = jax.eval_shape(lambda: TrainState(
            params=api.init_params(jax.random.PRNGKey(0), cfg),
            opt_state=opt.init(api.abstract_params(cfg)),
            step=np.zeros((), np.int32), error_fb=None))
        state_sh = sharding.named(mesh, sharding.params_pspecs(state, mesh))
        batch_sh = sharding.named(mesh, sharding.batch_pspecs(specs, mesh))
        # out_shardings pin the updated state to the input sharding: gradient
        # reduction lowers to reduce-scatter (not full all-reduce) and the
        # optimizer update stays sharded (§Perf iteration 2)
        return step, (state, specs), (state_sh, batch_sh), (state_sh, None)
    params = api.abstract_params(cfg)
    params_sh = sharding.named(
        mesh, sharding.params_pspecs(params, mesh, fsdp=not ws_decode))
    if cell.kind == "prefill":
        def step(params, batch):
            h, _ = api.prefill(params, cfg, batch, unroll=unroll)
            return h

        batch_sh = sharding.named(mesh, sharding.batch_pspecs(specs, mesh))
        h_sh = None  # hidden output: let XLA keep the internal sharding
        return step, (params, specs), (params_sh, batch_sh), h_sh
    # decode
    state = api.abstract_decode_state(cfg, cell)
    state_sh = sharding.named(mesh, sharding.decode_state_pspecs(state, mesh))
    tok_sh = sharding.named(mesh, sharding.batch_pspecs(
        {"token": specs["token"], "pos": specs["pos"]}, mesh))

    def step(params, state, token, pos):
        return api.decode(params, cfg, state, token, pos, unroll=unroll)

    return step, (params, state, specs["token"], specs["pos"]), \
        (params_sh, state_sh, tok_sh["token"], tok_sh["pos"]), (None, state_sh)


def reduce_layers(cfg: ArchConfig, n: int, cell: ShapeCell | None = None) -> ArchConfig:
    """Depth-reduced config for the cost pass (hybrid: whole groups).

    The cost pass unrolls inner chunk loops; cap the chunk count at 32 by
    enlarging the SSM chunk for long sequences (chunk size is a tunable —
    larger chunks raise arithmetic intensity, fitting the MXU; noted in
    EXPERIMENTS.md §Methodology)."""
    over = {}
    if cell is not None and cell.kind != "decode":
        over["ssm_chunk"] = max(cfg.ssm_chunk, cell.seq_len // 32)
        over["q_chunk"] = max(cfg.q_chunk, cell.seq_len // 32)
    if cfg.family == "hybrid":
        return replace(cfg, n_layers=n * cfg.hybrid_period, **over)
    if cfg.enc_layers:
        return replace(cfg, n_layers=n, enc_layers=n, **over)
    return replace(cfg, n_layers=n, **over)


def layer_units(cfg: ArchConfig) -> float:
    """How many 'units' the full model has in reduce_layers units."""
    if cfg.family == "hybrid":
        return cfg.n_layers / cfg.hybrid_period
    return float(cfg.n_layers)


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def lower_compile(cfg, cell, mesh, *, unroll, variant=None):
    fn, args, in_sh, out_sh = build_cell(cfg, cell, mesh, unroll=unroll,
                                         variant=variant)
    t0 = time.time()
    with mesh, mesh_context(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
    t2 = time.time()
    return lowered, compiled, {"lower_s": round(t1 - t0, 2),
                               "compile_s": round(t2 - t1, 2)}


def cost_snapshot(compiled, n_devices: int) -> dict:
    ca = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text(), n_devices)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_link_bytes": colls["link_bytes"]["total"],
            "coll_by_kind": colls["link_bytes"],
            "coll_counts": colls["counts"]}


def run_cell(arch: str, shape: str, multi_pod: bool, do_cost: bool = True,
             cost_layers=(2, 4), variant: str | None = None) -> dict:
    cfg = ARCHS[arch]
    if cfg.family == "hybrid":
        cost_layers = (1, 2)  # hybrid units are whole 6-layer groups
    cell = SHAPE_CELLS[shape]
    rec: dict = {"arch": arch, "shape": shape, "variant": variant or "baseline",
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    ok, why = cell_supported(cfg, cell)
    if not ok:
        rec["status"] = "SKIP"
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    try:
        # pass 1: full config, scanned — the compile deliverable
        _, compiled, times = lower_compile(cfg, cell, mesh, unroll=False,
                                            variant=variant)
        ma = compiled.memory_analysis()
        rec.update(times)
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        rec["scanned_cost"] = cost_snapshot(compiled, n_dev)
        del compiled
        rec["status"] = "PASS"
    except Exception as e:
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-2000:]
        return rec

    if not do_cost:
        return rec
    try:
        # pass 2: two-point unrolled extrapolation (single-pod roofline only
        # runs it once per mesh; terms are per-device so mesh matters)
        l1, l2 = cost_layers
        snaps = {}
        for ln in (l1, l2):
            _, comp, t = lower_compile(reduce_layers(cfg, ln, cell), cell, mesh,
                                       unroll=True, variant=variant)
            snaps[ln] = cost_snapshot(comp, n_dev)
            snaps[ln]["compile_s"] = t["compile_s"]
            del comp
        units = layer_units(cfg)
        full = {}
        for k in ("flops", "bytes", "coll_link_bytes"):
            per = (snaps[l2][k] - snaps[l1][k]) / (l2 - l1)
            full[k] = snaps[l2][k] + (units - l2) * per
            full[f"{k}_per_layer"] = per
        rec["cost_points"] = snaps
        rec["cost"] = full
        # roofline terms (per-device seconds)
        rec["roofline"] = {
            "compute_s": full["flops"] / HW.PEAK_FLOPS_BF16,
            "memory_s": full["bytes"] / HW.HBM_BW,
            "collective_s": full["coll_link_bytes"] / HW.ICI_BW,
        }
        mf = aflops.model_flops(cfg, cell)
        rec["model_flops_global"] = mf
        rec["model_flops_per_dev"] = mf / n_dev
        rec["useful_flop_ratio"] = (mf / n_dev) / max(full["flops"], 1.0)
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["dominant"] = dom
        step_time = max(rec["roofline"].values())
        rec["roofline_fraction"] = (mf / n_dev / HW.PEAK_FLOPS_BF16) / max(step_time, 1e-12)
    except Exception as e:
        rec["cost_error"] = f"{type(e).__name__}: {e}"
        rec["trace_cost"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="perf levers, '+'-joined: causal_skip, ws_decode, "
                         "remat_off, qchunkN")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPE_CELLS) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
            for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16",
                       args.variant or "baseline")
                if args.skip_existing and key in done:
                    continue
                t0 = time.time()
                # cost pass only on the single-pod mesh (the roofline table's
                # scope); multi-pod proves the pod axis shards
                rec = run_cell(arch, shape, mp, do_cost=not args.no_cost and not mp,
                               variant=args.variant)
                rec["wall_s"] = round(time.time() - t0, 1)
                results = [r for r in results if
                           (r["arch"], r["shape"], r["mesh"],
                            r.get("variant", "baseline")) != key] + [rec]
                json.dump(results, open(args.out, "w"), indent=1)
                dom = rec.get("dominant", "-")
                print(f"[{arch} x {shape} x {key[2]}] {rec['status']} "
                      f"wall={rec['wall_s']}s dominant={dom} "
                      f"{rec.get('error', '')}", flush=True)

    n_pass = sum(r["status"] == "PASS" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run summary: {n_pass} PASS / {n_skip} SKIP / {n_fail} FAIL ==")


if __name__ == "__main__":
    main()
