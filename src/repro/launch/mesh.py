"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from repro import compat

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


class HW:
    """TPU v5e-like hardware constants for the roofline (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link (the task-specified accounting)
    HBM_BYTES = 16 * 2**30
