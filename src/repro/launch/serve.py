"""Serving driver: scheduler-driven batched generation with optional LCC
compression, multi-device sharding and token streaming.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 6 --compress --stream

    # 2-way tensor parallel on a multi-device host (e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=2)
    PYTHONPATH=src python -m repro.launch.serve --reduced --tp 2
"""
import argparse
import time

import jax

import repro.core as core
from repro import compat
from repro.configs import get_arch, reduced_config
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Scheduler


def build_mesh(dp: int, tp: int):
    """("data", "model") mesh over the host's devices, or None for 1x1."""
    if dp * tp <= 1:
        return None
    if dp * tp > jax.device_count():
        raise SystemExit(f"--dp {dp} x --tp {tp} needs {dp * tp} devices, "
                         f"host has {jax.device_count()} "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return compat.make_mesh((dp, tp), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--compress", action="store_true",
                    help="Algorithm 1 over every compressible site (any "
                         "family), served from the CompressedModel artifact")
    ap.add_argument("--kernel", action="store_true",
                    help="with --compress: decode through the site-keyed "
                         "fused-kernel executor (interpret-mode Pallas off-TPU"
                         " — slower on CPU dev boxes, the TPU hot path)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dp", type=int, default=1, help="data-parallel mesh axis")
    ap.add_argument("--tp", type=int, default=1, help="tensor-parallel mesh axis")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are sampled")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="paged-KV block size in tokens; 0 = contiguous "
                         "per-slot slabs (attention families only)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="total usable KV pool blocks (default: one full "
                         "view per slot, i.e. contiguous-equivalent memory)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share prefilled prompt-prefix blocks across "
                         "requests (copy-on-write; paged engines only)")
    args = ap.parse_args()
    if args.kernel and not args.compress:
        ap.error("--kernel routes a compressed artifact; pass --compress too")

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    artifact = None
    if args.compress:
        artifact = api.compress_model(
            params, cfg,
            core.CompressionConfig(algorithm="fp" if args.kernel else "fs",
                                   max_share_rel_err=0.06),
            build_packed=args.kernel)
        print(artifact.report.table())

    lm = MarkovLM(vocab=cfg.vocab, k=8, seed=0)
    prompts = [lm.sample(1, 8, seed=100 + i)[0, :8].tolist()
               for i in range(args.requests)]
    kv = dict(kv_block=args.kv_block or None, kv_blocks=args.kv_blocks,
              prefix_cache=args.prefix_cache)
    if artifact is not None:
        eng = ServingEngine(artifact=artifact, n_slots=args.slots, max_len=128,
                            temperature=args.temperature,
                            use_kernel=args.kernel,
                            mesh=build_mesh(args.dp, args.tp), **kv)
    else:
        eng = ServingEngine(params, cfg, n_slots=args.slots, max_len=128,
                            temperature=args.temperature,
                            mesh=build_mesh(args.dp, args.tp), **kv)
    sched = Scheduler(eng)
    on_token = ((lambda rid, tok: print(f"  req{rid} += {tok}", flush=True))
                if args.stream else None)
    t0 = time.time()
    rids = [sched.enqueue(p, max_new=args.max_new,
                          priority=args.requests - i,  # earlier = higher
                          on_token=on_token)
            for i, p in enumerate(prompts)]
    sched.run()
    dt = time.time() - t0
    res = [sched.take_result(r) for r in rids]
    tok = sum(len(r.tokens) - r.prompt_len for r in res)
    for i, r in enumerate(res):
        tag = f" [error: {r.error}]" if r.error else ""
        print(f"req{i}: prompt={r.tokens[:r.prompt_len]} -> "
              f"{r.tokens[r.prompt_len:]}{tag}")
    where = (f"mesh {args.dp}x{args.tp}" if args.dp * args.tp > 1
             else jax.default_backend())
    print(f"{tok} tokens in {dt:.1f}s ({tok / dt:.1f} tok/s, "
          f"{args.slots} slots, {eng.step_dispatches} dispatches, {where})")
    ps = eng.pool_stats()
    if ps:
        print(f"kv pool: {ps['n_blocks']} blocks x {ps['block_size']} tok, "
              f"peak {ps['peak_in_use_blocks']} in use, "
              f"prefix hit-rate {ps['prefix_hit_rate']:.2f} "
              f"({ps['prefix_hit_tokens']} tok), {ps['cow_copies']} COW, "
              f"{ps['evictions']} evictions, "
              f"{sched.admitted_while_running} continuous admissions, "
              f"{sched.mem_stalls} block stalls")


if __name__ == "__main__":
    main()
