"""Serving driver: batched generation with optional LCC compression.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 6 --compress
"""
import argparse

import jax

import repro.core as core
from repro.configs import get_arch, reduced_config
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.serving.engine import ServingEngine, compress_ffn_for_serving


def compress_ffn(params, cfg, max_share_rel_err=0.06):
    """Algorithm-1 steps 2-3 on every FFN projection; returns (params', report)."""
    params_c, _matvecs, report = compress_ffn_for_serving(
        params, cfg,
        core.CompressionConfig(algorithm="fs",
                               max_share_rel_err=max_share_rel_err),
        build_matvecs=False)  # the demo serves through the XLA dense path
    return params_c, report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    if args.compress:
        if cfg.moe is not None or cfg.family in ("ssm", "hybrid") or cfg.enc_layers:
            raise SystemExit("--compress demo targets dense FFN archs")
        params, report = compress_ffn(params, cfg)
        print(report.table())

    lm = MarkovLM(vocab=cfg.vocab, k=8, seed=0)
    prompts = [lm.sample(1, 8, seed=100 + i)[0, :8].tolist()
               for i in range(args.requests)]
    eng = ServingEngine(params, cfg, n_slots=args.slots, max_len=128,
                        temperature=args.temperature)
    import time
    t0 = time.time()
    res = eng.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    tok = sum(len(r.tokens) - r.prompt_len for r in res)
    for i, r in enumerate(res):
        print(f"req{i}: prompt={r.tokens[:r.prompt_len]} -> "
              f"{r.tokens[r.prompt_len:]}")
    print(f"{tok} tokens in {dt:.1f}s ({tok / dt:.1f} tok/s, "
          f"{args.slots} slots, CPU interpret)")


if __name__ == "__main__":
    main()
