"""Serving driver: scheduler-driven batched generation with optional LCC
compression, multi-device sharding and token streaming.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 6 --compress --stream

    # 2-way tensor parallel on a multi-device host (e.g. under
    # XLA_FLAGS=--xla_force_host_platform_device_count=2)
    PYTHONPATH=src python -m repro.launch.serve --reduced --tp 2
"""
import argparse
import time

import jax

import repro.core as core
from repro import compat, obs
from repro.configs import get_arch, reduced_config
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Scheduler


def build_mesh(dp: int, tp: int):
    """("data", "model") mesh over the host's devices, or None for 1x1."""
    if dp * tp <= 1:
        return None
    if dp * tp > jax.device_count():
        raise SystemExit(f"--dp {dp} x --tp {tp} needs {dp * tp} devices, "
                         f"host has {jax.device_count()} "
                         "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return compat.make_mesh((dp, tp), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--compress", action="store_true",
                    help="Algorithm 1 over every compressible site (any "
                         "family), served from the CompressedModel artifact")
    ap.add_argument("--kernel", action="store_true",
                    help="with --compress: decode through the site-keyed "
                         "fused-kernel executor (interpret-mode Pallas off-TPU"
                         " — slower on CPU dev boxes, the TPU hot path)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dp", type=int, default=1, help="data-parallel mesh axis")
    ap.add_argument("--tp", type=int, default=1, help="tensor-parallel mesh axis")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are sampled")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="paged-KV block size in tokens; 0 = contiguous "
                         "per-slot slabs (attention families only)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="total usable KV pool blocks (default: one full "
                         "view per slot, i.e. contiguous-equivalent memory)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share prefilled prompt-prefix blocks across "
                         "requests (copy-on-write; paged engines only)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the merged metrics snapshot (+ trace summary "
                         "and live roofline) as JSON at exit")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request spans as JSONL at exit")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics (Prometheus text) on this port "
                         "for the run's duration (0 = ephemeral)")
    args = ap.parse_args()
    if args.kernel and not args.compress:
        ap.error("--kernel routes a compressed artifact; pass --compress too")

    cfg = get_arch(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=256)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    artifact = None
    if args.compress:
        artifact = api.compress_model(
            params, cfg,
            core.CompressionConfig(algorithm="fp" if args.kernel else "fs",
                                   max_share_rel_err=0.06),
            build_packed=args.kernel)
        print(artifact.report.table())

    lm = MarkovLM(vocab=cfg.vocab, k=8, seed=0)
    prompts = [lm.sample(1, 8, seed=100 + i)[0, :8].tolist()
               for i in range(args.requests)]
    kv = dict(kv_block=args.kv_block or None, kv_blocks=args.kv_blocks,
              prefix_cache=args.prefix_cache, tracer=True)
    if artifact is not None:
        eng = ServingEngine(artifact=artifact, n_slots=args.slots, max_len=128,
                            temperature=args.temperature,
                            use_kernel=args.kernel,
                            mesh=build_mesh(args.dp, args.tp), **kv)
    else:
        eng = ServingEngine(params, cfg, n_slots=args.slots, max_len=128,
                            temperature=args.temperature,
                            mesh=build_mesh(args.dp, args.tp), **kv)
    registries = [obs.get_global(), eng.metrics]
    srv = None
    if args.metrics_port is not None:
        srv = obs.start_metrics_server(registries, port=args.metrics_port)
        print(f"metrics: http://127.0.0.1:{srv.server_port}/metrics")
    sched = Scheduler(eng)
    on_token = ((lambda rid, tok: print(f"  req{rid} += {tok}", flush=True))
                if args.stream else None)
    t0 = time.time()
    rids = [sched.enqueue(p, max_new=args.max_new,
                          priority=args.requests - i,  # earlier = higher
                          on_token=on_token)
            for i, p in enumerate(prompts)]
    sched.run()
    dt = time.time() - t0
    res = [sched.take_result(r) for r in rids]
    tok = sum(len(r.tokens) - r.prompt_len for r in res)
    for i, r in enumerate(res):
        tag = f" [error: {r.error}]" if r.error else ""
        print(f"req{i}: prompt={r.tokens[:r.prompt_len]} -> "
              f"{r.tokens[r.prompt_len:]}{tag}")
    where = (f"mesh {args.dp}x{args.tp}" if args.dp * args.tp > 1
             else jax.default_backend())
    print(f"{tok} tokens in {dt:.1f}s ({tok / dt:.1f} tok/s, "
          f"{args.slots} slots, {eng.step_dispatches} dispatches, {where})")
    ps = eng.pool_stats()
    if ps["n_blocks"]:
        print(f"kv pool: {ps['n_blocks']} blocks x {ps['block_size']} tok, "
              f"peak {ps['peak_in_use_blocks']} in use, "
              f"prefix hit-rate {ps['prefix_hit_rate']:.2f} "
              f"({ps['prefix_hit_tokens']} tok), {ps['cow_copies']} COW, "
              f"{ps['evictions']} evictions, "
              f"{sched.admitted_while_running} continuous admissions, "
              f"{sched.mem_stalls} block stalls")

    # -------------------------------------------------- end-of-run telemetry
    tsum = eng.tracer.summary()
    prof = eng.profiler.summary()

    def ms(v):
        return "-" if v is None else f"{v * 1e3:8.1f}"

    print("telemetry summary")
    print(f"  {'metric':<14}{'p50 ms':>10}{'p99 ms':>10}{'n':>6}")
    for name in ("queue_wait_s", "ttft_s", "tpot_s", "e2e_s"):
        st = tsum[name]
        print(f"  {name[:-2]:<14}{ms(st['p50']):>10}{ms(st['p99']):>10}"
              f"{st['n']:>6}")
    print(f"  requests: {tsum['by_status']} ({tsum['open']} unclosed), "
          f"decode steps {prof['steps']}"
          + (f" @ {prof['tok_s']:.1f} tok/s" if prof["tok_s"] else ""))
    live = obs.live_roofline(eng)
    if live is not None:
        print(f"  live roofline: {live['total_lcc_adds']} lcc adds/token x "
              f"{live['decode_tok_s_n8']} tok/s = "
              f"{live['achieved_adds_per_s']} adds/s "
              f"({live['pallas_launches']} launches / "
              f"{live['n_layer_plans']} plans per step)")
    if args.trace_out:
        n_open = eng.tracer.dump_jsonl(args.trace_out)
        print(f"wrote {args.trace_out} ({tsum['completed']} spans, "
              f"{n_open} unclosed)")
    if args.metrics_out:
        obs.dump_metrics(args.metrics_out, registries,
                         trace_summary=tsum, profiler=prof,
                         live_roofline=live)
        print(f"wrote {args.metrics_out}")
    if srv is not None:
        srv.shutdown()


if __name__ == "__main__":
    main()
