"""Offline compression driver: parallel, resumable, budget-driven Algorithm 1.

    # quickstart-scale dense transformer, 4 worker processes
    PYTHONPATH=src python -m repro.launch.compress --arch olmo-1b --quickstart \
        --workers 4 --out /tmp/comp

    # budget-constrained run (adds-budget allocator chooses per-unit plans),
    # resumable after a kill: same command + --resume picks up the cached
    # slices and the recorded plans
    PYTHONPATH=src python -m repro.launch.compress --arch olmo-1b --quickstart \
        --budget 200000 --workers 4 --out /tmp/comp --resume

The run directory layout under ``--out``:

    run/          pipeline manifest (chosen per-unit plans, unit hashes)
    cache/        content-addressed slice results (msgpack+crc32)
    artifact/     the final ``CompressedModel`` checkpoint

The artifact is exactly what ``ServingEngine(artifact=...)`` consumes.
"""
import argparse
import json
import os
import time

import jax

from repro.core import CompressionConfig


def build_model(arch: str, quickstart: bool, seed: int):
    """(params, cfg) for a registry arch or the paper's small models.

    Parameters are keyed by ``--seed`` so repeated invocations (and the
    resume path) see identical weights; point this at a training checkpoint
    restore for real runs.
    """
    if arch == "resnet-small":
        from repro.models.resnet import init_resnet, resnet_small_config

        cfg = resnet_small_config(classes=6)
        return init_resnet(jax.random.PRNGKey(seed), cfg), cfg
    if arch == "mlp":
        from repro.models.mlp import MLPConfig, init_mlp

        cfg = MLPConfig()
        return init_mlp(jax.random.PRNGKey(seed), in_dim=cfg.in_dim,
                        hidden=cfg.hidden, classes=cfg.classes), cfg
    from repro.configs import get_arch, reduced_config
    from repro.models import api

    cfg = get_arch(arch)
    if quickstart or jax.default_backend() == "cpu":
        cfg = reduced_config(cfg, vocab=64, n_layers=2, d_model=32, d_ff=48,
                             n_heads=2, n_kv_heads=2, head_dim=16)
    return api.init_params(jax.random.PRNGKey(seed), cfg), cfg


def parse_compression(pairs: list[str]) -> CompressionConfig:
    """--config key=value overrides onto the pipeline's default FP config."""
    cfg = CompressionConfig(algorithm="fp", weight_sharing=True,
                            max_share_rel_err=0.06)
    for pair in pairs:
        key, _, val = pair.partition("=")
        if not hasattr(cfg, key):
            raise SystemExit(f"unknown CompressionConfig field {key!r}")
        cur = getattr(cfg, key)
        if val.lower() in ("none", "null"):
            parsed = None
        elif isinstance(cur, bool):
            parsed = val.lower() in ("1", "true", "yes")
        elif isinstance(cur, int) and not isinstance(cur, bool):
            parsed = int(val)
        elif isinstance(cur, float):
            parsed = float(val)
        elif cur is None:  # untyped optionals: frac-ish => float, else int
            parsed = float(val) if "." in val else int(val)
        else:
            parsed = val
        setattr(cfg, key, parsed)
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="olmo-1b",
                    help="registry arch id, 'resnet-small', or 'mlp'")
    ap.add_argument("--family", default=None,
                    help="expected architecture family (sanity check)")
    ap.add_argument("--quickstart", action="store_true",
                    help="reduced quickstart-scale dims (default on CPU)")
    ap.add_argument("--config", nargs="*", default=[], metavar="KEY=VAL",
                    help="CompressionConfig overrides, e.g. algorithm=fs")
    ap.add_argument("--budget", type=int, default=None,
                    help="global additions budget (invokes the allocator)")
    ap.add_argument("--workers", type=int, default=1,
                    help="slice-job worker processes")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from --out (manifest + cache)")
    ap.add_argument("--out", required=True, help="run directory")
    ap.add_argument("--include", default=None,
                    help="unit-name prefix filter, e.g. 'ffn.'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--conv-subsample", type=int, default=None)
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-slice progress events")
    ap.add_argument("--metrics-out", default=None,
                    help="write the run's metrics snapshot as JSON at exit")
    args = ap.parse_args()

    from repro.models import api
    from repro.obs import MetricsRegistry, dump_metrics, get_global

    metrics = MetricsRegistry() if args.metrics_out else None

    params, cfg = build_model(args.arch, args.quickstart, args.seed)
    family = api.family_of(cfg)
    if args.family is not None and args.family != family:
        raise SystemExit(f"--family {args.family} but {args.arch} is {family!r}")
    compression = parse_compression(args.config)

    chatty = {"plan", "unit_done", "budget", "resume"}

    def progress(ev):
        if not args.quiet or ev.kind in chatty:
            print(f"[{ev.kind}] {ev}", flush=True)

    t0 = time.time()
    art = api.compress_model(
        params, cfg, compression,
        include=args.include,
        conv_channel_subsample=args.conv_subsample,
        n_workers=args.workers,
        budget_adds=args.budget,
        cache_dir=os.path.join(args.out, "cache"),
        run_dir=os.path.join(args.out, "run"),
        resume=args.resume,
        progress=progress,
        metrics=metrics,
    )
    art.save(os.path.join(args.out, "artifact"))
    wall = time.time() - t0

    stats = dict(art.pipeline_stats)
    stats["total_wall_s"] = round(wall, 2)
    print(art.report.table())
    lcc = art.report.total_stage("lcc")
    print(f"family={family} units={stats['units']} jobs={stats['jobs']} "
          f"workers={stats['workers']} cache={stats['cache_hits']}h/"
          f"{stats['cache_misses']}m wall={wall:.1f}s "
          f"({stats['units_per_s']} units/s)")
    print(f"adds: baseline {art.report.total_baseline()} -> lcc {lcc} "
          f"(ratio {art.report.ratio('lcc'):.2f}x)"
          + (f"; budget {args.budget} landed {lcc / args.budget:.1%}"
             if args.budget else ""))
    with open(os.path.join(args.out, "stats.json"), "w") as f:
        json.dump(stats, f, indent=2)
        f.write("\n")
    if args.metrics_out:
        metrics.gauge("pipeline_adds", "artifact adds by stage",
                      labels=("stage",)).set(art.report.total_baseline(),
                                             stage="baseline")
        metrics.gauge("pipeline_adds", "artifact adds by stage",
                      labels=("stage",)).set(lcc, stage="lcc")
        dump_metrics(args.metrics_out, [get_global(), metrics])
        print(f"wrote {args.metrics_out}")
    print(f"artifact -> {os.path.join(args.out, 'artifact')}")


if __name__ == "__main__":
    main()
