"""Fault-tolerant checkpointing: msgpack + crc32, async writer, auto-resume.

Layout:  <dir>/step_<N>/shard_<proc>.msgpack  +  <dir>/step_<N>/DONE
A checkpoint is valid iff DONE exists and every shard's crc32 verifies; the
writer publishes DONE last (atomic rename), so a crash mid-write can never be
mistaken for a valid checkpoint.  Saves run on a background thread (training
continues; the paper-scale rule of thumb: checkpoint time must hide behind a
step).  ``restore_latest`` walks backwards until it finds an intact step —
corrupted/partial checkpoints are skipped with a warning, not a crash.

On multi-host deployments each process saves its addressable shards
(shard_<proc>); this container is single-process so shard_0 holds everything.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import zlib

import jax
import msgpack
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in paths_leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[name] = leaf
    return out, treedef


def _pack_leaf(x) -> dict:
    a = np.asarray(x)
    if a.dtype == jax.numpy.bfloat16:
        raw = a.view(np.uint16)
        return {"dtype": "bfloat16", "shape": list(a.shape),
                "data": raw.tobytes(), "crc": zlib.crc32(raw.tobytes())}
    b = a.tobytes()
    return {"dtype": a.dtype.str, "shape": list(a.shape), "data": b,
            "crc": zlib.crc32(b)}


def _unpack_leaf(d):
    if d["dtype"] == "bfloat16":
        a = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        if zlib.crc32(d["data"]) != d["crc"]:
            raise IOError("checkpoint crc mismatch")
        return a.view(jax.numpy.bfloat16)
    if zlib.crc32(d["data"]) != d["crc"]:
        raise IOError("checkpoint crc mismatch")
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree)
        else:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(target=self._write, args=(step, host_tree),
                                            daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> None:
        d = os.path.join(self.dir, f"step_{step:010d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat, _ = _flatten(host_tree)
        payload = {k: _pack_leaf(v) for k, v in flat.items()}
        with open(os.path.join(tmp, f"shard_{self.proc}.msgpack"), "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        open(os.path.join(tmp, "DONE"), "w").close()
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", n)
            if m and os.path.exists(os.path.join(self.dir, n, "DONE")):
                out.append(int(m.group(1)))
        return sorted(out)

    def restore_flat(self, step: int) -> dict:
        """Raw ``{flat_name: array}`` payload of one step, every leaf
        crc-verified.  Used by consumers (e.g. ``core.artifact``) whose tree
        structure is recorded in the payload itself rather than supplied as a
        like-tree."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, f"shard_{self.proc}.msgpack"), "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)
        return {name: _unpack_leaf(leaf) for name, leaf in payload.items()}

    def restore(self, step: int, like_tree):
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, f"shard_{self.proc}.msgpack"), "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)
        flat_like, treedef = _flatten(like_tree)
        leaves = []
        for name in flat_like:
            if name not in payload:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            leaves.append(_unpack_leaf(payload[name]))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like_tree):
        """(step, tree) from the newest *intact* checkpoint; (None, None) if none."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, like_tree)
            except Exception as e:  # corrupted shard: fall back to previous
                print(f"[checkpoint] step {step} unreadable ({e}); trying older")
        return None, None
