"""Per-family compressible-unit adapters for the Algorithm-1 pipeline.

Every architecture family registers two things with ``models.api``:

* ``sites(params, cfg)`` — the list of :class:`DenseSite` / :class:`ConvSite`
  records naming each compressible matrix (or conv kernel), where it lives in
  the params pytree, and how it is stored (stacked layer/expert axes, the
  ``dense_init`` [K, N] layout vs the paper's [N, K] ``y = W x`` layout).
* a generic ``rebind`` built on those same sites: write a compressed unit's
  dense-effective map back into a (functionally updated) params pytree, so the
  stock XLA forward serves the compressed model with zero code changes.

Coverage per family (the hard-coded FFN walk this replaces handled only the
dense-transformer FFN):

====================  =====================================================
dense / vlm           FFN gate/up/down + attention q/k/v/o (or MLA projs)
moe                   per-expert gate/up/down, shared experts, attention
ssm (rwkv6)           channel-mix k/v/r + time-mix r/k/v/g/o
hybrid (zamba2)       mamba in/out projections + the weight-shared
                      attention+MLP block
audio (whisper)       encoder & decoder MLP fc1/fc2 + self/cross attention
resnet                every conv kernel (FK/PK reshaping) + the linear head
====================  =====================================================

Sites are deterministic functions of (params, cfg): ``rebind`` re-derives them
by name, so unit names double as stable artifact keys.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.compress import CompressibleConv, CompressibleDense
from repro.core.conv_reshape import conv_fk_matrices, conv_pk_matrices

__all__ = ["DenseSite", "ConvSite", "sites_for", "units_from_sites",
           "rebind_site", "rebind_site_traced", "effective_conv_kernel",
           "FAMILY_SITE_FNS"]


@dataclass(frozen=True)
class DenseSite:
    """One dense matrix: ``params[path...][index...]`` viewed as y = W x."""

    name: str
    path: tuple  # keys into the params pytree down to the array
    index: tuple = ()  # leading indices into stacked axes (layer, expert, ...)
    transpose: bool = True  # True: stored [K, N] (dense_init layout)

    def weight(self, params) -> np.ndarray:
        a = _lookup(params, self.path)
        for i in self.index:
            a = a[i]
        w = np.asarray(a, np.float64)
        return w.T if self.transpose else w


@dataclass(frozen=True)
class ConvSite:
    """One conv kernel [N, K, O, O] (NCHW/OIHW models)."""

    name: str
    path: tuple
    index: tuple = ()

    def kernel(self, params) -> np.ndarray:
        a = _lookup(params, self.path)
        for i in self.index:
            a = a[i]
        return np.asarray(a, np.float64)


def _lookup(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _set_in(tree, path, value):
    """Functional nested update; dict levels are copied, list levels rebuilt."""
    if not path:
        return value
    k, rest = path[0], path[1:]
    if isinstance(tree, list):
        out = list(tree)
        out[k] = _set_in(tree[k], rest, value)
        return out
    out = dict(tree)
    out[k] = _set_in(tree[k], rest, value)
    return out


def rebind_site(params, site: DenseSite | ConvSite, effective: np.ndarray):
    """Write a dense-effective weight (or conv kernel) back at ``site``.

    ``effective`` is [N, K_orig] for dense sites (pruned columns already
    zero-expanded) and [N, K, O, O] for conv sites.  Returns a new params
    pytree; the original is untouched.
    """
    arr = _lookup(params, site.path)
    new = np.asarray(effective)
    if isinstance(site, DenseSite) and site.transpose:
        new = new.T
    leaf = jnp.asarray(new, jnp.asarray(arr).dtype)
    if site.index:
        idx = site.index if len(site.index) > 1 else site.index[0]
        leaf = jnp.asarray(arr).at[idx].set(leaf)
    return _set_in(params, site.path, leaf)


def rebind_site_traced(params, site: DenseSite | ConvSite, effective):
    """jit-traceable :func:`rebind_site`: same semantics, but ``effective`` may
    be a traced jnp array (no host round-trip), so recovery fine-tuning can
    rebuild the loss through the rebind and differentiate w.r.t. the
    compressed parameterization."""
    arr = _lookup(params, site.path)
    new = effective
    if isinstance(site, DenseSite) and site.transpose:
        new = jnp.swapaxes(new, -1, -2)
    leaf = new.astype(arr.dtype)
    if site.index:
        idx = site.index if len(site.index) > 1 else site.index[0]
        leaf = arr.at[idx].set(leaf)
    return _set_in(params, site.path, leaf)


def units_from_sites(params, sites) -> list[CompressibleDense | CompressibleConv]:
    out: list[CompressibleDense | CompressibleConv] = []
    for s in sites:
        if isinstance(s, DenseSite):
            out.append(CompressibleDense(name=s.name, weight=s.weight(params)))
        else:
            out.append(CompressibleConv(name=s.name, kernel=s.kernel(params)))
    return out


def effective_conv_kernel(kernel: np.ndarray, conv_record: dict,
                          method: str = "pk") -> np.ndarray:
    """Dense-equivalent kernel of a ``compress_conv_kernel`` record.

    Channels with a decomposition are replaced by the decomposition's dense
    equivalent (inverting the FK/PK reshape); subsampled or pruned-out
    channels keep their original values — the accounting already covers them.
    """
    n, k, oh, ow = kernel.shape
    eff = np.array(kernel, np.float64, copy=True)
    for ch, dec in conv_record["decompositions"].items():
        m = dec.to_dense()
        if method == "fk":
            eff[:, ch] = m.reshape(n, oh, ow)
        else:  # pk rows are (n, j): kernel columns of length oh
            eff[:, ch] = m.reshape(n, ow, oh).transpose(0, 2, 1)
    return eff


# ---------------------------------------------------------------------------
# per-family site enumerations
# ---------------------------------------------------------------------------


def _attn_sites(cfg, base_path, layer_index, tag) -> list[DenseSite]:
    projs = ("q", "dkv", "kr", "uk", "uv", "o") if cfg.mla is not None \
        else ("q", "k", "v", "o")
    return [DenseSite(name=f"{tag}.{p}.l{layer_index[-1]}" if layer_index
                      else f"{tag}.{p}",
                      path=base_path + (p, "w"), index=layer_index)
            for p in projs]


def _ffn_sites(layer_index, tag="ffn", projs=("gate", "up", "down"),
               base=("blocks", "ffn")) -> list[DenseSite]:
    li = layer_index[-1] if layer_index else None
    return [DenseSite(name=f"{tag}.{p}.l{li}" if layer_index else f"{tag}.{p}",
                      path=base + (p, "w"), index=layer_index)
            for p in projs]


def _dense_sites(params, cfg) -> list[DenseSite]:
    sites: list[DenseSite] = []
    for li in range(cfg.n_layers):
        sites += _ffn_sites((li,))
        sites += _attn_sites(cfg, ("blocks", "attn"), (li,), "attn")
    return sites


def _moe_sites(params, cfg) -> list[DenseSite]:
    sites: list[DenseSite] = []
    ffn = params["blocks"]["ffn"]
    for li in range(cfg.n_layers):
        for p in ("gate", "up", "down"):
            for e in range(cfg.moe.n_experts):
                # expert stacks are raw [L, E, in, out] arrays (no "w" level)
                sites.append(DenseSite(name=f"moe.{p}.l{li}.e{e}",
                                       path=("blocks", "ffn", p),
                                       index=(li, e)))
        if "shared" in ffn:
            sites += _ffn_sites((li,), tag="moe.shared",
                                base=("blocks", "ffn", "shared"))
        sites += _attn_sites(cfg, ("blocks", "attn"), (li,), "attn")
    return sites


def _ssm_sites(params, cfg) -> list[DenseSite]:
    sites: list[DenseSite] = []
    for li in range(cfg.n_layers):
        for p in ("r", "k", "v", "g", "o"):
            sites.append(DenseSite(name=f"tm.{p}.l{li}",
                                   path=("blocks", "tm", p, "w"), index=(li,)))
        for p in ("k", "v", "r"):
            sites.append(DenseSite(name=f"cm.{p}.l{li}",
                                   path=("blocks", "cm", p, "w"), index=(li,)))
    return sites


def _hybrid_sites(params, cfg) -> list[DenseSite]:
    sites: list[DenseSite] = []
    for li in range(cfg.n_layers):
        for p in ("in_proj", "out_proj"):
            sites.append(DenseSite(name=f"mamba.{p}.l{li}",
                                   path=("blocks", "mamba", p, "w"), index=(li,)))
    # the one weight-shared attention+MLP block (unstacked)
    sites += _ffn_sites((), tag="shared_attn.ffn", base=("shared_attn", "ffn"))
    sites += _attn_sites(cfg, ("shared_attn", "attn"), (), "shared_attn.attn")
    return sites


def _audio_sites(params, cfg) -> list[DenseSite]:
    sites: list[DenseSite] = []
    for li in range(cfg.enc_layers):
        sites += _ffn_sites((li,), tag="enc.mlp", projs=("fc1", "fc2"),
                            base=("enc_blocks", "mlp"))
        sites += _attn_sites(cfg, ("enc_blocks", "attn"), (li,), "enc.attn")
    for li in range(cfg.n_layers):
        sites += _ffn_sites((li,), tag="dec.mlp", projs=("fc1", "fc2"),
                            base=("dec_blocks", "mlp"))
        sites += _attn_sites(cfg, ("dec_blocks", "attn"), (li,), "dec.attn")
        sites += _attn_sites(cfg, ("dec_blocks", "xattn"), (li,), "dec.xattn")
    return sites


def _mlp_sites(params, cfg) -> list[DenseSite]:
    # weights are stored [N, K] acting as y = W x (the paper layout): no
    # transpose.  fc1 is the paper's compression target (Sec. IV-A); fc2 is
    # listed too and filtered via ``include=`` when only fc1 is wanted.
    return [DenseSite(name="fc1", path=("fc1", "w"), transpose=False),
            DenseSite(name="fc2", path=("fc2", "w"), transpose=False)]


def _resnet_sites(params, cfg) -> list[DenseSite | ConvSite]:
    sites: list[DenseSite | ConvSite] = [ConvSite(name="stem", path=("stem",))]
    for i, blk in enumerate(params["blocks"]):
        sites.append(ConvSite(name=f"block{i}.conv1", path=("blocks", i, "conv1")))
        sites.append(ConvSite(name=f"block{i}.conv2", path=("blocks", i, "conv2")))
        if "proj" in blk:
            sites.append(ConvSite(name=f"block{i}.proj", path=("blocks", i, "proj")))
    sites.append(DenseSite(name="head", path=("head", "w"), transpose=False))
    return sites


FAMILY_SITE_FNS = {
    "dense": _dense_sites,
    "vlm": _dense_sites,
    "moe": _moe_sites,
    "ssm": _ssm_sites,
    "hybrid": _hybrid_sites,
    "audio": _audio_sites,
    "resnet": _resnet_sites,
    "mlp": _mlp_sites,
}


def sites_for(params, cfg) -> list[DenseSite | ConvSite]:
    """All compressible sites of (params, cfg); keyed off the family registry."""
    from . import api  # late: api imports this module for registration

    family = api.family_of(cfg)
    try:
        fn = FAMILY_SITE_FNS[family]
    except KeyError:
        raise KeyError(
            f"no compression adapter registered for family {family!r}; "
            f"known: {sorted(FAMILY_SITE_FNS)}") from None
    return fn(params, cfg)


def register_family(family: str, site_fn) -> None:
    """Extension hook: plug a new architecture family into the registry."""
    FAMILY_SITE_FNS[family] = site_fn
