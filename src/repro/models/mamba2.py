"""Mamba2 (SSD) layer: chunked state-space duality scan + recurrent decode.

Faithful to the SSD formulation (Dao & Gu 2024): per-head scalar decay
a_t = exp(dt_t * A_h) with A_h = -exp(A_log_h); within a chunk the output is an
attention-like masked product, across chunks a small state [H, N, P] is carried.
``unroll_chunks=True`` lowers the chunk loop as a static python loop for the
roofline cost pass (see attention.py for why).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.act_shard import constrain

from .layers import dense_init, linear, rms_norm, site_fmt, site_linear

__all__ = ["init_mamba2", "mamba2_prefill", "mamba2_decode", "Mamba2State"]


class Mamba2State(NamedTuple):
    ssm: jnp.ndarray  # [B, H, N, P]
    conv: jnp.ndarray  # [B, d_conv_in, K-1]  (last K-1 inputs of the causal conv)


def init_mamba2(key, d_model: int, *, d_inner: int, d_state: int, head_dim: int,
                d_conv: int, dtype):
    h = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state  # x, B, C go through the conv
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner + 2 * d_state + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_dim, d_conv)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 at init
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype),
    }


def _split_proj(p, x, d_inner, d_state, h, executor=None, site_name=None):
    zxbcdt = constrain(site_linear(executor, site_name, p["in_proj"], x),
                       "batch", None, None)
    z, xc, b_in, c_in, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state], axis=-1
    )
    return z, xc, b_in, c_in, dt


def _causal_conv(xbc, w, b, prev=None):
    """Depthwise causal conv over time. xbc [B, S, Cd], w [Cd, K]."""
    k = w.shape[1]
    x = jnp.moveaxis(xbc, -1, 1)  # [B, Cd, S]
    if prev is None:
        x = jnp.pad(x, ((0, 0), (0, 0), (k - 1, 0)))
    else:
        x = jnp.concatenate([prev.astype(x.dtype), x], axis=-1)
    out = jax.lax.conv_general_dilated(
        x[:, :, None, :], w[:, None, None, :], (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=w.shape[0],
    )[:, :, 0, :]
    out = out + b[None, :, None]
    return jnp.moveaxis(out, 1, -1)  # [B, S', Cd]


def mamba2_prefill(p, x, *, d_inner: int, d_state: int, head_dim: int, d_conv: int,
                   chunk: int = 256, unroll_chunks: bool = False):
    """x [B, S, d_model] -> (y [B, S, d_model], final Mamba2State)."""
    b, s, _ = x.shape
    h = d_inner // head_dim
    n, pdim = d_state, head_dim
    z, xc, b_in, c_in, dt = _split_proj(p, x, d_inner, d_state, h)
    conv_in = jnp.concatenate([xc, b_in, c_in], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, b_in, c_in = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["A_log"])  # [H]
    loga = dt * a[None, None, :]  # log decay (negative)  [B,S,H]
    xh = xs.reshape(b, s, h, pdim).astype(jnp.float32) * dt[..., None]  # dt folded in
    bh = b_in.astype(jnp.float32)  # [B,S,N] (n_groups=1, broadcast over heads)
    ch = c_in.astype(jnp.float32)

    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    xh, bh, ch, loga = (t.reshape(b, nc, q, *t.shape[2:]) for t in (xh, bh, ch, loga))

    lcum = jnp.cumsum(loga, axis=2)  # [B,nc,q,H]
    ltot = lcum[:, :, -1]  # [B,nc,H]

    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_math(xc_, bc_, cc_, lc_, state):
        # intra: y[t] = sum_{s<=t} (C_t.B_s) exp(l_t - l_s) x_s
        cb = jnp.einsum("btn,bsn->bts", cc_, bc_)  # [B,q,q]
        dec = jnp.exp(lc_[:, :, None, :] - lc_[:, None, :, :])  # [B,t,s,H]
        dec = jnp.where(mask[None, :, :, None], dec, 0.0)
        y = jnp.einsum("bts,btsh,bshp->bthp", cb, dec, xc_)
        # inter: y[t] += C_t . state * exp(l_t)
        y = y + jnp.einsum("btn,bhnp,bth->bthp", cc_, state, jnp.exp(lc_))
        # state' = exp(l_q) state + sum_s exp(l_q - l_s) B_s x_s
        ltot_ = lc_[:, -1]  # [B,H]
        snew = jnp.einsum("bsn,bshp,bsh->bhnp", bc_, xc_, jnp.exp(ltot_[:, None] - lc_))
        state = state * jnp.exp(ltot_)[:, :, None, None] + snew
        return y, state

    state0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    if unroll_chunks:
        state = state0
        ys = []
        for i in range(nc):
            y, state = chunk_math(xh[:, i], bh[:, i], ch[:, i], lcum[:, i], state)
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        def body(state, args):
            y, state = chunk_math(*args, state)
            return state, y

        state, y = jax.lax.scan(
            body, state0,
            (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(bh, 1, 0), jnp.moveaxis(ch, 1, 0),
             jnp.moveaxis(lcum, 1, 0)),
        )
        y = jnp.moveaxis(y, 0, 1)

    y = y.reshape(b, s, h, pdim) + p["D"][None, None, :, None] * xs.reshape(b, s, h, pdim)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    conv_tail = jnp.moveaxis(conv_in[:, s - (d_conv - 1):], 1, 2) if s >= d_conv - 1 else \
        jnp.pad(jnp.moveaxis(conv_in, 1, 2), ((0, 0), (0, 0), (d_conv - 1 - s, 0)))
    return linear(p["out_proj"], y), Mamba2State(ssm=state, conv=conv_tail)


def mamba2_decode(p, x, state: Mamba2State, *, d_inner: int, d_state: int,
                  head_dim: int, d_conv: int, executor=None,
                  site: str | None = None):
    """One-token step. x [B, 1, d_model] -> (y [B, 1, d_model], new state).

    ``executor``/``site``: in/out projections route through the compressed
    executor's fused chains (sites ``site.format("in_proj"/"out_proj")``)."""
    b = x.shape[0]
    h = d_inner // head_dim
    sn = site_fmt(site)
    z, xc, b_in, c_in, dt = _split_proj(p, x, d_inner, d_state, h,
                                        executor=executor,
                                        site_name=sn("in_proj"))
    conv_in = jnp.concatenate([xc, b_in, c_in], axis=-1)  # [B,1,Cd]
    win = jnp.concatenate([state.conv, jnp.moveaxis(conv_in, 1, 2)], axis=-1)  # [B,Cd,K]
    conv_out = jnp.einsum("bck,ck->bc", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))[:, None, :]
    xs, b_i, c_i = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(dtv * (-jnp.exp(p["A_log"])))  # [B,H]
    xhp = xs[:, 0].reshape(b, h, head_dim).astype(jnp.float32) * dtv[..., None]
    ssm = state.ssm * a[:, :, None, None] + jnp.einsum("bn,bhp->bhnp", b_i[:, 0], xhp)
    y = jnp.einsum("bn,bhnp->bhp", c_i[:, 0], ssm)
    y = y + p["D"][None, :, None] * xs[:, 0].reshape(b, h, head_dim)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return site_linear(executor, sn("out_proj"), p["out_proj"], y), \
        Mamba2State(ssm=ssm, conv=win[:, :, 1:])
