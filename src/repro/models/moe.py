"""Mixture-of-experts FFN with capacity-bounded scatter dispatch (GShard-style).

Tokens are routed top-k, assigned a rank within their expert's queue, and
scattered into a [E, C, d] buffer (mode='drop' beyond capacity) so expert
computation is a dense batched einsum — EP-shardable over the expert axis and
faithful to the active-FLOP count (6 * N_active * D), unlike soft dispatch.

Shared (always-on) experts are folded into one SwiGLU with concatenated ff
(mathematically identical: the down projection is linear).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed.act_shard import constrain, get_mesh

from .layers import dense_init, site_linear, site_linear_group, swiglu

__all__ = ["init_moe", "moe_ffn", "router_aux_losses"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int, dtype):
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d_model).astype(jnp.float32)
    p = {
        "router": (jax.random.truncated_normal(ks[0], -2, 2, (d_model, n_experts)) * scale
                   ).astype(jnp.float32),  # router stays f32 for stable top-k
        "gate": (jax.random.truncated_normal(ks[1], -2, 2, (n_experts, d_model, d_ff)) * scale).astype(dtype),
        "up": (jax.random.truncated_normal(ks[2], -2, 2, (n_experts, d_model, d_ff)) * scale).astype(dtype),
        "down": (jax.random.truncated_normal(ks[3], -2, 2, (n_experts, d_ff, d_model))
                 * (1.0 / jnp.sqrt(d_ff).astype(jnp.float32))).astype(dtype),
    }
    if n_shared > 0:
        p["shared"] = {
            "gate": dense_init(jax.random.fold_in(ks[4], 0), d_model, n_shared * d_ff, dtype),
            "up": dense_init(jax.random.fold_in(ks[4], 1), d_model, n_shared * d_ff, dtype),
            "down": dense_init(jax.random.fold_in(ks[4], 2), n_shared * d_ff, d_model, dtype),
        }
    return p


def moe_ffn(p, x, *, n_experts: int, top_k: int, capacity_factor: float = 1.25,
            norm_topk: bool = True, min_capacity: int = 4, executor=None,
            site_tag: str | None = None):
    """x [B, S, d] -> (y [B, S, d], aux dict with router stats).

    ``executor``/``site_tag`` (compressed serving): after the capacity-bounded
    top-k dispatch, each projection's per-expert matmuls run as ONE grouped
    fused launch over all experts (sites ``moe.{proj}.{site_tag}.e{e}``) —
    every expert applies its own LCC chain to its own token buffer in a single
    Pallas dispatch.  Shared experts route through their own sites
    (``moe.shared.{proj}.{site_tag}``).  Routing/dispatch math is unchanged.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, top_k)  # [T, k]
    if norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(max(min_capacity, round(t * top_k * capacity_factor / n_experts)))
    # rank of each (token, slot) within its expert queue
    sel_oh = jax.nn.one_hot(sel, n_experts, dtype=jnp.int32)  # [T, k, E]
    flat_oh = sel_oh.reshape(t * top_k, n_experts)
    ranks = (jnp.cumsum(flat_oh, axis=0) - flat_oh).reshape(t, top_k, n_experts)
    rank = jnp.sum(ranks * sel_oh, axis=-1)  # [T, k]

    keep = rank < cap
    slot = sel * cap + jnp.minimum(rank, cap - 1)  # [T, k] flat index into E*C
    slot = jnp.where(keep, slot, n_experts * cap)  # OOB => dropped by scatter

    buf = jnp.zeros((n_experts * cap, d), x.dtype)
    for j in range(top_k):  # k small static scatters of [T, d]
        buf = buf.at[slot[:, j]].add(xt, mode="drop")
    buf = constrain(buf.reshape(n_experts, cap, d), "model", None, None)

    def expert_mm(proj, z):
        """z [E, C, d_in] @ p[proj] [E, d_in, d_out] -> [E, C, d_out]; ONE
        grouped fused launch over all experts when the executor covers every
        ``moe.{proj}.{site_tag}.e{e}`` site, dense batched einsum otherwise."""
        fused = None
        if executor is not None and site_tag is not None:
            fused = executor.grouped(tuple(
                f"moe.{proj}.{site_tag}.e{e}" for e in range(n_experts)))
        if fused is None:
            return jnp.einsum("ecd,edf->ecf", z, p[proj])
        ys = fused([z[e].astype(jnp.float32).T for e in range(n_experts)])
        return jnp.stack([y.T for y in ys]).astype(z.dtype)

    plan = None
    if executor is not None and site_tag is not None:
        mp = getattr(executor, "moe_plan", None)
        if mp is not None:
            plan = mp(site_tag, n_experts=n_experts, d_model=d,
                      d_ff=p["gate"].shape[-1])
    if plan is not None:
        # layer plan: all experts' gate/up/SwiGLU/down in ONE launch,
        # replacing the three grouped expert_mm dispatches
        out_buf = constrain(plan(buf), "model", None, None
                            ).reshape(n_experts * cap, d)
    else:
        h_gate = expert_mm("gate", buf)
        h_up = expert_mm("up", buf)
        mesh = get_mesh()
        ep = (mesh is not None and "model" in mesh.shape
              and n_experts % mesh.shape["model"] == 0 and n_experts >= mesh.shape["model"])
        if ep:  # EP: experts across "model"
            h_gate = constrain(h_gate, "model", None, None)
            h_up = constrain(h_up, "model", None, None)
        else:  # TP within expert: shard expert d_ff
            h_gate = constrain(h_gate, None, None, "model")
            h_up = constrain(h_up, None, None, "model")
        h = jax.nn.silu(h_gate) * h_up
        out_buf = constrain(expert_mm("down", h),
                            "model", None, None).reshape(n_experts * cap, d)

    y = jnp.zeros((t, d), x.dtype)
    for j in range(top_k):
        gathered = jnp.take(out_buf, jnp.minimum(slot[:, j], n_experts * cap - 1), axis=0)
        w = (gates[:, j] * keep[:, j]).astype(x.dtype)[:, None]
        y = y + w * gathered

    y = constrain(y, "batch", None)
    if "shared" in p:
        if executor is not None and site_tag is not None:
            sp = p["shared"]
            sg, su = site_linear_group(
                executor, (f"moe.shared.gate.{site_tag}",
                           f"moe.shared.up.{site_tag}"),
                (sp["gate"], sp["up"]), xt)
            # identical TP annotations to the dense-path swiglu
            sg = constrain(sg, "batch", None, "model")
            su = constrain(su, "batch", None, "model")
            y = y + constrain(
                site_linear(executor, f"moe.shared.down.{site_tag}",
                            sp["down"], jax.nn.silu(sg) * su),
                "batch", None, None)
        else:
            y = y + swiglu(p["shared"], xt)

    aux = {"router_probs_mean": probs.mean(0), "dropped_frac":
           1.0 - keep.mean(), "sel": sel}
    return y.reshape(b, s, d), aux


def moe_ffn_manual(p, x, *, n_experts: int, top_k: int,
                   capacity_factor: float = 1.25, norm_topk: bool = True,
                   min_capacity: int = 4, mesh=None):
    """MoE block as a fully-manual shard_map: local dispatch, EP or
    TP-within-expert compute, one psum over "model" for combine.

    Rationale (measured, EXPERIMENTS.md §Perf iteration 3): under pure GSPMD
    the capacity scatter/gather cannot be partitioned along tokens, so XLA
    replicates the [E*C, d] buffer and all-reduces hundreds of GB per layer.
    Making dispatch local to each (pod, data) shard removes those collectives;
    the surviving communication is the combine psum over "model" (plus the
    FSDP weight gathers at the shard_map boundary).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        mesh = get_mesh()
    b, s, d = x.shape
    t = b * s
    if mesh is None:
        return moe_ffn(p, x, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor, norm_topk=norm_topk,
                       min_capacity=min_capacity)
    msize = mesh.shape.get("model", 1)
    token_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tshard = int(np.prod([mesh.shape[a] for a in token_axes])) if token_axes else 1
    if t % max(tshard, 1):
        return moe_ffn(p, x, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor, norm_topk=norm_topk,
                       min_capacity=min_capacity)
    ep = n_experts % msize == 0 and n_experts >= msize
    e_loc = n_experts // msize if ep else n_experts

    def route_local(xt, router):
        tl = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, sel = jax.lax.top_k(probs, top_k)
        if norm_topk:
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        cap = int(max(min_capacity, round(t // max(tshard, 1) * top_k
                                          * capacity_factor / n_experts)))
        sel_oh = jax.nn.one_hot(sel, n_experts, dtype=jnp.int32)
        flat_oh = sel_oh.reshape(tl * top_k, n_experts)
        ranks = (jnp.cumsum(flat_oh, axis=0) - flat_oh).reshape(tl, top_k, n_experts)
        rank = jnp.sum(ranks * sel_oh, axis=-1)
        keep = rank < cap
        return gates, sel, rank, keep, cap

    def body(xt, router, gate, up, down, sg, su, sd):
        # xt [T_loc, d]; weight args are this shard's slices (EP: expert
        # slice; TP: d_ff slice). Local except the final psum over "model".
        gates, sel, rank, keep, cap = route_local(xt, router)
        slot = sel * cap + jnp.minimum(rank, cap - 1)
        slot = jnp.where(keep, slot, n_experts * cap)
        buf = jnp.zeros((n_experts * cap, d), xt.dtype)
        for j in range(top_k):
            buf = buf.at[slot[:, j]].add(xt, mode="drop")
        buf = buf.reshape(n_experts, cap, d)

        if ep:
            midx = jax.lax.axis_index("model")
            my = jax.lax.dynamic_slice_in_dim(buf, midx * e_loc, e_loc, 0)
        else:
            my = buf
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", my, gate)) * \
            jnp.einsum("ecd,edf->ecf", my, up)
        out = jnp.einsum("ecf,efd->ecd", h, down)

        y = jnp.zeros((xt.shape[0], d), jnp.float32)
        if ep:
            flat = out.reshape(e_loc * cap, d)
            for j in range(top_k):
                e_l = sel[:, j] - midx * e_loc
                owned = (e_l >= 0) & (e_l < e_loc) & keep[:, j]
                idx = jnp.clip(e_l * cap + jnp.minimum(rank[:, j], cap - 1),
                               0, e_loc * cap - 1)
                g = jnp.take(flat, idx, axis=0).astype(jnp.float32)
                y = y + jnp.where(owned[:, None], gates[:, j:j + 1] * g, 0.0)
        else:
            flat = out.reshape(n_experts * cap, d)
            for j in range(top_k):
                idx = jnp.minimum(slot[:, j], n_experts * cap - 1)
                g = jnp.take(flat, idx, axis=0).astype(jnp.float32)
                y = y + jnp.where(keep[:, j:j + 1], gates[:, j:j + 1] * g, 0.0)
        if sg is not None:  # shared experts, TP over their d_ff
            hs = jax.nn.silu(xt @ sg) * (xt @ su)
            y = y + (hs @ sd).astype(jnp.float32)
        y = jax.lax.psum(y, "model")
        return y.astype(xt.dtype)

    xt = x.reshape(t, d)
    tok_spec = P(token_axes if len(token_axes) > 1 else
                 (token_axes[0] if token_axes else None))
    gate_spec = P("model", None, None) if ep else P(None, None, "model")
    down_spec = P("model", None, None) if ep else P(None, "model", None)
    has_shared = "shared" in p
    if has_shared:
        extra = (p["shared"]["gate"]["w"], p["shared"]["up"]["w"],
                 p["shared"]["down"]["w"])
        extra_specs = (P(None, "model"), P(None, "model"), P("model", None))
    else:
        dummy = jnp.zeros((1, 1), x.dtype)
        extra = (dummy, dummy, dummy)
        extra_specs = (P(), P(), P())

        def body_noshared(xt, router, gate, up, down, _sg, _su, _sd):
            return body(xt, router, gate, up, down, None, None, None)
        body_fn = body_noshared
    body_fn = body if has_shared else body_noshared
    fn = compat.shard_map(
        body_fn, mesh=mesh,
        in_specs=(tok_spec, P(), gate_spec, gate_spec, down_spec) + extra_specs,
        out_specs=tok_spec,
        check_vma=False)
    y = fn(xt, p["router"], p["gate"], p["up"], p["down"], *extra)
    aux = {"router_probs_mean": jnp.zeros((n_experts,), jnp.float32),
           "dropped_frac": jnp.zeros(()), "sel": None}
    return y.reshape(b, s, d), aux


def router_aux_losses(aux, n_experts: int):
    """Load-balance loss (Switch-style) + router z-ish entropy penalty."""
    pm = aux["router_probs_mean"]  # [E]
    sel = aux["sel"]  # [T, k]
    frac = jnp.bincount(sel.reshape(-1), length=n_experts).astype(jnp.float32)
    frac = frac / jnp.maximum(frac.sum(), 1.0)
    lb = n_experts * jnp.sum(frac * pm)
    return {"load_balance": lb, "dropped_frac": aux["dropped_frac"]}
