"""Attention: GQA (full / sliding-window / bidirectional / cross) and MLA.

Prefill uses query-chunked attention (memory O(S * chunk) instead of O(S^2))
with two lowering modes:
  * ``unroll_chunks=False`` — lax.scan over chunks (compact HLO; production).
  * ``unroll_chunks=True``  — static python loop; used by the roofline pass
    (while-bodies are undercounted by HLO cost analysis, see DESIGN.md Sec. 6)
    and enables *causal chunk skipping*: a query chunk statically attends only
    to keys at positions <= its end, which removes the upper-triangle FLOPs —
    one of the beyond-paper optimizations measured in EXPERIMENTS.md §Perf.

KV caches are plain pytrees. Sliding-window attention uses a ring buffer of
size ``window`` so the 500k-token decode cell runs with bounded memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.act_shard import constrain

from .layers import (apply_mrope, apply_rope, dense_init, linear, site_fmt,
                     site_linear, site_linear_group)

__all__ = [
    "AttnParams",
    "init_attention",
    "attention_prefill",
    "attention_decode",
    "attention_extend",
    "KVCache",
    "PagedKVCache",
    "init_kv_cache",
    "init_mla",
    "mla_prefill",
    "mla_decode",
    "mla_extend",
    "MLACache",
    "PagedMLACache",
]

_NEG = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, Smax, Hkv, Dh]  (ring buffer if windowed)
    v: jnp.ndarray  # [B, Smax, Hkv, Dh]
    kpos: jnp.ndarray  # [B, Smax] absolute positions (-1 = empty)


class PagedKVCache(NamedTuple):
    """Paged KV: one block pool per layer plus per-row block tables.

    ``k``/``v`` are the pool slice for this layer; ``tbl[b, j]`` names the
    pool block backing row ``b``'s logical blocks (0 = the reserved null
    block — unallocated, masked out via ``kpos == -1``).  The logical view
    (``tbl`` gathered and flattened) has exactly the contiguous cache's
    layout, so attention math — and its numerics — are unchanged."""
    k: jnp.ndarray  # [Nb, bs, Hkv, Dh] block pool (this layer)
    v: jnp.ndarray  # [Nb, bs, Hkv, Dh]
    kpos: jnp.ndarray  # [B, S] logical positions (-1 = empty), S = mb * bs
    tbl: jnp.ndarray  # [B, mb] int32 block ids


class PagedMLACache(NamedTuple):
    c_kv: jnp.ndarray  # [Nb, bs, dc] latent block pool (this layer)
    k_rope: jnp.ndarray  # [Nb, bs, Dr]
    kpos: jnp.ndarray  # [B, S]
    tbl: jnp.ndarray  # [B, mb]


def paged_view(pool: jnp.ndarray, tbl: jnp.ndarray) -> jnp.ndarray:
    """Gather a pool ``[Nb, bs, ...]`` through block tables ``[B, mb]`` into
    the contiguous logical view ``[B, mb * bs, ...]``."""
    b, mb = tbl.shape
    bs = pool.shape[1]
    return pool[tbl].reshape(b, mb * bs, *pool.shape[2:])


def _paged_scatter(pool: jnp.ndarray, tbl: jnp.ndarray, slot: jnp.ndarray,
                   vals: jnp.ndarray) -> jnp.ndarray:
    """Write one token per row into the pool at logical view position
    ``slot`` ([B], -1 = no write -> routed to the null block)."""
    bs = pool.shape[1]
    w = jnp.maximum(slot, 0)
    bidx = jnp.take_along_axis(tbl, (w // bs)[:, None], axis=1)[:, 0]
    bidx = jnp.where(slot >= 0, bidx, 0)  # inactive rows sink to null block 0
    return pool.at[bidx, w % bs].set(vals)


def init_kv_cache(batch: int, smax: int, n_kv: int, head_dim: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, smax, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, smax, n_kv, head_dim), dtype),
        kpos=jnp.full((batch, smax), -1, jnp.int32),
    )


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    return {
        "q": dense_init(ks[0], d_model, n_heads * head_dim, dtype, bias=qkv_bias),
        "k": dense_init(ks[1], d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "v": dense_init(ks[2], d_model, n_kv * head_dim, dtype, bias=qkv_bias),
        "o": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _project_qkv(p, x, n_heads, n_kv, head_dim):
    b, s, _ = x.shape
    q = linear(p["q"], x).reshape(b, s, n_heads, head_dim)
    k = linear(p["k"], x).reshape(b, s, n_kv, head_dim)
    v = linear(p["v"], x).reshape(b, s, n_kv, head_dim)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q [B,Sq,Hkv,G,D], k/v [B,Sk,Hkv,D], additive mask [B,1,1,Sq,Sk] or None."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if mask is not None:
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out


def attention_prefill(
    p, x, positions, *, n_heads: int, n_kv: int, head_dim: int,
    causal: bool = True, window: int | None = None,
    rope_theta: float | None = 10000.0, mrope_sections=None, mrope_positions=None,
    q_chunk: int = 1024, unroll_chunks: bool = False, causal_skip: bool = False,
    kv_x: jnp.ndarray | None = None,
):
    """Returns (out [B,S,d_model], k, v). ``kv_x`` switches to cross-attention."""
    b, s, _ = x.shape
    g = n_heads // n_kv
    q = constrain(linear(p["q"], x).reshape(b, s, n_heads, head_dim),
                  "batch", None, "model", None)
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    k = constrain(linear(p["k"], src).reshape(b, sk, n_kv, head_dim),
                  "batch", None, "model", None)
    v = constrain(linear(p["v"], src).reshape(b, sk, n_kv, head_dim),
                  "batch", None, "model", None)

    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections)
        k = apply_mrope(k, mrope_positions, mrope_sections)
    elif rope_theta is not None:
        kpos = positions if kv_x is None else jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, kpos, rope_theta)

    qg = q.reshape(b, s, n_kv, g, head_dim)
    kpos_all = jnp.arange(sk)

    def chunk_out(q_c, qpos_c, k_c, v_c, kpos_c):
        if causal and kv_x is None:
            m = (kpos_c[None, :] <= qpos_c[:, None]).astype(jnp.float32)
            if window is not None:
                m = m * (kpos_c[None, :] > qpos_c[:, None] - window)
            mask = jnp.where(m > 0, 0.0, _NEG)[None, None, None]
        else:
            mask = None
        return _sdpa(q_c, k_c, v_c, mask)

    n_chunks = max(1, s // q_chunk) if s % q_chunk == 0 else 1
    if n_chunks == 1:
        out = chunk_out(qg, positions[0], k, v, kpos_all)
    elif unroll_chunks:
        outs = []
        cq = s // n_chunks
        for i in range(n_chunks):
            q_c = qg[:, i * cq:(i + 1) * cq]
            qpos_c = positions[0, i * cq:(i + 1) * cq]
            # static causal/window chunk skipping: only keys that can be seen
            lo, hi = 0, sk
            if causal_skip and causal and kv_x is None:
                hi = min(sk, (i + 1) * cq)
                if window is not None:
                    lo = max(0, i * cq - int(window))
            outs.append(chunk_out(q_c, qpos_c, k[:, lo:hi], v[:, lo:hi], kpos_all[lo:hi]))
        out = jnp.concatenate(outs, axis=1)
    else:
        cq = s // n_chunks
        qg_r = qg.reshape(b, n_chunks, cq, n_kv, g, head_dim)
        qpos_r = positions[0].reshape(n_chunks, cq)

        def body(_, qc):
            q_c, qpos_c = qc
            return None, chunk_out(jnp.moveaxis(q_c, 0, 0), qpos_c, k, v, kpos_all)

        _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qg_r, 1, 0), qpos_r))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_kv, g, head_dim)
        out = out.reshape(b, s, n_heads * head_dim)
        y = constrain(linear(p["o"], out.astype(x.dtype)), "batch", None, None)
        return y, k, v

    out = out.reshape(b, s, n_heads * head_dim)
    y = constrain(linear(p["o"], out.astype(x.dtype)), "batch", None, None)
    return y, k, v


def attention_decode(
    p, x, cache: KVCache, pos, *, n_heads: int, n_kv: int, head_dim: int,
    window: int | None = None, rope_theta: float | None = 10000.0,
    mrope_sections=None, mrope_positions=None, cross: bool = False,
    executor=None, site: str | None = None,
):
    """One-token decode. x [B,1,d]; pos [B] absolute position of this token.

    Returns (out [B,1,d], new_cache). With ``window`` the cache is a ring
    buffer (slot = pos % window). ``cross=True`` reads a static cross-attention
    cache (no update, no causal mask).

    ``executor``/``site`` (compressed serving): q/k/v/o route through the
    executor's fused LCC kernels — q/k/v as ONE grouped launch (they share the
    input) — for sites named ``site.format(proj)``; uncovered sites stay
    dense.

    ``cache`` may be a :class:`PagedKVCache`: keys/values then live in a block
    pool indexed through per-row block tables.  The gathered logical view has
    the contiguous layout (same positions, same mask math), and the new token
    is additionally scattered into its pool block so the pool — not the view —
    is the carried state."""
    b = x.shape[0]
    paged = isinstance(cache, PagedKVCache)
    sn = site_fmt(site)
    if cross:
        q_raw = site_linear(executor, sn("q"), p["q"], x)
    else:
        q_raw, k_raw, v_raw = site_linear_group(
            executor, (sn("q"), sn("k"), sn("v")),
            (p["q"], p["k"], p["v"]), x)
    q = constrain(q_raw.reshape(b, 1, n_heads, head_dim),
                  "batch", None, "model", None)
    if mrope_sections is not None:
        q = apply_mrope(q, mrope_positions, mrope_sections)
    elif rope_theta is not None:
        q = apply_rope(q, pos[:, None], rope_theta)

    if cross:
        new_cache = cache
        k, v, kpos = cache.k, cache.v, cache.kpos
    else:
        k_new = k_raw.reshape(b, 1, n_kv, head_dim)
        v_new = v_raw.reshape(b, 1, n_kv, head_dim)
        if rope_theta is not None and mrope_sections is None:
            k_new = apply_rope(k_new, pos[:, None], rope_theta)
        elif mrope_sections is not None:
            k_new = apply_mrope(k_new, mrope_positions, mrope_sections)
        k_cur = paged_view(cache.k, cache.tbl) if paged else cache.k
        v_cur = paged_view(cache.v, cache.tbl) if paged else cache.v
        smax = k_cur.shape[1]
        # negative pos (serving's inactive-slot sentinel) must stay out of the
        # ring too: plain pos would wrap -1 % smax onto a live cache entry
        slot = jnp.where(pos >= 0, pos % smax, -1) if window is not None else pos
        onehot = jax.nn.one_hot(slot, smax, dtype=k_cur.dtype)  # [B, Smax]
        k = k_cur * (1 - onehot)[..., None, None] + onehot[..., None, None] * k_new
        v = v_cur * (1 - onehot)[..., None, None] + onehot[..., None, None] * v_new
        kpos = jnp.where(onehot > 0, pos[:, None], cache.kpos)
        if paged:
            new_cache = PagedKVCache(
                k=_paged_scatter(cache.k, cache.tbl, slot, k_new[:, 0]),
                v=_paged_scatter(cache.v, cache.tbl, slot, v_new[:, 0]),
                kpos=kpos, tbl=cache.tbl)
        else:
            new_cache = KVCache(k=k, v=v, kpos=kpos)
    g = n_heads // n_kv
    qg = q.reshape(b, 1, n_kv, g, head_dim)
    if cross:
        mask = None
    else:
        valid = (kpos >= 0) & (kpos <= pos[:, None])
        if window is not None:
            valid = valid & (kpos > (pos[:, None] - window))
        mask = jnp.where(valid, 0.0, _NEG)[:, None, None, None, :]  # [B,1,1,1,Smax]
    out = _sdpa(qg, k, v, mask)
    out = out.reshape(b, 1, n_heads * head_dim)
    return site_linear(executor, sn("o"), p["o"], out.astype(x.dtype)), new_cache


def attention_extend(p, x, positions, past_k, past_v, past_kpos, *,
                     n_heads: int, n_kv: int, head_dim: int,
                     rope_theta: float | None = 10000.0):
    """Prefill continuation against a resident KV prefix (prefix-cache hit).

    ``x`` [B,T,d] are the unmatched tail tokens at absolute ``positions``
    [B,T]; ``past_k``/``past_v`` [B,C,Hkv,Dh] is the gathered prefix (already
    rotary-encoded at its own positions, exactly as the pool stores it) with
    validity mask ``past_kpos`` [B,C] (-1 = padding).  Returns
    ``(out [B,T,d], k_tail, v_tail)`` — only the tail K/V, for scatter into
    freshly allocated blocks.  Causal, non-windowed."""
    b, t, _ = x.shape
    g = n_heads // n_kv
    q = linear(p["q"], x).reshape(b, t, n_heads, head_dim)
    k_t = linear(p["k"], x).reshape(b, t, n_kv, head_dim)
    v_t = linear(p["v"], x).reshape(b, t, n_kv, head_dim)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k_t = apply_rope(k_t, positions, rope_theta)
    k = jnp.concatenate([past_k, k_t], axis=1)
    v = jnp.concatenate([past_v, v_t], axis=1)
    kpos = jnp.concatenate([past_kpos, positions], axis=1)  # [B, C+T]
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= positions[:, :, None])
    mask = jnp.where(valid, 0.0, _NEG)[:, None, None]  # [B,1,1,T,C+T]
    qg = q.reshape(b, t, n_kv, g, head_dim)
    out = _sdpa(qg, k, v, mask).reshape(b, t, n_heads * head_dim)
    return linear(p["o"], out.astype(x.dtype)), k_t, v_t


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV cache
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    c_kv: jnp.ndarray  # [B, Smax, dc]     compressed KV latents
    k_rope: jnp.ndarray  # [B, Smax, Dr]   shared rotary key branch
    kpos: jnp.ndarray  # [B, Smax]


def init_mla(key, d_model: int, n_heads: int, *, kv_lora: int, qk_nope: int,
             qk_rope: int, v_dim: int, dtype):
    ks = jax.random.split(key, 6)
    return {
        "q": dense_init(ks[0], d_model, n_heads * (qk_nope + qk_rope), dtype),
        "dkv": dense_init(ks[1], d_model, kv_lora, dtype),
        "kr": dense_init(ks[2], d_model, qk_rope, dtype),
        "uk": dense_init(ks[3], kv_lora, n_heads * qk_nope, dtype),
        "uv": dense_init(ks[4], kv_lora, n_heads * v_dim, dtype),
        "o": dense_init(ks[5], n_heads * v_dim, d_model, dtype),
    }


def _mla_qkv(p, x, c_kv, k_rope_src, positions, kpositions, n_heads, qk_nope, qk_rope, v_dim,
             rope_theta, executor=None, site=None):
    b, s, _ = x.shape
    sk = c_kv.shape[1]
    sn = site_fmt(site)
    q = site_linear(executor, sn("q"), p["q"], x).reshape(
        b, s, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    # uk/uv share the latent-cache input: one grouped launch when compressed
    uk, uv = site_linear_group(executor, (sn("uk"), sn("uv")),
                               (p["uk"], p["uv"]), c_kv)
    k_nope = constrain(uk.reshape(b, sk, n_heads, qk_nope),
                       "batch", None, "model", None)
    v = constrain(uv.reshape(b, sk, n_heads, v_dim),
                  "batch", None, "model", None)
    k_rope = apply_rope(k_rope_src[:, :, None, :], kpositions, rope_theta)  # [B,Sk,1,Dr]
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, sk, n_heads, qk_rope))], axis=-1)
    return q_full, k_full, v


def mla_prefill(p, x, positions, *, n_heads, kv_lora, qk_nope, qk_rope, v_dim,
                rope_theta=10000.0, q_chunk: int = 1024, unroll_chunks: bool = False,
                causal_skip: bool = False):
    b, s, _ = x.shape
    c_kv = linear(p["dkv"], x)  # [B,S,dc]
    k_rope_src = linear(p["kr"], x)  # [B,S,Dr]
    q, k, v = _mla_qkv(p, x, c_kv, k_rope_src, positions, positions, n_heads,
                       qk_nope, qk_rope, v_dim, rope_theta)
    # MLA heads are full multi-head (n_kv == n_heads): reuse the GQA kernel path
    qg = q.reshape(b, s, n_heads, 1, qk_nope + qk_rope)
    kpos = jnp.arange(s)

    def chunk_out(q_c, qpos_c, k_c, v_c, kpos_c):
        m = (kpos_c[None, :] <= qpos_c[:, None])
        mask = jnp.where(m, 0.0, _NEG)[None, None, None]
        return _sdpa(q_c, k_c, v_c, mask)

    n_chunks = max(1, s // q_chunk) if s % q_chunk == 0 else 1
    if n_chunks == 1:
        out = chunk_out(qg, positions[0], k, v, kpos)
    elif unroll_chunks:
        cq = s // n_chunks
        outs = []
        for i in range(n_chunks):
            hi = (i + 1) * cq if causal_skip else s
            outs.append(chunk_out(qg[:, i * cq:(i + 1) * cq], positions[0, i * cq:(i + 1) * cq],
                                  k[:, :hi], v[:, :hi], kpos[:hi]))
        out = jnp.concatenate(outs, axis=1)
    else:
        cq = s // n_chunks
        qg_r = jnp.moveaxis(qg.reshape(b, n_chunks, cq, n_heads, 1, -1), 1, 0)
        qpos_r = positions[0].reshape(n_chunks, cq)

        def body(_, qc):
            q_c, qpos_c = qc
            return None, chunk_out(q_c, qpos_c, k, v, kpos)

        _, outs = jax.lax.scan(body, None, (qg_r, qpos_r))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, n_heads, 1, v_dim)

    out = out.reshape(b, s, n_heads * v_dim)
    return linear(p["o"], out.astype(x.dtype)), c_kv, k_rope_src


def mla_decode(p, x, cache: MLACache, pos, *, n_heads, kv_lora, qk_nope, qk_rope,
               v_dim, rope_theta=10000.0, executor=None, site: str | None = None):
    """``cache`` may be a :class:`PagedMLACache` — the latent/rope pools are
    gathered through the block tables into the contiguous logical view and the
    new latent is scattered back into its pool block (cf. attention_decode)."""
    b = x.shape[0]
    paged = isinstance(cache, PagedMLACache)
    sn = site_fmt(site)
    c_cur = paged_view(cache.c_kv, cache.tbl) if paged else cache.c_kv
    kr_cur = paged_view(cache.k_rope, cache.tbl) if paged else cache.k_rope
    smax = c_cur.shape[1]
    c_new, kr_new = site_linear_group(executor, (sn("dkv"), sn("kr")),
                                      (p["dkv"], p["kr"]), x)  # [B,1,dc/Dr]
    onehot = jax.nn.one_hot(pos, smax, dtype=c_cur.dtype)
    c_kv = c_cur * (1 - onehot)[..., None] + onehot[..., None] * c_new
    k_rope = kr_cur * (1 - onehot)[..., None] + onehot[..., None] * kr_new
    kpos = jnp.where(onehot > 0, pos[:, None], cache.kpos)
    if paged:
        new_cache = PagedMLACache(
            c_kv=_paged_scatter(cache.c_kv, cache.tbl, pos, c_new[:, 0]),
            k_rope=_paged_scatter(cache.k_rope, cache.tbl, pos, kr_new[:, 0]),
            kpos=kpos, tbl=cache.tbl)
    else:
        new_cache = MLACache(c_kv=c_kv, k_rope=k_rope, kpos=kpos)

    kpositions = jnp.maximum(kpos, 0)
    q, k, v = _mla_qkv(p, x, c_kv, k_rope, pos[:, None], kpositions, n_heads,
                       qk_nope, qk_rope, v_dim, rope_theta,
                       executor=executor, site=site)
    qg = q.reshape(b, 1, n_heads, 1, qk_nope + qk_rope)
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    mask = jnp.where(valid, 0.0, _NEG)[:, None, None, None, :]
    out = _sdpa(qg, k, v, mask)
    out = out.reshape(b, 1, n_heads * v_dim)
    return site_linear(executor, sn("o"), p["o"], out.astype(x.dtype)), new_cache


def mla_extend(p, x, positions, past_c, past_kr, past_kpos, *, n_heads,
               qk_nope, qk_rope, v_dim, rope_theta=10000.0):
    """MLA prefill continuation against a resident latent prefix.

    ``past_c`` [B,C,dc] / ``past_kr`` [B,C,Dr] are the gathered compressed-KV
    prefix (pool layout: pre-rope rotary branch, latent as stored), masked by
    ``past_kpos`` [B,C].  Returns ``(out, c_tail, kr_tail)``."""
    b, t, _ = x.shape
    c_t = linear(p["dkv"], x)  # [B,T,dc]
    kr_t = linear(p["kr"], x)  # [B,T,Dr]
    c_all = jnp.concatenate([past_c, c_t], axis=1)
    kr_all = jnp.concatenate([past_kr, kr_t], axis=1)
    kpos = jnp.concatenate([past_kpos, positions], axis=1)  # [B, C+T]
    q, k, v = _mla_qkv(p, x, c_all, kr_all, positions, jnp.maximum(kpos, 0),
                       n_heads, qk_nope, qk_rope, v_dim, rope_theta)
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= positions[:, :, None])
    mask = jnp.where(valid, 0.0, _NEG)[:, None, None]  # [B,1,1,T,C+T]
    qg = q.reshape(b, t, n_heads, 1, qk_nope + qk_rope)
    out = _sdpa(qg, k, v, mask).reshape(b, t, n_heads * v_dim)
    return linear(p["o"], out.astype(x.dtype)), c_t, kr_t
