"""Shared model primitives: norms, rotary embeddings, FFNs, initializers.

Pure-functional JAX; parameters are nested dicts of arrays. Compute dtype and
accumulation dtype are explicit everywhere (bf16 compute / f32 accumulation by
default, matching the TPU deployment target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.act_shard import constrain

__all__ = [
    "dense_init",
    "linear",
    "matvec_acts",
    "site_fmt",
    "site_linear",
    "site_linear_group",
    "rms_norm",
    "layer_norm",
    "non_parametric_ln",
    "apply_rope",
    "apply_mrope",
    "swiglu",
    "gelu_mlp",
]


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None,
               bias: bool = False):
    """Truncated-normal fan-in init (the standard for all projections here)."""
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    p = {"w": (jax.random.truncated_normal(key, -2, 2, (in_dim, out_dim)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def site_fmt(site):
    """Site-name binder for a format template like ``"attn.{}.l3"`` — returns
    a key -> site-name function (None template => every projection dense)."""
    return (lambda k: site.format(k)) if site is not None else (lambda k: None)


def matvec_acts(fn, x):
    """Run a features-major matvec (x [K, B] -> [N, B]) on [..., d] acts."""
    lead = x.shape[:-1]
    y = fn(x.reshape(-1, x.shape[-1]).astype(jnp.float32).T)
    return y.T.reshape(*lead, -1).astype(x.dtype)


def site_linear(executor, name, p, x):
    """``linear(p, x)``, routed through the compressed executor's fused-kernel
    matvec when it covers site ``name`` (dense weights otherwise).

    ``executor`` is duck-typed (see ``repro.serving.executor``): any object
    with ``matvec(name) -> callable | None``.  Bias (whisper projections) is
    applied on top of the compressed map — only ``w`` is a compressible site.
    """
    fn = executor.matvec(name) if executor is not None else None
    if fn is None:
        return linear(p, x)
    y = matvec_acts(fn, x)
    if "b" in p:
        y = y + p["b"]
    return y


def site_linear_group(executor, names, ps, xs):
    """Several projections of one *fused region* (same batch of activations:
    attention q/k/v, SwiGLU gate/up, RWKV r/k/v/g) in ONE grouped kernel
    launch when the executor covers every site; per-site
    :func:`site_linear` fallback otherwise.

    ``xs`` is either one shared activation array or a per-site list; returns
    the per-site outputs in order.
    """
    xlist = list(xs) if isinstance(xs, (list, tuple)) else [xs] * len(names)
    fused = executor.grouped(tuple(names)) if executor is not None else None
    if fused is None:
        return [site_linear(executor, n, p, x)
                for n, p, x in zip(names, ps, xlist)]
    lead = xlist[0].shape[:-1]
    flat = [x.reshape(-1, x.shape[-1]).astype(jnp.float32).T for x in xlist]
    ys = fused(flat)
    outs = []
    for y, p, x in zip(ys, ps, xlist):
        o = y.T.reshape(*lead, -1).astype(x.dtype)
        if "b" in p:
            o = o + p["b"]
        outs.append(o)
    return outs


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w + b


def non_parametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable affine parameters."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def _rope_sincos(positions, dim: int, theta: float):
    """positions [...]: sin/cos [..., dim/2] in f32."""
    half = dim // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x [B, S, H, D], positions [B, S] (absolute)."""
    d = x.shape[-1]
    sin, cos = _rope_sincos(positions, d, theta)  # [B, S, d/2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: tuple[int, int, int], theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE. x [B, S, H, D], positions3 [3, B, S]
    (temporal / height / width position ids); ``sections`` split D/2 rotary
    frequencies among the three axes (sum(sections) == D // 2)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    # choose which positional axis drives each frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions3.astype(jnp.float32)  # [3, B, S]
    pos_sel = pos[sec_id]  # [half, B, S] — gather the driving axis per band
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs  # [B, S, half]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(p, x):
    """SwiGLU FFN: down( silu(gate(x)) * up(x) ). TP: d_ff sharded on "model"."""
    g = constrain(linear(p["gate"], x), "batch", None, "model")
    u = constrain(linear(p["up"], x), "batch", None, "model")
    y = linear(p["down"], jax.nn.silu(g) * u)
    return constrain(y, "batch", None, None)


def gelu_mlp(p, x):
    """Two-layer GELU MLP (whisper-style). TP: d_ff sharded on "model"."""
    h = constrain(linear(p["fc1"], x), "batch", None, "model")
    return constrain(linear(p["fc2"], jax.nn.gelu(h)), "batch", None, None)
