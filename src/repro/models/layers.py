"""Shared model primitives: norms, rotary embeddings, FFNs, initializers.

Pure-functional JAX; parameters are nested dicts of arrays. Compute dtype and
accumulation dtype are explicit everywhere (bf16 compute / f32 accumulation by
default, matching the TPU deployment target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.act_shard import constrain

__all__ = [
    "dense_init",
    "linear",
    "rms_norm",
    "layer_norm",
    "non_parametric_ln",
    "apply_rope",
    "apply_mrope",
    "swiglu",
    "gelu_mlp",
]


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None,
               bias: bool = False):
    """Truncated-normal fan-in init (the standard for all projections here)."""
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    p = {"w": (jax.random.truncated_normal(key, -2, 2, (in_dim, out_dim)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w + b


def non_parametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable affine parameters."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def _rope_sincos(positions, dim: int, theta: float):
    """positions [...]: sin/cos [..., dim/2] in f32."""
    half = dim // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x [B, S, H, D], positions [B, S] (absolute)."""
    d = x.shape[-1]
    sin, cos = _rope_sincos(positions, d, theta)  # [B, S, d/2]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: tuple[int, int, int], theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE. x [B, S, H, D], positions3 [3, B, S]
    (temporal / height / width position ids); ``sections`` split D/2 rotary
    frequencies among the three axes (sum(sections) == D // 2)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    # choose which positional axis drives each frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions3.astype(jnp.float32)  # [3, B, S]
    pos_sel = pos[sec_id]  # [half, B, S] — gather the driving axis per band
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs  # [B, S, half]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(p, x):
    """SwiGLU FFN: down( silu(gate(x)) * up(x) ). TP: d_ff sharded on "model"."""
    g = constrain(linear(p["gate"], x), "batch", None, "model")
    u = constrain(linear(p["up"], x), "batch", None, "model")
    y = linear(p["down"], jax.nn.silu(g) * u)
    return constrain(y, "batch", None, None)


def gelu_mlp(p, x):
    """Two-layer GELU MLP (whisper-style). TP: d_ff sharded on "model"."""
    h = constrain(linear(p["fc1"], x), "batch", None, "model")
    return constrain(linear(p["fc2"], jax.nn.gelu(h)), "batch", None, None)
