"""Unified decoder backbone covering all assigned LM families.

Block layout per family:
  dense / vlm:   x += attn(norm(x));   x += swiglu(norm(x))
  moe:           x += attn|mla(norm(x)); x += moe(norm(x)) [+ shared experts]
  ssm (rwkv6):   x += timemix(norm(x)); x += channelmix(norm(x))
  hybrid(zamba): groups of ``hybrid_period`` mamba2 blocks, one *weight-shared*
                 attention+MLP block between groups (the zamba2 trick: depth
                 reuses one attention block's parameters).

Layers are scanned (stacked [L, ...] params) for compact HLO and FSDP-friendly
per-layer weight gathering; ``unroll`` switches to a static python loop for
the roofline cost pass.  Loss is sequence-chunked so [B, S, V] logits are never
materialized.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_shard import constrain

from .attention import (
    KVCache,
    MLACache,
    attention_decode,
    attention_prefill,
    init_attention,
    init_kv_cache,
    init_mla,
    mla_decode,
    mla_prefill,
)
from .layers import dense_init, linear, non_parametric_ln, rms_norm, swiglu
from .mamba2 import Mamba2State, init_mamba2, mamba2_decode, mamba2_prefill
from .moe import init_moe, moe_ffn, moe_ffn_manual
from .rwkv6 import (
    RWKV6State,
    init_rwkv6,
    init_rwkv6_channelmix,
    rwkv6_channelmix,
    rwkv6_timemix_decode,
    rwkv6_timemix_prefill,
)

__all__ = ["init_params", "forward", "decode_step", "init_decode_state", "loss_fn"]


def _scan(body, init, xs, unroll: bool):
    """lax.scan or a static python loop (roofline cost pass — while bodies are
    undercounted by HLO cost analysis, DESIGN.md Sec. 6)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def _norm(cfg: ArchConfig, p, x):
    if cfg.norm == "nonparam":
        return non_parametric_ln(x)
    return rms_norm(x, p)


def _norm_param(cfg: ArchConfig, d):
    # non-parametric LN keeps a (frozen, unused) scale so pytree structure is uniform
    return jnp.ones((d,), cfg.pdtype)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": _norm_param(cfg, cfg.d_model),
                         "ln2": _norm_param(cfg, cfg.d_model)}
    if cfg.family == "ssm":  # rwkv6
        p["tm"] = init_rwkv6(ks[0], cfg.d_model, head_dim=cfg.hd, dtype=cfg.pdtype)
        p["cm"] = init_rwkv6_channelmix(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype)
        return p
    if cfg.family == "hybrid":  # zamba2 mamba block (attention is shared, separate)
        p.pop("ln2")
        p["mamba"] = init_mamba2(ks[0], cfg.d_model, d_inner=cfg.ssm.d_inner,
                                 d_state=cfg.ssm.d_state, head_dim=cfg.ssm.head_dim,
                                 d_conv=cfg.ssm.d_conv, dtype=cfg.pdtype)
        return p
    if cfg.mla is not None:
        p["attn"] = init_mla(ks[0], cfg.d_model, cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                             qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                             v_dim=cfg.mla.v_dim, dtype=cfg.pdtype)
    else:
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.pdtype, qkv_bias=cfg.qkv_bias)
    if cfg.moe is not None:
        p["ffn"] = init_moe(ks[1], cfg.d_model, cfg.moe.d_ff_expert, cfg.moe.n_experts,
                            cfg.moe.n_shared, cfg.pdtype)
    else:
        p["ffn"] = {
            "gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "up": dense_init(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "down": dense_init(ks[3], cfg.d_ff, cfg.d_model, cfg.pdtype),
        }
    return p


def _init_shared_attn(key, cfg: ArchConfig):
    """Zamba2's weight-shared attention + MLP block."""
    ks = jax.random.split(key, 4)
    return {
        "ln1": _norm_param(cfg, cfg.d_model),
        "ln2": _norm_param(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.pdtype),
        "ffn": {
            "gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "up": dense_init(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "down": dense_init(ks[3], cfg.d_ff, cfg.d_model, cfg.pdtype),
        },
    }


def init_params(key, cfg: ArchConfig):
    k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    scale = cfg.d_model**-0.5
    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model)) * scale).astype(cfg.pdtype),
        "final_ln": _norm_param(cfg, cfg.d_model),
    }
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, cfg.pdtype)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(k_shared, cfg)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_fwd(cfg: ArchConfig, p, x, positions, *, unroll: bool, state=None,
               mrope_positions=None):
    """One block forward. Returns (x, per-layer state-out or aux)."""
    if cfg.family == "ssm":
        tm_in = _norm(cfg, p["ln1"], x)
        y, st = rwkv6_timemix_prefill(p["tm"], tm_in, head_dim=cfg.hd,
                                      chunk=cfg.ssm_chunk, unroll_chunks=unroll,
                                      state=None)
        x = x + y
        cm_in = _norm(cfg, p["ln2"], x)
        y, cm_last = rwkv6_channelmix(p["cm"], cm_in)
        x = x + y
        return x, RWKV6State(wkv=st.wkv, x_prev=st.x_prev), cm_last
    if cfg.family == "hybrid":
        y, st = mamba2_prefill(p["mamba"], _norm(cfg, p["ln1"], x),
                               d_inner=cfg.ssm.d_inner, d_state=cfg.ssm.d_state,
                               head_dim=cfg.ssm.head_dim, d_conv=cfg.ssm.d_conv,
                               chunk=cfg.ssm_chunk, unroll_chunks=unroll)
        return x + y, st, None
    # attention family
    attn_in = _norm(cfg, p["ln1"], x)
    if cfg.mla is not None:
        y, c_kv, k_rope = mla_prefill(p["attn"], attn_in, positions, n_heads=cfg.n_heads,
                                      kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
                                      qk_rope=cfg.mla.qk_rope, v_dim=cfg.mla.v_dim,
                                      rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                                      unroll_chunks=unroll,
                                      causal_skip=cfg.causal_chunk_skip)
        kv = (c_kv, k_rope)
    else:
        y, k, v = attention_prefill(
            p["attn"], attn_in, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, causal=True, window=cfg.attn_window,
            rope_theta=None if cfg.pos in ("none", "mrope") else cfg.rope_theta,
            mrope_sections=cfg.mrope_sections if cfg.pos == "mrope" else None,
            mrope_positions=mrope_positions, q_chunk=cfg.q_chunk, unroll_chunks=unroll,
            causal_skip=cfg.causal_chunk_skip)
        kv = (k, v)
    x = x + y
    ffn_in = _norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
        y, _aux = moe_fn(p["ffn"], ffn_in, n_experts=cfg.moe.n_experts,
                         top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
                         norm_topk=cfg.moe.norm_topk)
    else:
        y = swiglu(p["ffn"], ffn_in)
    return x + y, kv, None


def _shared_attn_fwd(cfg: ArchConfig, p, x, positions, *, unroll: bool):
    y, k, v = attention_prefill(p["attn"], _norm(cfg, p["ln1"], x), positions,
                                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                                causal=True, window=cfg.attn_window,
                                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                                unroll_chunks=unroll, causal_skip=cfg.causal_chunk_skip)
    x = x + y
    x = x + swiglu(p["ffn"], _norm(cfg, p["ln2"], x))
    return x, (k, v)


def forward(params, cfg: ArchConfig, *, tokens=None, embeds=None, positions=None,
            positions3=None, unroll: bool = False, collect_cache: bool = False):
    """Train/prefill forward -> (hidden [B,S,d], caches or None)."""
    if embeds is not None:
        x = embeds.astype(cfg.cdtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, "batch", None, None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.pos == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))

    blocks = params["blocks"]

    def one(x, bp):
        return _block_fwd(cfg, bp, x, positions, unroll=unroll,
                          mrope_positions=positions3)

    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        if unroll or collect_cache:  # python loop: roofline pass / serving prefill
            caches = {"mamba": [], "attn": []}
            li = 0
            for g in range(n_groups):
                for _ in range(period):
                    bp = jax.tree.map(lambda a: a[li], blocks)
                    x, st, _ = one(x, bp)
                    caches["mamba"].append(st)
                    li += 1
                x, kv = _shared_attn_fwd(cfg, params["shared_attn"], x, positions,
                                         unroll=unroll)
                caches["attn"].append(kv)
            for _ in range(tail):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x, st, _ = one(x, bp)
                caches["mamba"].append(st)
                li += 1
            cache_out = caches if collect_cache else None
        else:  # production path: scan over groups, inner scan over mamba layers
            main = jax.tree.map(
                lambda a: a[: n_groups * period].reshape(n_groups, period, *a.shape[1:]),
                blocks)

            def layer_body(x, bp):
                x, _st, _ = one(x, bp)
                return x, None

            def group_body(x, gp):
                x, _ = jax.lax.scan(layer_body, x, gp)
                x, _kv = _shared_attn_fwd(cfg, params["shared_attn"], x, positions,
                                          unroll=False)
                return x, None

            if cfg.remat:
                group_body = jax.checkpoint(group_body)
            x, _ = jax.lax.scan(group_body, x, main)
            if tail:
                tailb = jax.tree.map(lambda a: a[n_groups * period:], blocks)
                body = jax.checkpoint(layer_body) if cfg.remat else layer_body
                x, _ = jax.lax.scan(body, x, tailb)
            cache_out = None
    elif unroll:
        cache_list = []
        if collect_cache:
            for li in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x, c, _extra = one(x, bp)
                cache_list.append(c)
        else:
            xonly = lambda x, bp: one(x, bp)[0]  # noqa: E731
            fn = jax.checkpoint(xonly) if cfg.remat else xonly
            for li in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x = fn(x, bp)
        cache_out = cache_list if collect_cache else None
    else:
        def body(x, bp):
            x, c, _extra = one(x, bp)
            return x, c if collect_cache else None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, stacked = jax.lax.scan(body, x, blocks)
        cache_out = stacked if collect_cache else None

    x = _norm(cfg, params["final_ln"], x)
    return x, cache_out


def logits_from_hidden(params, cfg: ArchConfig, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return linear(params["lm_head"], h)


def loss_fn(params, cfg: ArchConfig, batch, *, unroll: bool = False,
            seq_chunk: int = 512):
    """Sequence-chunked cross-entropy; logits [B,S,V] never materialized."""
    h, _ = forward(params, cfg,
                   tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                   positions3=batch.get("positions3"), unroll=unroll)
    labels = batch["labels"]
    b, s = labels.shape
    c = min(seq_chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    hch = h.reshape(b, nc, c, cfg.d_model)
    lch = labels.reshape(b, nc, c)

    def chunk_loss(hc, lc):
        logits = logits_from_hidden(params, cfg, hc).astype(jnp.float32)  # [B,c,V]
        logits = constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    if unroll or nc == 1:
        tot = 0.0
        for i in range(nc):
            tot += chunk_loss(hch[:, i], lch[:, i])
    else:
        def body(acc, xs):
            hc, lc = xs
            return acc + chunk_loss(hc, lc), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                              (jnp.moveaxis(hch, 1, 0), jnp.moveaxis(lch, 1, 0)))
    return tot / (b * s)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, smax: int):
    """Abstract-init-friendly per-layer decode caches (call under eval_shape too)."""
    L = cfg.n_layers
    cd = cfg.cdtype
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.hd
        return {
            "wkv": jnp.zeros((L, batch, h, cfg.hd, cfg.hd), jnp.float32),
            "x_prev_tm": jnp.zeros((L, batch, cfg.d_model), cd),
            "x_prev_cm": jnp.zeros((L, batch, cfg.d_model), cd),
        }
    if cfg.family == "hybrid":
        hh = cfg.ssm.d_inner // cfg.ssm.head_dim
        n_attn = cfg.n_layers // cfg.hybrid_period
        conv_dim = cfg.ssm.d_inner + 2 * cfg.ssm.d_state
        return {
            "ssm": jnp.zeros((L, batch, hh, cfg.ssm.d_state, cfg.ssm.head_dim), jnp.float32),
            "conv": jnp.zeros((L, batch, conv_dim, cfg.ssm.d_conv - 1), cd),
            "attn_k": jnp.zeros((n_attn, batch, smax, cfg.n_kv_heads, cfg.hd), cd),
            "attn_v": jnp.zeros((n_attn, batch, smax, cfg.n_kv_heads, cfg.hd), cd),
            "attn_kpos": jnp.full((n_attn, batch, smax), -1, jnp.int32),
        }
    if cfg.mla is not None:
        return {
            "c_kv": jnp.zeros((L, batch, smax, cfg.mla.kv_lora), cd),
            "k_rope": jnp.zeros((L, batch, smax, cfg.mla.qk_rope), cd),
            "kpos": jnp.full((L, batch, smax), -1, jnp.int32),
        }
    w = cfg.attn_window
    eff = min(smax, w) if w is not None else smax
    return {
        "k": jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), cd),
        "v": jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), cd),
        "kpos": jnp.full((L, batch, eff), -1, jnp.int32),
    }


def _override_matvec(fn, x):
    """Run a features-major matvec (x [K, B] -> [N, B]) on [B, S, d] acts."""
    b, s, d = x.shape
    y = fn(x.reshape(b * s, d).astype(jnp.float32).T)
    return y.T.reshape(b, s, -1).astype(x.dtype)


def _ffn_with_overrides(overrides, li: int):
    """SwiGLU whose gate/up/down may be routed through compressed matvecs.

    ``overrides`` maps projection name -> per-layer list of callables (None
    entries fall back to the dense weight); the callables are the serving
    engine's fused-LCC kernels, so a compressed model's FFNs execute as
    shift-add chains *inside* the jitted decode step.
    """
    def proj(p, name, x):
        fns = overrides.get(name)
        fn = fns[li] if fns is not None and li < len(fns) else None
        if fn is None:
            return linear(p[name], x)
        return _override_matvec(fn, x)

    def ffn(p, x):
        g = constrain(proj(p, "gate", x), "batch", None, "model")
        u = constrain(proj(p, "up", x), "batch", None, "model")
        y = proj(p, "down", jax.nn.silu(g) * u)
        return constrain(y, "batch", None, None)

    return ffn


def decode_step(params, cfg: ArchConfig, state, token, pos, *, unroll: bool = False,
                matvec_overrides=None):
    """One decode step: (logits [B, V], new state). token [B,1], pos [B].

    ``matvec_overrides`` (compressed serving): ``{"gate"|"up"|"down":
    [callable|None per layer]}`` — those FFN projections run through the given
    features-major matvecs (the fused LCC kernel path) instead of the dense
    weights.  Only the dense-FFN attention families support overrides; the
    layer loop is unrolled so each layer can bind its own kernel buffers.
    """
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)
    blocks = params["blocks"]
    if matvec_overrides is not None and (
            cfg.family in ("ssm", "hybrid") or cfg.moe is not None):
        raise ValueError(
            f"matvec overrides target dense-FFN decode; family {cfg.family!r} "
            "with MoE/SSM blocks serves through its dense-effective params")

    if cfg.family == "ssm":
        def body(x, xs):
            bp, wkv, xp_tm, xp_cm = xs
            tm_in = _norm(cfg, bp["ln1"], x)
            y, st = rwkv6_timemix_decode(bp["tm"], tm_in,
                                         RWKV6State(wkv=wkv, x_prev=xp_tm),
                                         head_dim=cfg.hd)
            x = x + y
            cm_in = _norm(cfg, bp["ln2"], x)
            y, _cm_last = rwkv6_channelmix(bp["cm"], cm_in, x_prev_last=xp_cm)
            x = x + y
            return x, (st.wkv, st.x_prev, cm_in[:, 0])

        x, outs = _scan(body, x, (blocks, state["wkv"], state["x_prev_tm"],
                              state["x_prev_cm"]), unroll)
        new = {"wkv": outs[0], "x_prev_tm": outs[1], "x_prev_cm": outs[2]}
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        nmain = n_groups * period
        sp = params["shared_attn"]

        def mamba_body(x, xs):
            bp, ssm, conv = xs
            st = Mamba2State(ssm=ssm, conv=conv)
            y, st2 = mamba2_decode(bp["mamba"], _norm(cfg, bp["ln1"], x), st,
                                   d_inner=cfg.ssm.d_inner, d_state=cfg.ssm.d_state,
                                   head_dim=cfg.ssm.head_dim, d_conv=cfg.ssm.d_conv)
            return x + y, (st2.ssm, st2.conv)

        def group_body(x, xs):
            gb, gssm, gconv, ak, av, akp = xs
            x, (ssm2, conv2) = _scan(mamba_body, x, (gb, gssm, gconv), unroll)
            cache = KVCache(k=ak, v=av, kpos=akp)
            y, c2 = attention_decode(sp["attn"], _norm(cfg, sp["ln1"], x), cache, pos,
                                     n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                     head_dim=cfg.hd, window=cfg.attn_window,
                                     rope_theta=cfg.rope_theta)
            x = x + y
            x = x + swiglu(sp["ffn"], _norm(cfg, sp["ln2"], x))
            return x, (ssm2, conv2, c2.k, c2.v, c2.kpos)

        regroup = lambda a: a[:nmain].reshape(n_groups, period, *a.shape[1:])  # noqa: E731
        main_b = jax.tree.map(regroup, blocks)
        x, outs = _scan(group_body, x,
                        (main_b, regroup(state["ssm"]), regroup(state["conv"]),
                         state["attn_k"], state["attn_v"], state["attn_kpos"]),
                        unroll)
        ssm2 = outs[0].reshape(nmain, *state["ssm"].shape[1:])
        conv2 = outs[1].reshape(nmain, *state["conv"].shape[1:])
        if tail:
            tail_b = jax.tree.map(lambda a: a[nmain:], blocks)
            x, touts = _scan(mamba_body, x,
                             (tail_b, state["ssm"][nmain:], state["conv"][nmain:]),
                             unroll)
            ssm2 = jnp.concatenate([ssm2, touts[0]])
            conv2 = jnp.concatenate([conv2, touts[1]])
        new = {"ssm": ssm2, "conv": conv2, "attn_k": outs[2], "attn_v": outs[3],
               "attn_kpos": outs[4]}
    elif cfg.mla is not None:
        def body(x, xs):
            bp, ck, kr, kp = xs
            cache = MLACache(c_kv=ck, k_rope=kr, kpos=kp)
            y, c2 = mla_decode(bp["attn"], _norm(cfg, bp["ln1"], x), cache, pos,
                               n_heads=cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                               qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                               v_dim=cfg.mla.v_dim, rope_theta=cfg.rope_theta)
            x = x + y
            ffn_in = _norm(cfg, bp["ln2"], x)
            if cfg.moe is not None:
                moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
                y, _ = moe_fn(bp["ffn"], ffn_in, n_experts=cfg.moe.n_experts,
                              top_k=cfg.moe.top_k,
                              capacity_factor=cfg.moe.capacity_factor,
                              norm_topk=cfg.moe.norm_topk)
            else:
                y = swiglu(bp["ffn"], ffn_in)
            return x + y, (c2.c_kv, c2.k_rope, c2.kpos)

        x, outs = _scan(body, x, (blocks, state["c_kv"], state["k_rope"],
                              state["kpos"]), unroll)
        new = {"c_kv": outs[0], "k_rope": outs[1], "kpos": outs[2]}
    else:
        def make_body(ffn_fn):
            def body(x, xs):
                bp, k, v, kp = xs
                cache = KVCache(k=k, v=v, kpos=kp)
                y, c2 = attention_decode(
                    bp["attn"], _norm(cfg, bp["ln1"], x), cache, pos,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    window=cfg.attn_window,
                    rope_theta=None if cfg.pos in ("none", "mrope") else cfg.rope_theta,
                    mrope_sections=cfg.mrope_sections if cfg.pos == "mrope" else None,
                    mrope_positions=jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
                    if cfg.pos == "mrope" else None)
                x = x + y
                ffn_in = _norm(cfg, bp["ln2"], x)
                y = ffn_fn(bp["ffn"], ffn_in)
                return x + y, (c2.k, c2.v, c2.kpos)
            return body

        if cfg.moe is not None:
            def default_ffn(p, ffn_in):
                moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
                y, _ = moe_fn(p, ffn_in, n_experts=cfg.moe.n_experts,
                              top_k=cfg.moe.top_k,
                              capacity_factor=cfg.moe.capacity_factor,
                              norm_topk=cfg.moe.norm_topk)
                return y
        else:
            default_ffn = swiglu

        xs_all = (blocks, state["k"], state["v"], state["kpos"])
        if matvec_overrides is None:
            x, outs = _scan(make_body(default_ffn), x, xs_all, unroll)
        else:
            # unrolled layer loop: each layer binds its own kernel buffers
            per_layer = []
            for li in range(cfg.n_layers):
                xs_li = jax.tree.map(lambda a: a[li], xs_all)
                x, out = make_body(_ffn_with_overrides(matvec_overrides, li))(x, xs_li)
                per_layer.append(out)
            outs = jax.tree.map(lambda *a: jnp.stack(a), *per_layer)
        new = {"k": outs[0], "v": outs[1], "kpos": outs[2]}

    h = _norm(cfg, params["final_ln"], x)
    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, new
