"""Unified decoder backbone covering all assigned LM families.

Block layout per family:
  dense / vlm:   x += attn(norm(x));   x += swiglu(norm(x))
  moe:           x += attn|mla(norm(x)); x += moe(norm(x)) [+ shared experts]
  ssm (rwkv6):   x += timemix(norm(x)); x += channelmix(norm(x))
  hybrid(zamba): groups of ``hybrid_period`` mamba2 blocks, one *weight-shared*
                 attention+MLP block between groups (the zamba2 trick: depth
                 reuses one attention block's parameters).

Layers are scanned (stacked [L, ...] params) for compact HLO and FSDP-friendly
per-layer weight gathering; ``unroll`` switches to a static python loop for
the roofline cost pass.  Loss is sequence-chunked so [B, S, V] logits are never
materialized.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act_shard import constrain

from .attention import (
    KVCache,
    MLACache,
    PagedKVCache,
    PagedMLACache,
    attention_decode,
    attention_extend,
    attention_prefill,
    init_attention,
    init_kv_cache,
    init_mla,
    mla_decode,
    mla_extend,
    mla_prefill,
)
from .layers import (dense_init, linear, non_parametric_ln, rms_norm,
                     site_linear, site_linear_group, swiglu)
from .mamba2 import Mamba2State, init_mamba2, mamba2_decode, mamba2_prefill
from .moe import init_moe, moe_ffn, moe_ffn_manual
from .rwkv6 import (
    RWKV6State,
    init_rwkv6,
    init_rwkv6_channelmix,
    rwkv6_channelmix,
    rwkv6_timemix_decode,
    rwkv6_timemix_prefill,
)

__all__ = ["init_params", "forward", "decode_step", "init_decode_state",
           "forward_extend", "paged_layout", "loss_fn"]


def _scan(body, init, xs, unroll: bool):
    """lax.scan or a static python loop (roofline cost pass — while bodies are
    undercounted by HLO cost analysis, DESIGN.md Sec. 6)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def _norm(cfg: ArchConfig, p, x):
    if cfg.norm == "nonparam":
        return non_parametric_ln(x)
    return rms_norm(x, p)


def _norm_param(cfg: ArchConfig, d):
    # non-parametric LN keeps a (frozen, unused) scale so pytree structure is uniform
    return jnp.ones((d,), cfg.pdtype)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": _norm_param(cfg, cfg.d_model),
                         "ln2": _norm_param(cfg, cfg.d_model)}
    if cfg.family == "ssm":  # rwkv6
        p["tm"] = init_rwkv6(ks[0], cfg.d_model, head_dim=cfg.hd, dtype=cfg.pdtype)
        p["cm"] = init_rwkv6_channelmix(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype)
        return p
    if cfg.family == "hybrid":  # zamba2 mamba block (attention is shared, separate)
        p.pop("ln2")
        p["mamba"] = init_mamba2(ks[0], cfg.d_model, d_inner=cfg.ssm.d_inner,
                                 d_state=cfg.ssm.d_state, head_dim=cfg.ssm.head_dim,
                                 d_conv=cfg.ssm.d_conv, dtype=cfg.pdtype)
        return p
    if cfg.mla is not None:
        p["attn"] = init_mla(ks[0], cfg.d_model, cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                             qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                             v_dim=cfg.mla.v_dim, dtype=cfg.pdtype)
    else:
        p["attn"] = init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.hd, cfg.pdtype, qkv_bias=cfg.qkv_bias)
    if cfg.moe is not None:
        p["ffn"] = init_moe(ks[1], cfg.d_model, cfg.moe.d_ff_expert, cfg.moe.n_experts,
                            cfg.moe.n_shared, cfg.pdtype)
    else:
        p["ffn"] = {
            "gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "up": dense_init(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "down": dense_init(ks[3], cfg.d_ff, cfg.d_model, cfg.pdtype),
        }
    return p


def _init_shared_attn(key, cfg: ArchConfig):
    """Zamba2's weight-shared attention + MLP block."""
    ks = jax.random.split(key, 4)
    return {
        "ln1": _norm_param(cfg, cfg.d_model),
        "ln2": _norm_param(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.pdtype),
        "ffn": {
            "gate": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "up": dense_init(ks[2], cfg.d_model, cfg.d_ff, cfg.pdtype),
            "down": dense_init(ks[3], cfg.d_ff, cfg.d_model, cfg.pdtype),
        },
    }


def init_params(key, cfg: ArchConfig):
    k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    scale = cfg.d_model**-0.5
    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model)) * scale).astype(cfg.pdtype),
        "final_ln": _norm_param(cfg, cfg.d_model),
    }
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, cfg.pdtype)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(k_shared, cfg)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_fwd(cfg: ArchConfig, p, x, positions, *, unroll: bool, state=None,
               mrope_positions=None):
    """One block forward. Returns (x, per-layer state-out or aux)."""
    if cfg.family == "ssm":
        tm_in = _norm(cfg, p["ln1"], x)
        y, st = rwkv6_timemix_prefill(p["tm"], tm_in, head_dim=cfg.hd,
                                      chunk=cfg.ssm_chunk, unroll_chunks=unroll,
                                      state=None)
        x = x + y
        cm_in = _norm(cfg, p["ln2"], x)
        y, cm_last = rwkv6_channelmix(p["cm"], cm_in)
        x = x + y
        return x, RWKV6State(wkv=st.wkv, x_prev=st.x_prev), cm_last
    if cfg.family == "hybrid":
        y, st = mamba2_prefill(p["mamba"], _norm(cfg, p["ln1"], x),
                               d_inner=cfg.ssm.d_inner, d_state=cfg.ssm.d_state,
                               head_dim=cfg.ssm.head_dim, d_conv=cfg.ssm.d_conv,
                               chunk=cfg.ssm_chunk, unroll_chunks=unroll)
        return x + y, st, None
    # attention family
    attn_in = _norm(cfg, p["ln1"], x)
    if cfg.mla is not None:
        y, c_kv, k_rope = mla_prefill(p["attn"], attn_in, positions, n_heads=cfg.n_heads,
                                      kv_lora=cfg.mla.kv_lora, qk_nope=cfg.mla.qk_nope,
                                      qk_rope=cfg.mla.qk_rope, v_dim=cfg.mla.v_dim,
                                      rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                                      unroll_chunks=unroll,
                                      causal_skip=cfg.causal_chunk_skip)
        kv = (c_kv, k_rope)
    else:
        y, k, v = attention_prefill(
            p["attn"], attn_in, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, causal=True, window=cfg.attn_window,
            rope_theta=None if cfg.pos in ("none", "mrope") else cfg.rope_theta,
            mrope_sections=cfg.mrope_sections if cfg.pos == "mrope" else None,
            mrope_positions=mrope_positions, q_chunk=cfg.q_chunk, unroll_chunks=unroll,
            causal_skip=cfg.causal_chunk_skip)
        kv = (k, v)
    x = x + y
    ffn_in = _norm(cfg, p["ln2"], x)
    if cfg.moe is not None:
        moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
        y, _aux = moe_fn(p["ffn"], ffn_in, n_experts=cfg.moe.n_experts,
                         top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
                         norm_topk=cfg.moe.norm_topk)
    else:
        y = swiglu(p["ffn"], ffn_in)
    return x + y, kv, None


def _shared_attn_fwd(cfg: ArchConfig, p, x, positions, *, unroll: bool):
    y, k, v = attention_prefill(p["attn"], _norm(cfg, p["ln1"], x), positions,
                                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                                causal=True, window=cfg.attn_window,
                                rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                                unroll_chunks=unroll, causal_skip=cfg.causal_chunk_skip)
    x = x + y
    x = x + swiglu(p["ffn"], _norm(cfg, p["ln2"], x))
    return x, (k, v)


def forward(params, cfg: ArchConfig, *, tokens=None, embeds=None, positions=None,
            positions3=None, unroll: bool = False, collect_cache: bool = False):
    """Train/prefill forward -> (hidden [B,S,d], caches or None)."""
    if embeds is not None:
        x = embeds.astype(cfg.cdtype)
        b, s = x.shape[:2]
    else:
        b, s = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, "batch", None, None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.pos == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))

    blocks = params["blocks"]

    def one(x, bp):
        return _block_fwd(cfg, bp, x, positions, unroll=unroll,
                          mrope_positions=positions3)

    if cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        if unroll or collect_cache:  # python loop: roofline pass / serving prefill
            caches = {"mamba": [], "attn": []}
            li = 0
            for g in range(n_groups):
                for _ in range(period):
                    bp = jax.tree.map(lambda a: a[li], blocks)
                    x, st, _ = one(x, bp)
                    caches["mamba"].append(st)
                    li += 1
                x, kv = _shared_attn_fwd(cfg, params["shared_attn"], x, positions,
                                         unroll=unroll)
                caches["attn"].append(kv)
            for _ in range(tail):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x, st, _ = one(x, bp)
                caches["mamba"].append(st)
                li += 1
            cache_out = caches if collect_cache else None
        else:  # production path: scan over groups, inner scan over mamba layers
            main = jax.tree.map(
                lambda a: a[: n_groups * period].reshape(n_groups, period, *a.shape[1:]),
                blocks)

            def layer_body(x, bp):
                x, _st, _ = one(x, bp)
                return x, None

            def group_body(x, gp):
                x, _ = jax.lax.scan(layer_body, x, gp)
                x, _kv = _shared_attn_fwd(cfg, params["shared_attn"], x, positions,
                                          unroll=False)
                return x, None

            if cfg.remat:
                group_body = jax.checkpoint(group_body)
            x, _ = jax.lax.scan(group_body, x, main)
            if tail:
                tailb = jax.tree.map(lambda a: a[n_groups * period:], blocks)
                body = jax.checkpoint(layer_body) if cfg.remat else layer_body
                x, _ = jax.lax.scan(body, x, tailb)
            cache_out = None
    elif unroll:
        cache_list = []
        if collect_cache:
            for li in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x, c, _extra = one(x, bp)
                cache_list.append(c)
        else:
            xonly = lambda x, bp: one(x, bp)[0]  # noqa: E731
            fn = jax.checkpoint(xonly) if cfg.remat else xonly
            for li in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[li], blocks)
                x = fn(x, bp)
        cache_out = cache_list if collect_cache else None
    else:
        def body(x, bp):
            x, c, _extra = one(x, bp)
            return x, c if collect_cache else None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, stacked = jax.lax.scan(body, x, blocks)
        cache_out = stacked if collect_cache else None

    x = _norm(cfg, params["final_ln"], x)
    return x, cache_out


def logits_from_hidden(params, cfg: ArchConfig, h):
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return linear(params["lm_head"], h)


def loss_fn(params, cfg: ArchConfig, batch, *, unroll: bool = False,
            seq_chunk: int = 512):
    """Sequence-chunked cross-entropy; logits [B,S,V] never materialized."""
    h, _ = forward(params, cfg,
                   tokens=batch.get("tokens"), embeds=batch.get("embeds"),
                   positions3=batch.get("positions3"), unroll=unroll)
    labels = batch["labels"]
    b, s = labels.shape
    c = min(seq_chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    hch = h.reshape(b, nc, c, cfg.d_model)
    lch = labels.reshape(b, nc, c)

    def chunk_loss(hc, lc):
        logits = logits_from_hidden(params, cfg, hc).astype(jnp.float32)  # [B,c,V]
        logits = constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    if unroll or nc == 1:
        tot = 0.0
        for i in range(nc):
            tot += chunk_loss(hch[:, i], lch[:, i])
    else:
        def body(acc, xs):
            hc, lc = xs
            return acc + chunk_loss(hc, lc), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                              (jnp.moveaxis(hch, 1, 0), jnp.moveaxis(lch, 1, 0)))
    return tot / (b * s)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def paged_layout(cfg: ArchConfig, smax: int, kv_block: int,
                 kv_blocks: int | None = None, n_slots: int = 1):
    """Resolve paged-KV geometry -> ``(block_size, view_blocks, pool_entries)``.

    Windowed attention shrinks the block so it divides the ring exactly
    (``gcd``), keeping the logical view the same length as the ring — the
    ``pos % eff`` slot arithmetic is unchanged.  ``pool_entries`` counts the
    reserved null block (id 0) and is rounded up to a multiple of 8 so the
    pool axis shards evenly over small meshes; without ``kv_blocks`` the pool
    matches the contiguous layout's token capacity (one full view per slot).
    """
    w = cfg.attn_window
    eff = min(smax, w) if w is not None else smax
    bs = math.gcd(int(kv_block), eff) if w is not None else min(int(kv_block), eff)
    mb = -(-eff // bs)
    usable = kv_blocks if kv_blocks is not None else n_slots * mb
    if w is not None:
        usable = max(usable, mb)  # a ring slot needs its whole view resident
    entries = -(-(usable + 1) // 8) * 8
    return bs, mb, entries


def init_decode_state(cfg: ArchConfig, batch: int, smax: int, *,
                      kv_block: int | None = None,
                      kv_blocks: int | None = None):
    """Abstract-init-friendly per-layer decode caches (call under eval_shape too).

    ``kv_block`` switches the attention families (dense GQA, MLA) to a paged
    layout: per-layer block *pools* ``[L, pool, bs, ...]`` plus one shared
    block table ``[batch, view_blocks]`` (see ``serving.kvpool``).  Families
    whose state is not a KV sequence (ssm, hybrid) and encoder-decoder models
    ignore it — they keep the contiguous layout.
    """
    L = cfg.n_layers
    cd = cfg.cdtype
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.hd
        return {
            "wkv": jnp.zeros((L, batch, h, cfg.hd, cfg.hd), jnp.float32),
            "x_prev_tm": jnp.zeros((L, batch, cfg.d_model), cd),
            "x_prev_cm": jnp.zeros((L, batch, cfg.d_model), cd),
        }
    if cfg.family == "hybrid":
        hh = cfg.ssm.d_inner // cfg.ssm.head_dim
        n_attn = cfg.n_layers // cfg.hybrid_period
        conv_dim = cfg.ssm.d_inner + 2 * cfg.ssm.d_state
        return {
            "ssm": jnp.zeros((L, batch, hh, cfg.ssm.d_state, cfg.ssm.head_dim), jnp.float32),
            "conv": jnp.zeros((L, batch, conv_dim, cfg.ssm.d_conv - 1), cd),
            "attn_k": jnp.zeros((n_attn, batch, smax, cfg.n_kv_heads, cfg.hd), cd),
            "attn_v": jnp.zeros((n_attn, batch, smax, cfg.n_kv_heads, cfg.hd), cd),
            "attn_kpos": jnp.full((n_attn, batch, smax), -1, jnp.int32),
        }
    if cfg.mla is not None:
        if kv_block is not None:
            bs, mb, nb = paged_layout(cfg, smax, kv_block, kv_blocks, n_slots=batch)
            return {
                "c_kv": jnp.zeros((L, nb, bs, cfg.mla.kv_lora), cd),
                "k_rope": jnp.zeros((L, nb, bs, cfg.mla.qk_rope), cd),
                "kpos": jnp.full((L, batch, mb * bs), -1, jnp.int32),
                "block_tbl": jnp.zeros((batch, mb), jnp.int32),
            }
        return {
            "c_kv": jnp.zeros((L, batch, smax, cfg.mla.kv_lora), cd),
            "k_rope": jnp.zeros((L, batch, smax, cfg.mla.qk_rope), cd),
            "kpos": jnp.full((L, batch, smax), -1, jnp.int32),
        }
    w = cfg.attn_window
    eff = min(smax, w) if w is not None else smax
    if kv_block is not None:
        bs, mb, nb = paged_layout(cfg, smax, kv_block, kv_blocks, n_slots=batch)
        return {
            "k": jnp.zeros((L, nb, bs, cfg.n_kv_heads, cfg.hd), cd),
            "v": jnp.zeros((L, nb, bs, cfg.n_kv_heads, cfg.hd), cd),
            "kpos": jnp.full((L, batch, mb * bs), -1, jnp.int32),
            "block_tbl": jnp.zeros((batch, mb), jnp.int32),
        }
    return {
        "k": jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), cd),
        "v": jnp.zeros((L, batch, eff, cfg.n_kv_heads, cfg.hd), cd),
        "kpos": jnp.full((L, batch, eff), -1, jnp.int32),
    }


def _sites_swiglu(executor, tag: str):
    """SwiGLU routed through compressed sites: gate/up (shared input) as ONE
    grouped fused launch, down through its own chain; uncovered sites dense."""
    def ffn(p, x):
        g, u = site_linear_group(executor, (tag.format("gate"), tag.format("up")),
                                 (p["gate"], p["up"]), x)
        g = constrain(g, "batch", None, "model")
        u = constrain(u, "batch", None, "model")
        y = site_linear(executor, tag.format("down"), p["down"],
                        jax.nn.silu(g) * u)
        return constrain(y, "batch", None, None)

    return ffn


def _unrolled_layers(body_for, x, xs_all, n_layers: int):
    """Static per-layer loop so layer ``li`` binds its own kernel buffers
    (the executor's fused chains are per-site constants, which a lax.scan
    cannot carry)."""
    per_layer = []
    for li in range(n_layers):
        xs_li = jax.tree.map(lambda a: a[li], xs_all)
        x, out = body_for(li)(x, xs_li)
        per_layer.append(out)
    outs = jax.tree.map(lambda *a: jnp.stack(a), *per_layer)
    return x, outs


def decode_step(params, cfg: ArchConfig, state, token, pos, *, unroll: bool = False,
                executor=None):
    """One decode step: (logits [B, V], new state). token [B,1], pos [B].

    ``executor`` (compressed serving): a site-keyed registry — see
    ``repro.serving.executor.CompressedExecutor`` — consulted for EVERY
    compressible site of the family (attention q/k/v/o or MLA projections,
    FFN gate/up/down, per-expert MoE matrices, RWKV-6 time/channel mixes,
    Mamba2 in/out, the zamba2 shared block).  Covered sites execute their LCC
    chains through fused Pallas launches *inside* this (jitted) step; sites
    the executor does not cover fall back to the dense weights.  The layer
    loop is unrolled when an executor is present so each layer binds its own
    kernel buffers.
    """
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)
    blocks = params["blocks"]

    if cfg.family == "ssm":
        def body_for(li):
            ex = executor if li is not None else None

            def body(x, xs):
                bp, wkv, xp_tm, xp_cm = xs
                tm_in = _norm(cfg, bp["ln1"], x)
                y, st = rwkv6_timemix_decode(
                    bp["tm"], tm_in, RWKV6State(wkv=wkv, x_prev=xp_tm),
                    head_dim=cfg.hd, executor=ex,
                    site=f"tm.{{}}.l{li}" if ex is not None else None)
                x = x + y
                cm_in = _norm(cfg, bp["ln2"], x)
                y, _cm_last = rwkv6_channelmix(
                    bp["cm"], cm_in, x_prev_last=xp_cm, executor=ex,
                    site=f"cm.{{}}.l{li}" if ex is not None else None)
                x = x + y
                return x, (st.wkv, st.x_prev, cm_in[:, 0])
            return body

        xs_all = (blocks, state["wkv"], state["x_prev_tm"], state["x_prev_cm"])
        if executor is None:
            x, outs = _scan(body_for(None), x, xs_all, unroll)
        else:
            x, outs = _unrolled_layers(body_for, x, xs_all, cfg.n_layers)
        new = {"wkv": outs[0], "x_prev_tm": outs[1], "x_prev_cm": outs[2]}
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        nmain = n_groups * period
        sp = params["shared_attn"]

        def mamba_body_for(li):
            ex = executor if li is not None else None

            def mamba_body(x, xs):
                bp, ssm, conv = xs
                st = Mamba2State(ssm=ssm, conv=conv)
                y, st2 = mamba2_decode(
                    bp["mamba"], _norm(cfg, bp["ln1"], x), st,
                    d_inner=cfg.ssm.d_inner, d_state=cfg.ssm.d_state,
                    head_dim=cfg.ssm.head_dim, d_conv=cfg.ssm.d_conv,
                    executor=ex,
                    site=f"mamba.{{}}.l{li}" if ex is not None else None)
                return x + y, (st2.ssm, st2.conv)
            return mamba_body

        def shared_attn_step(x, ak, av, akp):
            cache = KVCache(k=ak, v=av, kpos=akp)
            y, c2 = attention_decode(
                sp["attn"], _norm(cfg, sp["ln1"], x), cache, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                window=cfg.attn_window, rope_theta=cfg.rope_theta,
                executor=executor,
                site="shared_attn.attn.{}" if executor is not None else None)
            x = x + y
            if executor is not None:
                ffn = _sites_swiglu(executor, "shared_attn.ffn.{}")
                x = x + ffn(sp["ffn"], _norm(cfg, sp["ln2"], x))
            else:
                x = x + swiglu(sp["ffn"], _norm(cfg, sp["ln2"], x))
            return x, c2

        if executor is None:
            def group_body(x, xs):
                gb, gssm, gconv, ak, av, akp = xs
                x, (ssm2, conv2) = _scan(mamba_body_for(None), x,
                                         (gb, gssm, gconv), unroll)
                x, c2 = shared_attn_step(x, ak, av, akp)
                return x, (ssm2, conv2, c2.k, c2.v, c2.kpos)

            regroup = lambda a: a[:nmain].reshape(n_groups, period, *a.shape[1:])  # noqa: E731
            main_b = jax.tree.map(regroup, blocks)
            x, outs = _scan(group_body, x,
                            (main_b, regroup(state["ssm"]), regroup(state["conv"]),
                             state["attn_k"], state["attn_v"], state["attn_kpos"]),
                            unroll)
            ssm2 = outs[0].reshape(nmain, *state["ssm"].shape[1:])
            conv2 = outs[1].reshape(nmain, *state["conv"].shape[1:])
            ak2, av2, akp2 = outs[2], outs[3], outs[4]
            if tail:
                tail_b = jax.tree.map(lambda a: a[nmain:], blocks)
                x, touts = _scan(mamba_body_for(None), x,
                                 (tail_b, state["ssm"][nmain:], state["conv"][nmain:]),
                                 unroll)
                ssm2 = jnp.concatenate([ssm2, touts[0]])
                conv2 = jnp.concatenate([conv2, touts[1]])
        else:
            # unrolled: each mamba layer / the shared block bind their chains
            ssm_l, conv_l, ak_l, av_l, akp_l = [], [], [], [], []
            li = 0
            for g in range(n_groups):
                for _ in range(period):
                    xs_li = (jax.tree.map(lambda a: a[li], blocks),
                             state["ssm"][li], state["conv"][li])
                    x, (s2, c2) = mamba_body_for(li)(x, xs_li)
                    ssm_l.append(s2)
                    conv_l.append(c2)
                    li += 1
                x, kv2 = shared_attn_step(x, state["attn_k"][g],
                                          state["attn_v"][g],
                                          state["attn_kpos"][g])
                ak_l.append(kv2.k)
                av_l.append(kv2.v)
                akp_l.append(kv2.kpos)
            for _ in range(tail):
                xs_li = (jax.tree.map(lambda a: a[li], blocks),
                         state["ssm"][li], state["conv"][li])
                x, (s2, c2) = mamba_body_for(li)(x, xs_li)
                ssm_l.append(s2)
                conv_l.append(c2)
                li += 1
            ssm2 = jnp.stack(ssm_l)
            conv2 = jnp.stack(conv_l)
            ak2, av2, akp2 = (jnp.stack(ak_l), jnp.stack(av_l),
                              jnp.stack(akp_l))
        new = {"ssm": ssm2, "conv": conv2, "attn_k": ak2, "attn_v": av2,
               "attn_kpos": akp2}
    elif cfg.mla is not None:
        tbl = state.get("block_tbl")  # paged: closure constant across layers

        def body_for(li):
            ex = executor if li is not None else None

            def body(x, xs):
                bp, ck, kr, kp = xs
                cache = (PagedMLACache(c_kv=ck, k_rope=kr, kpos=kp, tbl=tbl)
                         if tbl is not None
                         else MLACache(c_kv=ck, k_rope=kr, kpos=kp))
                y, c2 = mla_decode(
                    bp["attn"], _norm(cfg, bp["ln1"], x), cache, pos,
                    n_heads=cfg.n_heads, kv_lora=cfg.mla.kv_lora,
                    qk_nope=cfg.mla.qk_nope, qk_rope=cfg.mla.qk_rope,
                    v_dim=cfg.mla.v_dim, rope_theta=cfg.rope_theta,
                    executor=ex,
                    site=f"attn.{{}}.l{li}" if ex is not None else None)
                x = x + y
                ffn_in = _norm(cfg, bp["ln2"], x)
                if cfg.moe is not None:
                    moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
                    kw = ({"executor": ex, "site_tag": f"l{li}"}
                          if ex is not None and not cfg.moe_manual else {})
                    y, _ = moe_fn(bp["ffn"], ffn_in, n_experts=cfg.moe.n_experts,
                                  top_k=cfg.moe.top_k,
                                  capacity_factor=cfg.moe.capacity_factor,
                                  norm_topk=cfg.moe.norm_topk, **kw)
                elif ex is not None:
                    y = _sites_swiglu(ex, f"ffn.{{}}.l{li}")(bp["ffn"], ffn_in)
                else:
                    y = swiglu(bp["ffn"], ffn_in)
                return x + y, (c2.c_kv, c2.k_rope, c2.kpos)
            return body

        xs_all = (blocks, state["c_kv"], state["k_rope"], state["kpos"])
        if executor is None:
            x, outs = _scan(body_for(None), x, xs_all, unroll)
        else:
            x, outs = _unrolled_layers(body_for, x, xs_all, cfg.n_layers)
        new = {"c_kv": outs[0], "k_rope": outs[1], "kpos": outs[2]}
        if tbl is not None:
            new["block_tbl"] = tbl
    else:
        tbl = state.get("block_tbl")
        # whole-step layer plan: when the executor can express the full layer
        # stack as one stacked-grid launch, the per-layer loop (and all its
        # per-region dispatches) is replaced by a single pallas_call
        plan = (executor.step_plan(cfg)
                if executor is not None and hasattr(executor, "step_plan")
                else None)
        if plan is not None:
            x, new = plan.decode_layers(state, x, pos)
        else:
            def body_for(li):
                ex = executor if li is not None else None

                def ffn_fn(p, ffn_in):
                    if cfg.moe is not None:
                        moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
                        kw = ({"executor": ex, "site_tag": f"l{li}"}
                              if ex is not None and not cfg.moe_manual else {})
                        y, _ = moe_fn(p, ffn_in, n_experts=cfg.moe.n_experts,
                                      top_k=cfg.moe.top_k,
                                      capacity_factor=cfg.moe.capacity_factor,
                                      norm_topk=cfg.moe.norm_topk, **kw)
                        return y
                    if ex is not None:
                        return _sites_swiglu(ex, f"ffn.{{}}.l{li}")(p, ffn_in)
                    return swiglu(p, ffn_in)

                def body(x, xs):
                    bp, k, v, kp = xs
                    cache = (PagedKVCache(k=k, v=v, kpos=kp, tbl=tbl)
                             if tbl is not None else KVCache(k=k, v=v, kpos=kp))
                    y, c2 = attention_decode(
                        bp["attn"], _norm(cfg, bp["ln1"], x), cache, pos,
                        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                        window=cfg.attn_window,
                        rope_theta=None if cfg.pos in ("none", "mrope") else cfg.rope_theta,
                        mrope_sections=cfg.mrope_sections if cfg.pos == "mrope" else None,
                        mrope_positions=jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
                        if cfg.pos == "mrope" else None,
                        executor=ex,
                        site=f"attn.{{}}.l{li}" if ex is not None else None)
                    x = x + y
                    ffn_in = _norm(cfg, bp["ln2"], x)
                    y = ffn_fn(bp["ffn"], ffn_in)
                    return x + y, (c2.k, c2.v, c2.kpos)
                return body

            xs_all = (blocks, state["k"], state["v"], state["kpos"])
            if executor is None:
                x, outs = _scan(body_for(None), x, xs_all, unroll)
            else:
                # unrolled layer loop: each layer binds its own kernel buffers
                x, outs = _unrolled_layers(body_for, x, xs_all, cfg.n_layers)
            new = {"k": outs[0], "v": outs[1], "kpos": outs[2]}
            if tbl is not None:
                new["block_tbl"] = tbl

    h = _norm(cfg, params["final_ln"], x)
    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, new


def forward_extend(params, cfg: ArchConfig, tokens, positions, past, last, *,
                   unroll: bool = False):
    """Prefix-cache tail prefill: run ``tokens`` [B,T] at absolute
    ``positions`` [B,T] attending to a resident per-layer KV prefix.

    ``past`` holds the *gathered* pool views for the cached prefix —
    dense: ``{"k","v": [L,B,C,Hkv,hd], "kpos": [L,B,C]}``; MLA:
    ``{"c_kv","k_rope","kpos"}`` — masked by ``kpos == -1`` (so padding the
    prefix view is harmless).  Padded tail entries carry position ``-1``:
    they are excluded from every real query's key set and their own garbage
    activations stay confined to their row.  ``last`` [B] indexes the final
    real tail token.  Returns ``(logits [B,V] at ``last``, tail caches with
    [L,B,T,...] leaves)`` — only the tail K/V, for scatter into freshly
    allocated blocks.
    """
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    blocks = params["blocks"]

    def ffn_fn(bp, ffn_in):
        if cfg.moe is not None:
            moe_fn = moe_ffn_manual if cfg.moe_manual else moe_ffn
            y, _ = moe_fn(bp["ffn"], ffn_in, n_experts=cfg.moe.n_experts,
                          top_k=cfg.moe.top_k,
                          capacity_factor=cfg.moe.capacity_factor,
                          norm_topk=cfg.moe.norm_topk)
            return y
        return swiglu(bp["ffn"], ffn_in)

    if cfg.mla is not None:
        def body(x, xs):
            bp, pc, pkr, pkp = xs
            y, c_t, kr_t = mla_extend(
                bp["attn"], _norm(cfg, bp["ln1"], x), positions, pc, pkr, pkp,
                n_heads=cfg.n_heads, qk_nope=cfg.mla.qk_nope,
                qk_rope=cfg.mla.qk_rope, v_dim=cfg.mla.v_dim,
                rope_theta=cfg.rope_theta)
            x = x + y
            x = x + ffn_fn(bp, _norm(cfg, bp["ln2"], x))
            return x, (c_t, kr_t)

        x, outs = _scan(body, x, (blocks, past["c_kv"], past["k_rope"],
                                  past["kpos"]), unroll)
        tails = {"c_kv": outs[0], "k_rope": outs[1]}
    else:
        def body(x, xs):
            bp, pk, pv, pkp = xs
            y, k_t, v_t = attention_extend(
                bp["attn"], _norm(cfg, bp["ln1"], x), positions, pk, pv, pkp,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=None if cfg.pos == "none" else cfg.rope_theta)
            x = x + y
            x = x + ffn_fn(bp, _norm(cfg, bp["ln2"], x))
            return x, (k_t, v_t)

        x, outs = _scan(body, x, (blocks, past["k"], past["v"], past["kpos"]),
                        unroll)
        tails = {"k": outs[0], "v": outs[1]}

    h = x[jnp.arange(b), last][:, None]  # [B,1,d]
    h = _norm(cfg, params["final_ln"], h)
    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, tails
