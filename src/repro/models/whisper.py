"""Whisper-style encoder-decoder backbone (audio frontend stubbed per spec).

``frames`` are precomputed frame embeddings [B, S, d] (the conv frontend stub);
the encoder is bidirectional, the decoder causal with cross-attention.
Sinusoidal encoder positions, learned decoder positions, pre-LN layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.act_shard import constrain

from .attention import KVCache, attention_decode, attention_prefill, init_attention
from .layers import dense_init, gelu_mlp, layer_norm, linear, site_linear

__all__ = ["init_params", "encode", "decoder_forward", "loss_fn", "decode_step",
           "init_decode_state"]


def _ln_p(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_layer(key, cfg: ArchConfig, cross: bool):
    ks = jax.random.split(key, 5)
    p = {
        "ln1": _ln_p(cfg.d_model, cfg.pdtype),
        "attn": init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, cfg.pdtype, qkv_bias=True),
        "ln2": _ln_p(cfg.d_model, cfg.pdtype),
        "mlp": {"fc1": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype, bias=True),
                "fc2": dense_init(ks[2], cfg.d_ff, cfg.d_model, cfg.pdtype, bias=True)},
    }
    if cross:
        p["ln_x"] = _ln_p(cfg.d_model, cfg.pdtype)
        p["xattn"] = init_attention(ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, cfg.pdtype, qkv_bias=True)
    return p


def init_params(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: _init_layer(k, cfg, cross=False))(enc_keys),
        "enc_ln": _ln_p(cfg.d_model, cfg.pdtype),
        "dec_blocks": jax.vmap(lambda k: _init_layer(k, cfg, cross=True))(dec_keys),
        "dec_ln": _ln_p(cfg.d_model, cfg.pdtype),
        "embed": (jax.random.normal(k3, (cfg.vocab, cfg.d_model)) * cfg.d_model**-0.5
                  ).astype(cfg.pdtype),
        "dec_pos": (jax.random.normal(k4, (cfg.max_decoder_len, cfg.d_model)) * 0.01
                    ).astype(cfg.pdtype),
    }


def _sinusoid(s, d):
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=1), jnp.float32)


def encode(params, cfg: ArchConfig, frames, *, unroll: bool = False):
    """frames [B, S, d] -> encoder states [B, S, d]."""
    b, s, _ = frames.shape
    x = frames.astype(cfg.cdtype) + _sinusoid(s, cfg.d_model).astype(cfg.cdtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def one(x, bp):
        a_in = layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"])
        y, _, _ = attention_prefill(bp["attn"], a_in, positions, n_heads=cfg.n_heads,
                                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=False,
                                    rope_theta=None, q_chunk=cfg.q_chunk,
                                    unroll_chunks=unroll)
        x = x + y
        m_in = layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"])
        return x + gelu_mlp(bp["mlp"], m_in)

    if unroll:
        for li in range(cfg.enc_layers):
            bp = jax.tree.map(lambda a: a[li], params["enc_blocks"])
            x = one(x, bp)
    else:
        def body(x, bp):
            return one(x, bp), None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"])


def decoder_forward(params, cfg: ArchConfig, tokens, enc_out, *, unroll: bool = False):
    """Teacher-forced decoder -> hidden [B, T, d]."""
    b, t = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = x + params["dec_pos"][:t][None].astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def one(x, bp):
        a_in = layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"])
        y, _, _ = attention_prefill(bp["attn"], a_in, positions, n_heads=cfg.n_heads,
                                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=True,
                                    rope_theta=None, q_chunk=cfg.q_chunk,
                                    unroll_chunks=unroll)
        x = x + y
        x_in = layer_norm(x, bp["ln_x"]["w"], bp["ln_x"]["b"])
        y, _, _ = attention_prefill(bp["xattn"], x_in, positions, n_heads=cfg.n_heads,
                                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd, causal=False,
                                    rope_theta=None, q_chunk=cfg.q_chunk,
                                    unroll_chunks=unroll, kv_x=enc_out)
        x = x + y
        m_in = layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"])
        return x + gelu_mlp(bp["mlp"], m_in)

    if unroll:
        for li in range(cfg.n_layers):
            bp = jax.tree.map(lambda a: a[li], params["dec_blocks"])
            x = one(x, bp)
    else:
        def body(x, bp):
            return one(x, bp), None
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])


def loss_fn(params, cfg: ArchConfig, batch, *, unroll: bool = False, seq_chunk: int = 512):
    enc_out = encode(params, cfg, batch["frames"], unroll=unroll)
    h = decoder_forward(params, cfg, batch["tokens"], enc_out, unroll=unroll)
    logits = (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def init_decode_state(cfg: ArchConfig, batch: int, enc_len: int):
    """Self-KV (ring over max_decoder_len) + static cross-KV per layer."""
    L = cfg.n_layers
    cd = cfg.cdtype
    t = cfg.max_decoder_len
    return {
        "self_k": jnp.zeros((L, batch, t, cfg.n_kv_heads, cfg.hd), cd),
        "self_v": jnp.zeros((L, batch, t, cfg.n_kv_heads, cfg.hd), cd),
        "self_kpos": jnp.full((L, batch, t), -1, jnp.int32),
        "cross_k": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, cfg.hd), cd),
        "cross_v": jnp.zeros((L, batch, enc_len, cfg.n_kv_heads, cfg.hd), cd),
    }


def decode_step(params, cfg: ArchConfig, state, token, pos, *, unroll: bool = False,
                executor=None):
    """One decoder token against precomputed cross-KV. token [B,1], pos [B].

    ``executor`` (compressed serving): decoder self/cross-attention and MLP
    projections route through the compressed executor's fused LCC chains
    (sites ``dec.attn.*.l{li}`` / ``dec.xattn.*.l{li}`` / ``dec.mlp.*.l{li}``);
    the layer loop unrolls so each layer binds its own kernel buffers."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.cdtype)
    pos_emb = jnp.take(params["dec_pos"], jnp.minimum(pos, cfg.max_decoder_len - 1),
                       axis=0)[:, None]
    x = x + pos_emb.astype(cfg.cdtype)

    def body_for(li):
        ex = executor if li is not None else None

        def body(x, xs):
            bp, sk, sv, skp, ck, cv = xs
            a_in = layer_norm(x, bp["ln1"]["w"], bp["ln1"]["b"])
            cache = KVCache(k=sk, v=sv, kpos=skp)
            y, c2 = attention_decode(
                bp["attn"], a_in, cache, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope_theta=None,
                executor=ex,
                site=f"dec.attn.{{}}.l{li}" if ex is not None else None)
            x = x + y
            x_in = layer_norm(x, bp["ln_x"]["w"], bp["ln_x"]["b"])
            xcache = KVCache(k=ck, v=cv, kpos=jnp.zeros(ck.shape[:2], jnp.int32))
            y, _ = attention_decode(
                bp["xattn"], x_in, xcache, pos, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope_theta=None,
                cross=True, executor=ex,
                site=f"dec.xattn.{{}}.l{li}" if ex is not None else None)
            x = x + y
            m_in = layer_norm(x, bp["ln2"]["w"], bp["ln2"]["b"])
            if ex is not None:
                # same TP annotations as gelu_mlp: d_ff on "model"
                h = constrain(site_linear(ex, f"dec.mlp.fc1.l{li}",
                                          bp["mlp"]["fc1"], m_in),
                              "batch", None, "model")
                x = x + constrain(site_linear(ex, f"dec.mlp.fc2.l{li}",
                                              bp["mlp"]["fc2"], jax.nn.gelu(h)),
                                  "batch", None, None)
            else:
                x = x + gelu_mlp(bp["mlp"], m_in)
            return x, (c2.k, c2.v, c2.kpos)
        return body

    from .transformer import _scan, _unrolled_layers
    xs_all = (params["dec_blocks"], state["self_k"], state["self_v"],
              state["self_kpos"], state["cross_k"], state["cross_v"])
    if executor is None:
        x, outs = _scan(body_for(None), x, xs_all, unroll)
    else:
        x, outs = _unrolled_layers(body_for, x, xs_all, cfg.n_layers)
    h = layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
    logits = (h @ params["embed"].T.astype(h.dtype))[:, 0]
    new = {"self_k": outs[0], "self_v": outs[1], "self_kpos": outs[2],
           "cross_k": state["cross_k"], "cross_v": state["cross_v"]}
    return logits, new
