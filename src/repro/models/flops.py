"""Analytic parameter / FLOP counts per (arch, shape-cell).

Used for the roofline MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) term and
as the cross-check against HLO cost analysis (which undercounts while bodies —
see DESIGN.md Sec. 6).
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeCell

__all__ = ["param_count", "active_param_count", "model_flops",
           "attention_flops", "compressed_adds"]


def _attn_params(cfg: ArchConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    if cfg.mla is not None:
        m = cfg.mla
        return (d * cfg.n_heads * (m.qk_nope + m.qk_rope)  # q
                + d * m.kv_lora + d * m.qk_rope            # down-proj + rope key
                + m.kv_lora * cfg.n_heads * (m.qk_nope + m.v_dim)  # up-proj k,v
                + cfg.n_heads * m.v_dim * d)               # o
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _ffn_params(cfg: ArchConfig, active_only: bool) -> int:
    d = cfg.d_model
    if cfg.moe is not None:
        m = cfg.moe
        per_expert = 3 * d * m.d_ff_expert
        routed = (m.top_k if active_only else m.n_experts) * per_expert
        shared = m.n_shared * per_expert
        router = d * m.n_experts
        return routed + shared + router
    return 3 * d * cfg.d_ff


def _mamba_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    h = s.d_inner // s.head_dim
    conv_dim = s.d_inner + 2 * s.d_state
    return (d * (2 * s.d_inner + 2 * s.d_state + h) + conv_dim * s.d_conv
            + 3 * h + s.d_inner + s.d_inner * d)


def _rwkv_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    tm = 5 * d * d + d * (32 * 5) + 5 * 32 * d + d * 64 + 64 * d + 2 * d
    cm = d * cfg.d_ff + cfg.d_ff * d + d * d
    return tm + cm


def _layer_params(cfg: ArchConfig, active_only: bool) -> int:
    if cfg.family == "ssm":
        return _rwkv_params(cfg)
    if cfg.family == "hybrid":
        return _mamba_params(cfg)
    return _attn_params(cfg) + _ffn_params(cfg, active_only)


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Non-embedding parameters (embedding included separately below)."""
    body = cfg.n_layers * _layer_params(cfg, active_only)
    if cfg.family == "hybrid":
        n_shared_blocks = 1  # weights shared across insertions
        body += n_shared_blocks * (_attn_params(cfg) + 3 * cfg.d_model * cfg.d_ff)
    if cfg.enc_layers:
        body += cfg.enc_layers * (_attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff)
        body += cfg.n_layers * _attn_params(cfg)  # decoder cross-attention
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return body + emb


def active_param_count(cfg: ArchConfig) -> int:
    return param_count(cfg, active_only=True)


def _hybrid_active_body(cfg: ArchConfig) -> int:
    """Hybrid compute counts the shared block once per insertion (13x), not once."""
    n_ins = cfg.n_layers // cfg.hybrid_period
    return (cfg.n_layers * _mamba_params(cfg)
            + n_ins * (_attn_params(cfg) + 3 * cfg.d_model * cfg.d_ff))


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """6*N*D with N = active non-embedding params + lm head, D = tokens touched."""
    if cfg.family == "hybrid":
        body = _hybrid_active_body(cfg)
    else:
        body = cfg.n_layers * _layer_params(cfg, active_only=True)
        if cfg.enc_layers:
            body += cfg.enc_layers * (_attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff)
            body += cfg.n_layers * _attn_params(cfg)
    head = cfg.vocab * cfg.d_model  # logits matmul
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * (body + head) * tokens
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * body * tokens  # forward only, no logits in prefill cell
    # decode: one token per sequence
    return 2.0 * (body + head) * cell.global_batch


def compressed_adds(cfg, artifact) -> dict:
    """Paper Table-1 metric for a compressed artifact: matvec *additions* per
    token at the compressed sites, alongside the dense-MAC counts above.

    Sourced from the artifact's :class:`~repro.core.cost.ModelCostReport`
    (baseline = CSD shift-add cost of the uncompressed quantized weights, the
    paper's denominator).  MoE per-expert units are additionally reported
    with routing applied — only ``top_k / n_experts`` of each expert stack
    runs per token, so the ``active_*`` pair is the serving-time cost while
    ``baseline/compressed`` count every stored expert (the paper's storage
    view).  Returns ``{baseline_adds, compressed_adds, ratio,
    active_baseline_adds, active_compressed_adds, active_ratio}``.
    """
    moe = getattr(cfg, "moe", None)
    base = comp = a_base = a_comp = 0.0
    for lc in artifact.report.layers:
        adds = lc.stage_adds.get("lcc", lc.baseline_adds)
        scale = 1.0
        if moe is not None:
            parts = lc.name.split(".")
            if (lc.name.startswith("moe.") and parts[-1].startswith("e")
                    and parts[-1][1:].isdigit()):
                scale = moe.top_k / moe.n_experts
        base += lc.baseline_adds
        comp += adds
        a_base += lc.baseline_adds * scale
        a_comp += adds * scale
    return {
        "baseline_adds": int(round(base)),
        "compressed_adds": int(round(comp)),
        "ratio": base / comp if comp else float("inf"),
        "active_baseline_adds": int(round(a_base)),
        "active_compressed_adds": int(round(a_comp)),
        "active_ratio": a_base / a_comp if a_comp else float("inf"),
    }


def attention_flops(cfg: ArchConfig, cell: ShapeCell, causal_skip: bool = False) -> float:
    """Quadratic attention-score/value FLOPs (excluded from 6ND by convention)."""
    if cfg.family == "ssm":
        return 0.0
    s = cell.seq_len
    b = cell.global_batch
    hd = cfg.hd if cfg.mla is None else (cfg.mla.qk_nope + cfg.mla.qk_rope)
    n_attn_layers = cfg.n_layers if cfg.family != "hybrid" else cfg.n_layers // cfg.hybrid_period
    if cell.kind == "decode":
        kv = min(s, cfg.attn_window) if cfg.attn_window else s
        per = 2 * 2 * cfg.n_heads * hd * kv  # scores + values, 1 query
        return float(n_attn_layers * b * per)
    kv_span = min(s, cfg.attn_window) if cfg.attn_window else s
    per = 2 * 2 * cfg.n_heads * hd * s * kv_span
    if causal_skip and not cfg.attn_window:
        per *= 0.5
    fl = float(n_attn_layers * b * per)
    if cell.kind == "train":
        fl *= 3.0
    return fl
