"""The paper's MLP (Sec. IV-A): one hidden layer of width 300, trained with
group-lasso regularization on the first layer.  Pure JAX; parameters double as
``CompressibleDense`` units for the Algorithm-1 pipeline — :class:`MLPConfig`
registers the model as the ``mlp`` family in the compression-adapter registry,
so ``api.compress_model`` and the parallel pipeline produce a serializable
``CompressedModel`` artifact for it like for any other architecture."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["MLPConfig", "init_mlp", "mlp_forward", "mlp_forward_custom",
           "mlp_forward_compressed", "mlp_loss", "mlp_accuracy"]


@dataclass(frozen=True)
class MLPConfig:
    in_dim: int = 784
    hidden: int = 300
    classes: int = 10
    family: str = "mlp"  # compression-adapter registry key


def init_mlp(key, in_dim: int = 784, hidden: int = 300, classes: int = 10,
             dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    s1 = (2.0 / in_dim) ** 0.5
    s2 = (2.0 / hidden) ** 0.5
    return {
        "fc1": {"w": (jax.random.normal(k1, (hidden, in_dim)) * s1).astype(dtype),
                "b": jnp.zeros((hidden,), dtype)},
        "fc2": {"w": (jax.random.normal(k2, (classes, hidden)) * s2).astype(dtype),
                "b": jnp.zeros((classes,), dtype)},
    }


def mlp_forward(params, x):
    """x [B, in_dim] -> logits [B, classes]. Weights act as y = W x (paper layout)."""
    h = jax.nn.relu(x @ params["fc1"]["w"].T + params["fc1"]["b"])
    return h @ params["fc2"]["w"].T + params["fc2"]["b"]


def mlp_forward_custom(params, x, fc1_matvec=None):
    """Forward with a replaceable first-layer matvec (compressed inference).

    ``fc1_matvec`` maps x [B, in_dim] -> [B, hidden] (batch-major, like the
    dense path it replaces).
    """
    if fc1_matvec is None:
        return mlp_forward(params, x)
    h = jax.nn.relu(fc1_matvec(x) + params["fc1"]["b"])
    return h @ params["fc2"]["w"].T + params["fc2"]["b"]


def mlp_forward_compressed(params, packed_fc1, x, *, interpret=None):
    """Compressed-dense forward: fc1 runs as ONE fused whole-chain LCC launch.

    ``packed_fc1`` is ``repro.kernels.ops.pack_decomposition`` of an LCC
    decomposition of fc1's weight (paper Sec. IV-A: the 784->300 layer).  The
    kernel contract is features-major, so the batch is transposed around the
    fused call; fc2 stays dense (it is not the compression target).
    """
    from repro.kernels import ops

    h = ops.apply_packed_decomposition(packed_fc1, x.T, interpret=interpret).T
    h = jax.nn.relu(h + params["fc1"]["b"])
    return h @ params["fc2"]["w"].T + params["fc2"]["b"]


def mlp_loss(params, x, y):
    logits = mlp_forward(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (lse - gold).mean()


def mlp_accuracy(params, x, y, fc1_matvec=None):
    logits = mlp_forward_custom(params, x, fc1_matvec)
    return (jnp.argmax(logits, -1) == y).mean()
