"""Single dispatch surface for every architecture family.

All launchers, trainers and the dry-run go through these five functions so a
new family only has to plug in here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell

from . import transformer, whisper

__all__ = ["init_params", "abstract_params", "train_loss", "prefill", "decode",
           "init_decode_state", "abstract_decode_state"]


def init_params(key, cfg: ArchConfig):
    if cfg.enc_layers > 0:
        return whisper.init_params(key, cfg)
    return transformer.init_params(key, cfg)


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run contract)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def train_loss(params, cfg: ArchConfig, batch, *, unroll: bool = False):
    if cfg.enc_layers > 0:
        return whisper.loss_fn(params, cfg, batch, unroll=unroll)
    return transformer.loss_fn(params, cfg, batch, unroll=unroll)


def prefill(params, cfg: ArchConfig, batch, *, unroll: bool = False,
            collect_cache: bool = False):
    """Returns final hidden states (and caches when collect_cache)."""
    if cfg.enc_layers > 0:
        return whisper.encode(params, cfg, batch["frames"], unroll=unroll), None
    h, cache = transformer.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        positions3=batch.get("positions3"), unroll=unroll, collect_cache=collect_cache)
    return h, cache


def decode(params, cfg: ArchConfig, state, token, pos, *, unroll: bool = False):
    if cfg.enc_layers > 0:
        return whisper.decode_step(params, cfg, state, token, pos, unroll=unroll)
    return transformer.decode_step(params, cfg, state, token, pos, unroll=unroll)


def init_decode_state(cfg: ArchConfig, batch: int, smax: int):
    if cfg.enc_layers > 0:
        return whisper.init_decode_state(cfg, batch, enc_len=smax)
    return transformer.init_decode_state(cfg, batch, smax)


def abstract_decode_state(cfg: ArchConfig, cell: ShapeCell):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, cell.global_batch, cell.seq_len))
