"""Single dispatch surface for every architecture family.

All launchers, trainers and the dry-run go through these five functions so a
new family only has to plug in here.

The same file is the dispatch surface for *compression*: a compressible-unit
adapter registry (:mod:`repro.models.compress_adapters`) maps every family to
its dense matrices / conv kernels, and :func:`compress_model` runs Algorithm 1
over all of them, returning a serializable
:class:`repro.core.artifact.CompressedModel` that the serving engine executes
natively (fused LCC kernels for FP decompositions, dense-effective weights
otherwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell

from . import transformer, whisper

__all__ = ["init_params", "abstract_params", "train_loss", "prefill", "decode",
           "prefill_extend", "paged_supported", "paged_layout",
           "init_decode_state", "abstract_decode_state", "sample_tokens",
           "family_of", "register_compress_adapter", "compressible_units",
           "rebind", "compress_model"]


def init_params(key, cfg: ArchConfig):
    if cfg.enc_layers > 0:
        return whisper.init_params(key, cfg)
    return transformer.init_params(key, cfg)


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run contract)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def train_loss(params, cfg: ArchConfig, batch, *, unroll: bool = False):
    if cfg.enc_layers > 0:
        return whisper.loss_fn(params, cfg, batch, unroll=unroll)
    return transformer.loss_fn(params, cfg, batch, unroll=unroll)


def prefill(params, cfg: ArchConfig, batch, *, unroll: bool = False,
            collect_cache: bool = False):
    """Returns final hidden states (and caches when collect_cache)."""
    if cfg.enc_layers > 0:
        return whisper.encode(params, cfg, batch["frames"], unroll=unroll), None
    h, cache = transformer.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        positions3=batch.get("positions3"), unroll=unroll, collect_cache=collect_cache)
    return h, cache


def decode(params, cfg: ArchConfig, state, token, pos, *, unroll: bool = False,
           executor=None):
    """One decode step.  ``executor`` is the compressed-serving hook: a
    site-keyed registry (``repro.serving.executor.CompressedExecutor``) that
    routes every covered projection — attention, FFN, MoE experts, recurrent
    mixes, whisper decoder — through fused LCC kernel launches inside the
    jitted step (see ``transformer.decode_step`` / ``whisper.decode_step``)."""
    if cfg.enc_layers > 0:
        return whisper.decode_step(params, cfg, state, token, pos, unroll=unroll,
                                   executor=executor)
    return transformer.decode_step(params, cfg, state, token, pos, unroll=unroll,
                                   executor=executor)


def sample_tokens(logits, keys, temperature):
    """Device-side per-row sampling: logits [B, V], keys [B, 2] (one PRNG key
    per row), temperature [B].  Rows with temperature <= 0 take the argmax;
    the rest draw from ``softmax(logits / temperature)`` under their own key,
    so draws are independent of batch composition and row order.  Traceable —
    serving fuses this into the jitted decode step."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(k, row, t):
        return jax.random.categorical(k, row / jnp.maximum(t, 1e-6))

    sampled = jax.vmap(one)(keys, logits, temperature).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def paged_supported(cfg: ArchConfig) -> bool:
    """True when the family's decode cache can live in a paged block pool:
    pure-attention decoders (dense GQA / MLA).  SSM and hybrid recurrent state
    is not a KV sequence and encoder-decoder (whisper) carries a cross cache —
    those keep the contiguous layout."""
    return (cfg.enc_layers == 0 and cfg.family not in ("ssm", "hybrid"))


def paged_layout(cfg: ArchConfig, smax: int, kv_block: int,
                 kv_blocks: int | None = None, n_slots: int = 1):
    """(block_size, view_blocks, pool_entries) — see ``transformer.paged_layout``."""
    return transformer.paged_layout(cfg, smax, kv_block, kv_blocks, n_slots)


def prefill_extend(params, cfg: ArchConfig, tokens, positions, past, last, *,
                   unroll: bool = False):
    """Tail prefill against a resident KV prefix (prefix-cache hit path)."""
    if not paged_supported(cfg):
        raise ValueError(f"prefill_extend: family {cfg.family!r} is not paged")
    return transformer.forward_extend(params, cfg, tokens, positions, past,
                                      last, unroll=unroll)


def init_decode_state(cfg: ArchConfig, batch: int, smax: int, *,
                      kv_block: int | None = None, kv_blocks: int | None = None):
    if cfg.enc_layers > 0:
        return whisper.init_decode_state(cfg, batch, enc_len=smax)
    if not paged_supported(cfg):
        kv_block = kv_blocks = None  # contiguous fallback (ssm/hybrid state)
    return transformer.init_decode_state(cfg, batch, smax, kv_block=kv_block,
                                         kv_blocks=kv_blocks)


def abstract_decode_state(cfg: ArchConfig, cell: ShapeCell):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, cell.global_batch, cell.seq_len))


# ---------------------------------------------------------------------------
# compression surface: family adapter registry + whole-model Algorithm 1
# ---------------------------------------------------------------------------


def family_of(cfg) -> str:
    """Adapter-registry key for a config object (ArchConfig or ResNetConfig)."""
    fam = getattr(cfg, "family", None)
    if fam is not None:
        return fam
    from .resnet import ResNetConfig

    if isinstance(cfg, ResNetConfig):
        return "resnet"
    raise TypeError(f"cannot infer architecture family from {type(cfg).__name__}")


def register_compress_adapter(family: str, site_fn) -> None:
    """Register ``site_fn(params, cfg) -> list[DenseSite | ConvSite]`` for a
    family.  Built-in families are pre-registered by
    :mod:`repro.models.compress_adapters`."""
    from . import compress_adapters

    compress_adapters.register_family(family, site_fn)


def compressible_units(params, cfg):
    """Every compressible unit (CompressibleDense / CompressibleConv) of the
    model, via the family's registered adapter."""
    from . import compress_adapters

    return compress_adapters.units_from_sites(
        params, compress_adapters.sites_for(params, cfg))


def rebind(params, cfg, name: str, effective):
    """Write a unit's dense-effective map back into a new params pytree."""
    from . import compress_adapters

    for site in compress_adapters.sites_for(params, cfg):
        if site.name == name:
            return compress_adapters.rebind_site(params, site, effective)
    raise KeyError(f"no compressible unit named {name!r} for this model")


def compress_model(params, cfg, compression=None, *, include=None,
                   conv_channel_subsample=None, progress=None,
                   build_packed: bool = True, n_workers: int = 1,
                   budget_adds=None, cache_dir=None, run_dir=None,
                   resume: bool = False, metrics=None):
    """Steps 2-3 of Algorithm 1 over every compressible unit of any family,
    executed by the :mod:`repro.pipeline` job graph.

    Returns a :class:`repro.core.artifact.CompressedModel`: per-unit
    compressed records, packed fused-kernel buffers (FP decompositions),
    dense-effective params (drop-in for the stock XLA forward), the
    :class:`ModelCostReport`, and — when the allocator ran — the chosen
    per-unit plans.  ``include`` filters unit names (callable or prefix
    string); ``build_packed=False`` skips the kernel-buffer packing when only
    the report/effective weights are wanted.

    Pipeline controls: ``n_workers`` fans slice jobs out over processes;
    ``budget_adds`` invokes the adds-budget allocator (per-unit plans instead
    of one global config); ``cache_dir`` enables the content-addressed slice
    cache; ``run_dir``/``resume`` make the run restartable after a kill.
    ``progress`` receives structured ``repro.pipeline.CompressionEvent``s;
    ``metrics`` (a ``repro.obs.MetricsRegistry``) additionally publishes the
    event stream and run stats as live counters/gauges.
    """
    import numpy as np

    from repro import core
    from repro.core.artifact import CompressedModel
    from repro.kernels import ops
    from repro.pipeline import run_pipeline

    from . import compress_adapters

    if compression is None:
        compression = core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                             max_share_rel_err=0.06)
    sites = compress_adapters.sites_for(params, cfg)
    if include is not None:
        keep = include if callable(include) else lambda n: n.startswith(include)
        sites = [s for s in sites if keep(s.name)]
    units = compress_adapters.units_from_sites(params, sites)
    res = run_pipeline(units, compression, n_workers=n_workers,
                       budget_adds=budget_adds, cache_dir=cache_dir,
                       run_dir=run_dir, resume=resume,
                       conv_channel_subsample=conv_channel_subsample,
                       progress=progress, metrics=metrics)
    packed: dict[str, object] = {}
    params_c = params
    for site in sites:
        rec = res.records[site.name]
        if isinstance(site, compress_adapters.DenseSite):
            w = site.weight(params)
            eff = np.zeros_like(w)
            eff[:, rec.kept_columns] = rec.effective
            params_c = compress_adapters.rebind_site(params_c, site, eff)
            if build_packed:
                packed[site.name] = ops.pack_decomposition(rec.decomposition)
        else:
            kernel = site.kernel(params)
            eff_k = compress_adapters.effective_conv_kernel(
                kernel, rec, res.unit_configs[site.name].conv_method)
            params_c = compress_adapters.rebind_site(params_c, site, eff_k)
    # record only plans that differ from the global config (allocator output)
    unit_configs = {n: c for n, c in res.unit_configs.items() if c != compression}
    return CompressedModel(config=cfg, params=params_c, records=res.records,
                           packed=packed, report=res.report,
                           compression=compression, unit_configs=unit_configs,
                           pipeline_stats=res.stats)


from . import compress_adapters as _compress_adapters  # noqa: E402,F401  (registers built-in families)
