"""ResNet with pre-activation blocks (He et al. 2016 [35]), the paper's large
model (ResNet-34 on TinyImageNet).  NCHW / OIHW, lax.conv; BatchNorm replaced
by GroupNorm(1) = LayerNorm-over-CHW for single-device training without
cross-batch state (noted in DESIGN.md; the compression pipeline touches only
conv kernels and is normalization-agnostic).

``resnet34_config()`` is the paper model; ``resnet_small_config()`` is the
reduced variant used by CPU tests/benches.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["ResNetConfig", "resnet34_config", "resnet_small_config", "init_resnet",
           "resnet_forward", "resnet_loss", "conv_kernels"]


@dataclass(frozen=True)
class ResNetConfig:
    stages: tuple[int, ...] = (3, 4, 6, 3)  # ResNet-34
    widths: tuple[int, ...] = (64, 128, 256, 512)
    classes: int = 200
    in_ch: int = 3
    stem_kernel: int = 3
    dtype: str = "float32"


def resnet34_config(classes: int = 200) -> ResNetConfig:
    return ResNetConfig(classes=classes)


def resnet_small_config(classes: int = 10) -> ResNetConfig:
    return ResNetConfig(stages=(1, 1), widths=(16, 32), classes=classes)


def _conv_init(key, n_out, n_in, k, dtype):
    fan = n_in * k * k
    return (jax.random.normal(key, (n_out, n_in, k, k)) * (2.0 / fan) ** 0.5).astype(dtype)


def init_resnet(key, cfg: ResNetConfig):
    dt = jnp.dtype(cfg.dtype)
    keys = iter(jax.random.split(key, 256))
    p = {"stem": _conv_init(next(keys), cfg.widths[0], cfg.in_ch, cfg.stem_kernel, dt),
         "blocks": [], "head": {}}
    c_in = cfg.widths[0]
    for si, (n_blocks, w) in enumerate(zip(cfg.stages, cfg.widths)):
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {
                "gn1": jnp.ones((c_in,), dt),
                "conv1": _conv_init(next(keys), w, c_in, 3, dt),
                "gn2": jnp.ones((w,), dt),
                "conv2": _conv_init(next(keys), w, w, 3, dt),
            }
            if stride != 1 or c_in != w:
                blk["proj"] = _conv_init(next(keys), w, c_in, 1, dt)
            p["blocks"].append(blk)
            c_in = w
    p["head"] = {"w": (jax.random.normal(next(keys), (cfg.classes, c_in)) * 0.01).astype(dt),
                 "b": jnp.zeros((cfg.classes,), dt)}
    return p


def _gn(x, w):
    """GroupNorm(1) over (C, H, W), scale per channel."""
    mu = x.mean(axis=(1, 2, 3), keepdims=True)
    var = x.var(axis=(1, 2, 3), keepdims=True)
    return (x - mu) * lax.rsqrt(var + 1e-5) * w[None, :, None, None]


def _conv(x, k, stride=1):
    return lax.conv_general_dilated(x, k, (stride, stride), "SAME",
                                    dimension_numbers=("NCHW", "OIHW", "NCHW"))


def resnet_forward(params, x, executor=None):
    """x [B, C, H, W] -> logits.

    ``executor`` (compressed serving): conv sites with a decomposition run in
    the compressed domain — the FK/PK conv-as-matmul path applies every
    decomposed channel's LCC chain in one grouped fused launch — and the
    linear head routes through its own chain; uncovered sites stay dense.
    """
    def conv(name, h, k, stride=1):
        fn = executor.conv(name) if executor is not None else None
        if fn is None:
            return _conv(h, k, stride)
        return fn(h, stride=stride, padding="SAME")

    h = conv("stem", x, params["stem"])
    for i, blk in enumerate(params["blocks"]):
        # stride-2 exactly at stage transitions (out channels != in channels);
        # stride is derived, not stored, so the params stay a pure array pytree
        stride = 2 if ("proj" in blk
                       and blk["proj"].shape[0] != blk["proj"].shape[1]) else 1
        y = jax.nn.relu(_gn(h, blk["gn1"]))
        sc = conv(f"block{i}.proj", y, blk["proj"], stride) if "proj" in blk else h
        y = conv(f"block{i}.conv1", y, blk["conv1"], stride)
        y = jax.nn.relu(_gn(y, blk["gn2"]))
        y = conv(f"block{i}.conv2", y, blk["conv2"])
        h = sc + y
    h = jax.nn.relu(h).mean(axis=(2, 3))
    head_fn = executor.matvec("head") if executor is not None else None
    if head_fn is not None:
        from .layers import matvec_acts

        return matvec_acts(head_fn, h) + params["head"]["b"]
    return h @ params["head"]["w"].T + params["head"]["b"]


def resnet_loss(params, x, y):
    logits = resnet_forward(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (lse - gold).mean()


def conv_kernels(params) -> list[tuple[str, jnp.ndarray]]:
    """All 3x3 conv kernels (the compression targets), name -> [N, K, O, O]."""
    out = [("stem", params["stem"])]
    for i, blk in enumerate(params["blocks"]):
        out.append((f"block{i}.conv1", blk["conv1"]))
        out.append((f"block{i}.conv2", blk["conv2"]))
    return out
