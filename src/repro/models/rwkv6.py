"""RWKV-6 "Finch": time-mix with data-dependent per-channel decay + channel-mix.

Recurrence (per head, state S in R^{K x V}, before-token convention):
    y_t = r_t . (S_t + diag(u) k_t^T v_t)
    S_{t+1} = diag(w_t) S_t + k_t^T v_t
with w_t = exp(-exp(w0 + lora_w(x_t)))  (data-dependent decay, the Finch
novelty) and token-shift ddlerp mixing on every projection input.

Prefill uses a chunked formulation: within a chunk the pairwise term is a
masked matmul on decay-normalized keys/queries; across chunks the [H, K, V]
state is carried (scan, or static loop when ``unroll_chunks``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.act_shard import constrain

from .layers import dense_init, linear, site_fmt, site_linear, site_linear_group

__all__ = ["init_rwkv6", "rwkv6_timemix_prefill", "rwkv6_timemix_decode",
           "init_rwkv6_channelmix", "rwkv6_channelmix", "RWKV6State"]

_MIX = ("r", "k", "v", "w", "g")


class RWKV6State(NamedTuple):
    wkv: jnp.ndarray  # [B, H, K, V]
    x_prev: jnp.ndarray  # [B, d_model]  (time-mix token shift)


def init_rwkv6(key, d_model: int, *, head_dim: int, lora_w: int = 64,
               lora_mix: int = 32, dtype=jnp.bfloat16):
    h = d_model // head_dim
    ks = jax.random.split(key, 12)
    p = {
        "mix_mu": jnp.full((len(_MIX), d_model), 0.5, jnp.float32),
        "mix_A": (jax.random.normal(ks[0], (d_model, lora_mix * len(_MIX))) * 0.01).astype(dtype),
        "mix_B": (jax.random.normal(ks[1], (len(_MIX), lora_mix, d_model)) * 0.01).astype(dtype),
        "r": dense_init(ks[2], d_model, d_model, dtype),
        "k": dense_init(ks[3], d_model, d_model, dtype),
        "v": dense_init(ks[4], d_model, d_model, dtype),
        "g": dense_init(ks[5], d_model, d_model, dtype),
        "o": dense_init(ks[6], d_model, d_model, dtype),
        "w0": jnp.full((d_model,), -5.0, jnp.float32),
        "wA": (jax.random.normal(ks[7], (d_model, lora_w)) * 0.01).astype(dtype),
        "wB": (jax.random.normal(ks[8], (lora_w, d_model)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (h, head_dim)) * 0.1).astype(jnp.float32),
        "ln_w": jnp.ones((d_model,), jnp.float32),  # per-head group norm scale
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift: one mixed input per projection (r,k,v,w,g)."""
    delta = x_prev - x
    lora = jnp.tanh(x @ p["mix_A"])  # [B,S,5*lm]
    lora = lora.reshape(*x.shape[:-1], len(_MIX), -1)
    dd = jnp.einsum("bsmi,mid->bsmd", lora, p["mix_B"].astype(x.dtype))
    mu = p["mix_mu"].astype(x.dtype)  # [5, d]
    mixed = x[..., None, :] + delta[..., None, :] * (mu + dd)
    return tuple(mixed[..., i, :] for i in range(len(_MIX)))


def _group_norm_heads(x, w, h, eps=64e-5):
    """Per-head LayerNorm of the wkv output (RWKV's ln_x)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, h, d // h).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, s, d) * w).astype(x.dtype)


def rwkv6_timemix_prefill(p, x, *, head_dim: int, chunk: int = 256,
                          unroll_chunks: bool = False,
                          state: RWKV6State | None = None):
    """x [B, S, d] -> (y [B, S, d], final RWKV6State)."""
    b, s, d = x.shape
    h = d // head_dim
    x_prev_tok = jnp.concatenate(
        [state.x_prev[:, None] if state is not None else jnp.zeros((b, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev_tok)

    r = constrain(linear(p["r"], xr).reshape(b, s, h, head_dim),
                  "batch", None, "model", None).astype(jnp.float32)
    k = constrain(linear(p["k"], xk).reshape(b, s, h, head_dim),
                  "batch", None, "model", None).astype(jnp.float32)
    v = constrain(linear(p["v"], xv).reshape(b, s, h, head_dim),
                  "batch", None, "model", None).astype(jnp.float32)
    g = jax.nn.silu(linear(p["g"], xg))
    logw = -jnp.exp(p["w0"] + (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32))
    logw = logw.reshape(b, s, h, head_dim)  # log decay, < 0

    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    r, k, v, logw = (t.reshape(b, nc, q, h, head_dim) for t in (r, k, v, logw))
    lcum = jnp.cumsum(logw, axis=2)  # [B,nc,q,H,K]

    mask = jnp.tril(jnp.ones((q, q), bool), -1)  # strictly lower: s < t
    u = p["u"]  # [H, K]

    def chunk_math(rc, kc, vc, lc, lw, st):
        # rq_t = r_t * exp(l_{t-1});  ks_s = k_s * exp(-l_s)
        lprev = lc - lw  # l_{t-1} = cumsum up to t-1
        rq = rc * jnp.exp(lprev)
        ks = kc * jnp.exp(-lc)
        score = jnp.einsum("bthk,bshk->bhts", rq, ks)
        score = jnp.where(mask[None, None], score, 0.0)
        y = jnp.einsum("bhts,bshv->bthv", score, vc)
        # bonus diagonal term: y_t += (r_t . (u * k_t)) v_t
        y = y + jnp.einsum("bthk,hk->bth", rc * kc, u)[..., None] * vc
        # inter-chunk: y_t += (r_t * exp(l_{t-1})) . state
        y = y + jnp.einsum("bthk,bhkv->bthv", rq, st)
        # state' = diag(exp(l_Q)) state + sum_s exp(l_Q - l_s) k_s v_s
        lq = lc[:, -1]  # [B,H,K]
        kdec = kc * jnp.exp(lq[:, None] - lc)
        st = st * jnp.exp(lq)[..., None] + jnp.einsum("bshk,bshv->bhkv", kdec, vc)
        return y, st

    st0 = state.wkv.astype(jnp.float32) if state is not None else \
        jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    if unroll_chunks:
        st = st0
        ys = []
        for i in range(nc):
            y, st = chunk_math(r[:, i], k[:, i], v[:, i], lcum[:, i], logw[:, i], st)
            ys.append(y)
        y = jnp.stack(ys, 1)
    else:
        def body(st, args):
            y, st = chunk_math(*args, st)
            return st, y

        st, y = jax.lax.scan(body, st0, tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, lcum, logw)))
        y = jnp.moveaxis(y, 0, 1)

    y = y.reshape(b, s, d).astype(x.dtype)
    y = _group_norm_heads(y, p["ln_w"], h) * g
    return linear(p["o"], y), RWKV6State(wkv=st, x_prev=x[:, -1])


def rwkv6_timemix_decode(p, x, state: RWKV6State, *, head_dim: int,
                         executor=None, site: str | None = None):
    """One-token step. x [B, 1, d].

    ``executor``/``site``: route the r/k/v/g projections through the
    compressed executor as ONE grouped fused launch (their token-shifted
    inputs stack along the group axis) and ``o`` through its own chain."""
    b, _, d = x.shape
    h = d // head_dim
    sn = site_fmt(site)
    x_prev_tok = state.x_prev[:, None]
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev_tok)
    rr, kk, vv, gg = site_linear_group(
        executor, (sn("r"), sn("k"), sn("v"), sn("g")),
        (p["r"], p["k"], p["v"], p["g"]), [xr, xk, xv, xg])
    r = rr.reshape(b, h, head_dim).astype(jnp.float32)
    k = kk.reshape(b, h, head_dim).astype(jnp.float32)
    v = vv.reshape(b, h, head_dim).astype(jnp.float32)
    g = jax.nn.silu(gg)
    w = jnp.exp(-jnp.exp(p["w0"] + (jnp.tanh(xw @ p["wA"]) @ p["wB"]).astype(jnp.float32)))
    w = w.reshape(b, 1, h, head_dim)[:, 0]

    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, state.wkv.astype(jnp.float32) + p["u"][..., None] * kv)
    wkv = state.wkv.astype(jnp.float32) * w[..., None] + kv
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = _group_norm_heads(y, p["ln_w"], h) * g
    return site_linear(executor, sn("o"), p["o"], y), \
        RWKV6State(wkv=wkv, x_prev=x[:, 0])


def init_rwkv6_channelmix(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mix_mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "k": dense_init(ks[0], d_model, d_ff, dtype),
        "v": dense_init(ks[1], d_ff, d_model, dtype),
        "r": dense_init(ks[2], d_model, d_model, dtype),
    }


def rwkv6_channelmix(p, x, x_prev_last=None, *, executor=None,
                     site: str | None = None):
    """Squared-ReLU channel mix with token shift. Returns (y, last token x).

    ``executor``/``site``: k/r (shared token-shifted input) run as one grouped
    fused launch, v through its own chain; dense fallback otherwise."""
    b, s, d = x.shape
    sn = site_fmt(site)
    xp = jnp.concatenate(
        [x_prev_last[:, None] if x_prev_last is not None else jnp.zeros((b, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    mu = p["mix_mu_k"].astype(x.dtype)
    xk = x + (xp - x) * mu
    k_out, r_out = site_linear_group(executor, (sn("k"), sn("r")),
                                     (p["k"], p["r"]), xk)
    kk = constrain(jnp.square(jax.nn.relu(k_out)), "batch", None, "model")
    rr = jax.nn.sigmoid(r_out)
    v_out = site_linear(executor, sn("v"), p["v"], kk)
    return constrain(rr * v_out, "batch", None, None), x[:, -1]
