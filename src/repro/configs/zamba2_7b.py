"""zamba2-7b: 81 Mamba2 layers d3584, weight-shared attention block (32H,
d_ff=14336) inserted every 6 layers, ssm_state=64. [arXiv:2411.15242]"""
from .base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    head_dim=112, hybrid_period=6,
    ssm=SSMSpec(d_inner=7168, d_state=64, head_dim=64, d_conv=4),
    notes="Mamba2 backbone + weight-shared attention blocks [arXiv:2411.15242]",
)
