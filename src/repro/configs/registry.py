"""Registry of the 10 assigned architectures (one module per arch),
selectable via ``--arch <id>``."""
from __future__ import annotations

from .base import ArchConfig
from . import (deepseek_v2_lite_16b, llama3_2_3b, mixtral_8x22b, olmo_1b,
               qwen2_5_3b, qwen2_vl_7b, rwkv6_1_6b, whisper_small, yi_9b,
               zamba2_7b)

__all__ = ["ARCHS", "get_arch"]

ARCHS: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in (
    mixtral_8x22b, deepseek_v2_lite_16b, zamba2_7b, qwen2_5_3b, olmo_1b,
    yi_9b, llama3_2_3b, whisper_small, qwen2_vl_7b, rwkv6_1_6b,
)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
