"""qwen2-vl-7b: 28L d3584 28H (GQA kv=4) d_ff=18944 V=152064, M-RoPE; vision
tower stubbed (input_specs provides patch/token embeddings). [arXiv:2409.12191]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064,
    qkv_bias=True, pos="mrope", mrope_sections=(16, 24, 24), inputs="embeds",
    notes="M-RoPE, dynamic resolution (stub) [arXiv:2409.12191]",
)
