from .base import (  # noqa: F401
    ArchConfig, MLASpec, MoESpec, SSMSpec, SHAPE_CELLS, ShapeCell,
    cell_supported, input_specs, reduced_config,
)
from .registry import ARCHS, get_arch  # noqa: F401
