"""deepseek-v2-lite-16b: 27L d2048 16H d_ff=1408 V=102400, MLA kv_lora=512,
2 shared + 64 routed experts top-6. [arXiv:2405.04434; hf]
Interpretation: the assigned config lists 'MoE 64e top-6'; applied uniformly
to all layers (the HF release additionally makes layer 0 dense — noted)."""
from .base import ArchConfig, MLASpec, MoESpec

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    head_dim=128,
    mla=MLASpec(kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoESpec(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    notes="MLA kv_lora=512, 2 shared + 64 routed top-6 [arXiv:2405.04434]",
)
