"""rwkv6-1.6b (Finch): 24L d2048 attention-free, d_ff=7168 V=65536,
data-dependent decay. [arXiv:2404.05892]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536,
    head_dim=64, pos="none",
    notes="Finch: data-dependent decay [arXiv:2404.05892]",
)
