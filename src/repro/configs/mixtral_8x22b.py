"""mixtral-8x22b: 56L d6144 48H (GQA kv=8) d_ff=16384 V=32768, 8 experts top-2,
sliding-window attention. [arXiv:2401.04088; hf]"""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
    head_dim=128, attn_window=4096,
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384),
    notes="8 experts top-2, sliding-window attention [arXiv:2401.04088]",
)
