"""Architecture + shape-cell configuration schema.

One ``ArchConfig`` per assigned architecture (exact published numbers, see the
per-arch modules).  Shape cells (train_4k / prefill_32k / decode_32k /
long_500k) are defined here once; ``input_specs`` builds ShapeDtypeStruct
stand-ins — no device allocation, the dry-run contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

__all__ = ["MoESpec", "MLASpec", "SSMSpec", "ArchConfig", "ShapeCell", "SHAPE_CELLS",
           "input_specs", "reduced_config", "arch_to_dict", "arch_from_dict"]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    norm_topk: bool = True


@dataclass(frozen=True)
class MLASpec:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMSpec:
    d_inner: int
    d_state: int
    head_dim: int = 64
    d_conv: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    norm: str = "rms"  # rms | nonparam | ln
    pos: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10000.0
    attn_window: int | None = None
    tie_embeddings: bool = False
    moe: MoESpec | None = None
    mla: MLASpec | None = None
    ssm: SSMSpec | None = None
    hybrid_period: int = 6
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    enc_layers: int = 0  # > 0 => encoder-decoder (whisper)
    max_decoder_len: int = 448
    inputs: str = "tokens"  # tokens | embeds (stubbed modality frontend)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    q_chunk: int = 1024
    ssm_chunk: int = 256
    remat: bool = True
    causal_chunk_skip: bool = False  # static upper-triangle skip (§Perf lever)
    moe_manual: bool = False  # shard_map MoE dispatch (§Perf lever)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token cell with bounded state?"""
        return self.family in ("ssm", "hybrid") or self.attn_window is not None


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(supported, reason-if-not). Mirrors DESIGN.md 'Shape-cell skips'."""
    if cell.name == "long_500k" and not cfg.sub_quadratic():
        return False, "full softmax attention: 500k decode needs sub-quadratic attention"
    if cell.name == "long_500k" and cfg.enc_layers > 0:
        return False, "encoder-decoder: 500k positions out of decoder design range"
    return True, ""


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, cell)."""
    s, b = cell.seq_len, cell.global_batch
    i32 = jnp.int32
    cd = cfg.cdtype
    if cell.kind == "train":
        if cfg.enc_layers > 0:  # whisper: stub frame embeddings + decoder tokens
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                "tokens": jax.ShapeDtypeStruct((b, cfg.max_decoder_len), i32),
                "labels": jax.ShapeDtypeStruct((b, cfg.max_decoder_len), i32),
            }
        if cfg.inputs == "embeds":  # vlm: stub patch/token embeddings
            d = {
                "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.pos == "mrope":
                d["positions3"] = jax.ShapeDtypeStruct((3, b, s), i32)
            return d
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cell.kind == "prefill":
        if cfg.enc_layers > 0:
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)}
        if cfg.inputs == "embeds":
            d = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)}
            if cfg.pos == "mrope":
                d["positions3"] = jax.ShapeDtypeStruct((3, b, s), i32)
            return d
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len-deep cache/state
    d = {"token": jax.ShapeDtypeStruct((b, 1), i32),
         "pos": jax.ShapeDtypeStruct((b,), i32)}
    return d


def arch_to_dict(cfg: ArchConfig) -> dict:
    """JSON-serializable form of an ArchConfig (inverse: ``arch_from_dict``).

    Used by the compressed-model artifact so an offline compression run can be
    served later without access to the config object that produced it."""
    from dataclasses import asdict

    d = asdict(cfg)
    d["mrope_sections"] = list(d["mrope_sections"])
    return d


def arch_from_dict(d: dict) -> ArchConfig:
    d = dict(d)
    if d.get("moe") is not None:
        d["moe"] = MoESpec(**d["moe"])
    if d.get("mla") is not None:
        d["mla"] = MLASpec(**d["mla"])
    if d.get("ssm") is not None:
        d["ssm"] = SSMSpec(**d["ssm"])
    d["mrope_sections"] = tuple(d["mrope_sections"])
    return ArchConfig(**d)


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, cfg.hybrid_period // 3)) if cfg.family == "hybrid"
        else min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        q_chunk=64,
        ssm_chunk=32,
        enc_layers=2 if cfg.enc_layers > 0 else 0,
        max_decoder_len=32,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        # generous capacity => drop-free routing, so prefill/decode consistency
        # is exact (capacity drops are inherent to GShard dispatch, not a bug)
        small["moe"] = MoESpec(n_experts=4, top_k=2, d_ff_expert=64,
                               n_shared=min(cfg.moe.n_shared, 1),
                               capacity_factor=8.0)
    if cfg.mla is not None:
        small["mla"] = MLASpec(kv_lora=32, qk_nope=32, qk_rope=16, v_dim=32)
    if cfg.ssm is not None:
        small["ssm"] = SSMSpec(d_inner=256, d_state=16, head_dim=32, d_conv=4)
    if cfg.family == "hybrid":
        small["hybrid_period"] = 2
        small["n_layers"] = 4
    if cfg.pos == "mrope":
        half = small.get("head_dim", cfg.hd) // 2
        t = max(1, half // 4)
        small["mrope_sections"] = (t, (half - t) // 2, half - t - (half - t) // 2)
    small.update(overrides)
    return replace(cfg, **small)
