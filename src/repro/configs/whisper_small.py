"""whisper-small: 12 enc + 12 dec layers d768 12H d_ff=3072 V=51865; enc-dec
with the conv frontend stubbed (input_specs provides frame embeddings).
[arXiv:2212.04356] Interpretation: assigned '12L' = 12 encoder + 12 decoder."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, pos="learned", norm="ln", max_decoder_len=448,
    notes="enc-dec; conv frontend stub provides frame embeddings [arXiv:2212.04356]",
)
