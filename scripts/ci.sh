#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a bounded smoke of the quickstart.
#
#   scripts/ci.sh            # from the repo root
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== quickstart smoke (30s budget) =="
timeout 30 python examples/quickstart.py

echo "CI OK"
