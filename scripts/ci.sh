#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a bounded smoke of the quickstart.
#
#   scripts/ci.sh            # from the repo root
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== quickstart smoke (30s budget) =="
timeout 30 python examples/quickstart.py

echo "== serving bench smoke (240s budget) =="
# /tmp output: the tracked BENCH_serving.json is refreshed deliberately per
# PR, not dirtied by every CI run's machine-dependent numbers.  The bench
# drives the site-keyed executor end-to-end: FFN-only, FFN+attention and MoE
# (grouped multi-expert launch) compressed rows must all decode.
timeout 240 python benchmarks/bench_serving.py --smoke --out /tmp/BENCH_serving.json
python -c "import json; r = json.load(open('/tmp/BENCH_serving.json')); \
modes = {(x['arch'], x['mode']) for x in r['results']}; \
assert all(x['decode_tok_s'] > 0 for x in r['results']); \
assert any(m == 'compressed+attn' for _, m in modes), modes; \
assert ('mixtral-8x22b', 'compressed') in modes, modes; \
assert all(v['ratio'] > 1 for v in r['adds'].values()), r['adds']; \
assert all(p['errors'] == 0 for p in r['poisson']), r['poisson']; \
assert r['prefix_cache']['speedup'] >= 2, r['prefix_cache']; \
assert r['prefix_cache']['leaked_blocks'] == 0, r['prefix_cache']"
# perf gate: the smoke's compressed decode must not fall below 0.8x the
# tracked full-bench number (the smoke model is far smaller, so a pass means
# the plan path engaged), and full telemetry must cost <= 3% decode tok/s.
# The one-launch-per-layer-plan invariant is gated below from a live
# engine's own metrics file (telemetry smoke), not from bench plumbing.
python - <<'EOF'
import json
r = json.load(open("/tmp/BENCH_serving.json"))
smoke = next(x["decode_tok_s"] for x in r["results"]
             if x["arch"] == "olmo-1b" and x["mode"] == "compressed"
             and x["n_slots"] == 8)
tracked = json.load(open("BENCH_serving.json"))
base = next(x["decode_tok_s"] for x in tracked["results"]
            if x["arch"] == "olmo-1b" and x["mode"] == "compressed"
            and x["n_slots"] == 8)
assert smoke >= 0.8 * base, (
    f"compressed decode regressed: smoke {smoke} tok/s < 0.8x tracked {base}")
assert r["roofline"] and all(s["sites"] for s in r["roofline"])
# whole-step MoE plan: the mixtral compressed row must decode in exactly one
# Pallas launch covering its one layer plan (attention + router + experts)
moe = next(x for x in r["results"]
           if x["arch"] == "mixtral-8x22b" and x["mode"] == "compressed"
           and x["n_slots"] == 8)
assert moe["pallas_launches"] == moe["n_layer_plans"] > 0, moe
assert not moe["plan_fallbacks"], moe["plan_fallbacks"]
# compressed-vs-dense gate on the tracked full bench: the segment-packed
# one-launch plan must keep olmo-1b compressed within 0.95x of dense at n8
t_dense = next(x["decode_tok_s"] for x in tracked["results"]
               if x["arch"] == "olmo-1b" and x["mode"] == "dense"
               and x["n_slots"] == 8)
assert base >= 0.95 * t_dense, (
    f"tracked compressed {base} tok/s < 0.95x tracked dense {t_dense}")
# cross-PR history must be tracked in the committed bench report
assert tracked["history"] and all("date" in h for h in tracked["history"])
assert tracked.get("segment_layout"), "segment_layout section missing"
# telemetry's cost is a fixed ~tens-of-us per step, so judge it against the
# tracked full-bench engine's step wall (the smoke engine's sub-ms steps
# would overstate the fraction by the model-size ratio)
ob = r["obs_overhead"]
ovh = ob["overhead_s_per_step"] / (ob["n_slots"] / base)
assert ovh <= 0.03, (
    f"telemetry overhead {ob['overhead_s_per_step'] * 1e6:.0f} us/step = "
    f"{ovh:.2%} of the tracked engine's step > 3% budget")
print(f"perf gate OK: {smoke} tok/s >= 0.8x tracked {base}, telemetry "
      f"{ob['overhead_s_per_step'] * 1e6:+.0f} us/step ({ovh:.2%} of step)")
EOF

echo "== telemetry smoke (120s budget) =="
# a compressed serve run with full telemetry: every span must close, and the
# live engine's own metrics file must show exactly one Pallas launch per
# layer plan (the executor invariant, gated from telemetry rather than bench
# internals)
timeout 120 python -m repro.launch.serve --reduced --compress --kernel \
    --requests 2 --max-new 8 --slots 2 \
    --metrics-out /tmp/obs_metrics.json --trace-out /tmp/obs_trace.jsonl
python - <<'EOF'
import json
spans = [json.loads(l) for l in open("/tmp/obs_trace.jsonl")]
assert len(spans) == 2, spans
assert all(s["status"] == "ok" for s in spans), spans
m = json.load(open("/tmp/obs_metrics.json"))["metrics"]
launches = max(v["value"]
               for v in m["serving_pallas_launches_per_step"]["values"])
plans = m["serving_layer_plans"]["values"][0]["value"]
assert launches == plans > 0, (launches, plans)
assert m["serving_requests_total"]["values"] == [
    {"labels": {"status": "ok"}, "value": 2}], m["serving_requests_total"]
assert m["pallas_launches_total"]["values"][0]["value"] > 0
roof = json.load(open("/tmp/obs_metrics.json"))["live_roofline"]
assert roof and roof["sites"] and roof["achieved_adds_per_s"] > 0, roof
print(f"telemetry smoke OK: 2/2 spans closed, {int(launches)} launches == "
      f"{int(plans)} layer plans, live roofline "
      f"{roof['achieved_adds_per_s']} adds/s")
EOF

echo "== paged KV prefix-sharing smoke (60s budget) =="
# two requests sharing a system prompt: the second must prefill from cached
# pool blocks (>= 1 prefix hit) and shutdown must leak zero blocks
timeout 60 python - <<'EOF'
import jax
from repro.configs import get_arch, reduced_config
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Scheduler
cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2, n_kv_heads=2,
                     head_dim=16, d_ff=48, vocab=64, n_layers=2)
params = api.init_params(jax.random.PRNGKey(0), cfg)
eng = ServingEngine(params, cfg, n_slots=2, max_len=64, kv_block=8)
sched = Scheduler(eng)
system = [(5 * i + 3) % cfg.vocab for i in range(16)]  # shared system prompt
rids = [sched.enqueue(system + t, max_new=4) for t in ([7, 8], [9, 10, 11])]
sched.run()
res = [sched.take_result(r) for r in rids]
assert all(r.finished and r.error is None for r in res), res
s = eng.pool_stats()
assert s["prefix_hit_blocks"] >= 1, s
assert s["in_use_blocks"] == 0, s
print(f"prefix smoke OK: {s['prefix_hit_blocks']} blocks "
      f"({s['prefix_hit_tokens']} tokens) served from cache, zero leaks")
EOF

echo "== compression pipeline bench smoke (120s budget) =="
timeout 120 python benchmarks/bench_compress_pipeline.py --smoke \
    --out /tmp/BENCH_compress.json
python -c "import json, os; r = json.load(open('/tmp/BENCH_compress.json')); \
assert r['results'] and all(x['wall_s'] > 0 for x in r['results']); \
assert r['cache']['speedup'] > 1 and r['cache']['warm_hits'] == r['jobs']; \
assert r['cpu_count'] < 4 or r['speedup_4v1'] > 1.0, r['speedup_4v1']"

echo "== train -> compress -> recover -> serve smoke (60s budget) =="
# the paper's full Algorithm-1 loop on the MLP: prox-regularized training must
# produce dead input groups, the prune-aware planner must turn them into
# skipped/shrunk 0-add slice jobs, and recovery + fused serving must complete
timeout 60 python -m repro.launch.train --arch mlp --prox --lambda 0.12 \
    --hidden 100 --epochs 6 --train-n 2000 --test-n 500 --recover 30 \
    --compress-out /tmp/train_smoke \
    --compress-config algorithm=fp prune_tol=-1e-6 weight_sharing=false \
    snr_offset_db=-12
python -c "import json; s = json.load(open('/tmp/train_smoke/train_stats.json')); \
p = s['pipeline']; \
assert p['dead_groups'] >= 1, p; \
assert p['skipped_jobs'] + p['shrunk_jobs'] >= 1, p; \
assert s['accuracy']['compressed'] > 0.8, s['accuracy']; \
assert s['accuracy']['fused'] > 0.8, s['accuracy']; \
assert s['recover']['loss_last'] < s['recover']['loss_first'], s['recover']; \
assert 'recovered' in s['accuracy'], s['accuracy']"

echo "CI OK"
