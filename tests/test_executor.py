"""Full-model compressed execution: the site-keyed CompressedExecutor routes
attention, MoE experts, recurrent mixes, whisper-decoder and conv sites
through fused kernel launches inside the jitted decode step, with
compressed-vs-dense logits parity <= 1e-4 and no dense-effective matmul on
the hot path for covered sites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import get_arch
from repro.configs.base import MoESpec, SSMSpec, reduced_config
from repro.core.artifact import CompressedModel
from repro.models import api
from repro.serving.engine import ServingEngine
from repro.serving.executor import CompressedExecutor, GroupedLCCMatvec, LCCMatvec


def _fp():
    return core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                  max_share_rel_err=0.06)


def _decode_parity(art, *, batch: int = 1, smax: int = 8):
    """Build an executor over ``art`` and compare one jitted decode step on
    the kernel path vs the dense-effective path.  Returns (executor, err)."""
    cfg = art.config
    ex = CompressedExecutor(art, interpret=None)
    state = api.init_decode_state(cfg, batch, smax)
    tok = jnp.asarray([[3]] * batch, jnp.int32)
    pos = jnp.asarray([0] * batch, jnp.int32)
    l_k, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                          executor=ex))(art.params)
    l_d, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos))(art.params)
    return ex, float(jnp.abs(l_k - l_d).max())


# ------------------------------------------------------------ family parity


def test_moe_executor_parity_and_grouped_dispatch(monkeypatch):
    """All experts of an MoE layer apply their chains through the grouped
    (one-dispatch) launch; compressed logits match dense-effective <= 1e-4.
    Plans are disabled here so the per-region grouped route stays covered
    (with plans on, the whole-step MoE plan absorbs the expert dispatches)."""
    from repro.kernels import ops

    calls = {"group": 0}
    real = ops.lcc_group_matmul

    def counting(*a, **k):
        calls["group"] += 1
        return real(*a, **k)

    monkeypatch.setattr(ops, "lcc_group_matmul", counting)

    cfg = reduced_config(
        get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab=64, n_layers=1,
        moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16, capacity_factor=8.0))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    art = api.compress_model(params, cfg, _fp())
    cfg = art.config
    ex = CompressedExecutor(art, interpret=None, use_plans=False)
    state = api.init_decode_state(cfg, 2, 8)
    tok = jnp.asarray([[3]] * 2, jnp.int32)
    pos = jnp.asarray([0] * 2, jnp.int32)
    l_k, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                          executor=ex))(art.params)
    l_d, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos))(art.params)
    assert float(jnp.abs(l_k - l_d).max()) <= 1e-4
    assert ex.routed == ex.sites, ex.sites - ex.routed
    assert calls["group"] > 0, "MoE experts never hit the grouped launch"
    assert ex.plan_fallbacks.get("step") == "plans_disabled"


def test_rwkv6_executor_parity():
    """Recurrent family: time-mix r/k/v/g + channel-mix sites run fused."""
    cfg = reduced_config(get_arch("rwkv6-1.6b"), d_model=64, head_dim=16,
                         d_ff=96, vocab=64)
    params = api.init_params(jax.random.PRNGKey(2), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex, err = _decode_parity(art)
    assert err <= 1e-4, err
    assert ex.routed == ex.sites, ex.sites - ex.routed


def test_hybrid_executor_parity():
    """zamba2: mamba in/out projections + the weight-shared attention block."""
    cfg = reduced_config(get_arch("zamba2-7b"), d_model=64, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96, vocab=64,
                         ssm=SSMSpec(d_inner=64, d_state=16, head_dim=16,
                                     d_conv=4))
    params = api.init_params(jax.random.PRNGKey(3), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex, err = _decode_parity(art)
    assert err <= 1e-4, err
    assert ex.routed == ex.sites, ex.sites - ex.routed


def test_mla_executor_parity():
    """MLA projections (q/dkv/kr/uk/uv/o) route through fused chains —
    together with the MoE expert + shared-expert sites of the same layer."""
    cfg = reduced_config(get_arch("deepseek-v2-lite-16b"), d_model=32,
                         n_heads=2, n_kv_heads=2, vocab=64, n_layers=1,
                         moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16,
                                     n_shared=1, capacity_factor=8.0))
    params = api.init_params(jax.random.PRNGKey(4), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex, err = _decode_parity(art)
    assert err <= 1e-4, err
    assert ex.routed == ex.sites, ex.sites - ex.routed


def test_whisper_executor_parity():
    """Whisper decoder self/cross-attention + MLP sites run fused; encoder
    and cross-KV sites only execute at prefill, so the decode step routes
    exactly the dec.* sites (cross k/v excluded — their KV is static)."""
    cfg = reduced_config(get_arch("whisper-small"), d_model=64, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96, vocab=64,
                         n_layers=1, enc_layers=1)
    params = api.init_params(jax.random.PRNGKey(5), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex, err = _decode_parity(art)
    assert err <= 1e-4, err
    expected = {n for n in ex.sites
                if n.startswith("dec.") and not (
                    n.startswith("dec.xattn.k") or n.startswith("dec.xattn.v"))}
    assert ex.routed == expected, ex.routed ^ expected


# ---------------------------------------------------------------- conv path


@pytest.mark.parametrize("method", ["pk", "fk"])
def test_conv_executor_parity(method):
    """Compressed ResNet channels execute through the conv-as-matmul grouped
    launch (FK and PK reshapes), matching the dense-effective conv <= 1e-4 —
    including the stride-2 stage transition and the 1x1 projection."""
    from repro.models.resnet import ResNetConfig, init_resnet, resnet_forward

    comp = core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                  max_share_rel_err=0.06, conv_method=method)
    rcfg = ResNetConfig(stages=(1, 1), widths=(8, 12), classes=4, in_ch=3)
    rp = init_resnet(jax.random.PRNGKey(2), rcfg)
    art = api.compress_model(rp, rcfg, comp)
    ex = CompressedExecutor(art, interpret=None)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 3, 10, 10)),
                    jnp.float32)
    y_k = resnet_forward(art.params, x, executor=ex)
    y_d = resnet_forward(art.params, x)
    assert float(jnp.abs(y_k - y_d).max()) <= 1e-4
    assert ex.routed == ex.sites  # every conv + the head dispatched fused


# ---------------------------------------------------- engine / serving level


def test_engine_serves_moe_artifact():
    """ServingEngine(artifact=...) is family-agnostic: an MoE artifact decodes
    on the kernel path and produces the same tokens as the dense-effective
    engine."""
    cfg = reduced_config(
        get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab=64, n_layers=1,
        moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16, capacity_factor=8.0))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    art = api.compress_model(params, cfg, _fp())
    eng = ServingEngine(artifact=art, n_slots=2, max_len=32)
    assert eng.executor is not None and eng.executor.sites == set(art.records)
    res = eng.generate([[3, 1, 4], [1, 5]], max_new_tokens=4)
    eng_d = ServingEngine(artifact=art, n_slots=2, max_len=32, use_kernel=False)
    res_d = eng_d.generate([[3, 1, 4], [1, 5]], max_new_tokens=4)
    assert [r.tokens for r in res] == [r.tokens for r in res_d]
    assert eng.executor.routed == eng.executor.sites


# ------------------------------------------------------- grouped matvec unit


def test_grouped_matvec_matches_per_site():
    """GroupedLCCMatvec (one launch) == per-site LCCMatvec outputs."""
    rng = np.random.default_rng(0)
    report = core.ModelCostReport()
    recs = [core.compress_dense_matrix(f"u{i}", rng.standard_normal((16 + 8 * i, 24)),
                                       _fp(), report)
            for i in range(3)]
    grouped = GroupedLCCMatvec(recs, interpret=None)
    singles = [LCCMatvec(r, interpret=None) for r in recs]
    xs = [jnp.asarray(rng.standard_normal((24, 5))) for _ in recs]
    ys = grouped(xs)
    for y, mv, x in zip(ys, singles, xs):
        np.testing.assert_allclose(np.asarray(y), np.asarray(mv(x)),
                                   rtol=0, atol=1e-5)


# ------------------------------------------------------------- api / artifact


def test_compress_model_include_callable():
    """include= accepts a callable site filter, not just a prefix string."""
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp(),
                             include=lambda n: n.startswith("attn.q")
                             or n == "ffn.down.l1")
    assert set(art.records) == {"attn.q.l0", "attn.q.l1", "ffn.down.l1"}
    # unfiltered sites keep their original weights in the effective params
    np.testing.assert_array_equal(
        np.asarray(art.params["blocks"]["ffn"]["gate"]["w"]),
        np.asarray(params["blocks"]["ffn"]["gate"]["w"]))


def test_artifact_roundtrip_non_ffn_records(tmp_path):
    """Attention and conv records survive save/load bitwise and the loaded
    artifact still routes through the executor."""
    # attention record round-trip (dense transformer, attention sites only)
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp(), include="attn.")
    d = str(tmp_path / "attn_art")
    art.save(d)
    art2 = CompressedModel.load(d)
    assert set(art2.records) == set(art.records)
    r1, r2 = art.records["attn.q.l0"], art2.records["attn.q.l0"]
    np.testing.assert_array_equal(r1.effective, r2.effective)
    np.testing.assert_array_equal(r1.kept_columns, r2.kept_columns)
    np.testing.assert_array_equal(r1.decomposition.to_dense(),
                                  r2.decomposition.to_dense())
    ex, err = _decode_parity(art2)
    assert err <= 1e-4
    assert {n for n in ex.routed if n.startswith("attn.")} == set(art.records)

    # conv record round-trip (ResNet) + compressed-domain forward after load
    from repro.models.resnet import ResNetConfig, init_resnet, resnet_forward

    rcfg = ResNetConfig(stages=(1,), widths=(8,), classes=4, in_ch=3)
    rp = init_resnet(jax.random.PRNGKey(2), rcfg)
    art_r = api.compress_model(rp, rcfg, _fp())
    dr = str(tmp_path / "conv_art")
    art_r.save(dr)
    art_r2 = CompressedModel.load(dr)
    rec1 = art_r.records["block0.conv1"]
    rec2 = art_r2.records["block0.conv1"]
    assert rec1["channels_nonzero"] == rec2["channels_nonzero"]
    assert set(rec1["decompositions"]) == set(rec2["decompositions"])
    for ch in rec1["decompositions"]:
        np.testing.assert_array_equal(rec1["decompositions"][ch].to_dense(),
                                      rec2["decompositions"][ch].to_dense())
    ex_r = CompressedExecutor(art_r2)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 3, 8, 8)),
                    jnp.float32)
    y_k = resnet_forward(art_r2.params, x, executor=ex_r)
    y_d = resnet_forward(art_r2.params, x)
    assert float(jnp.abs(y_k - y_d).max()) <= 1e-4


# ------------------------------------------------------------------ metrics


def test_compressed_adds_metric():
    """flops.compressed_adds reports the Table-1 additions alongside dense
    MACs, with MoE expert stacks scaled to the per-token active count."""
    from repro.models import flops

    cfg = reduced_config(
        get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab=64, n_layers=1,
        moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16, capacity_factor=8.0))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    art = api.compress_model(params, cfg, _fp())
    m = flops.compressed_adds(cfg, art)
    assert m["baseline_adds"] == art.report.total_baseline()
    assert m["compressed_adds"] == art.report.total_stage("lcc")
    assert m["ratio"] > 1.0  # compression must reduce additions
    # top_k=1 of 2 experts: the active view charges half of each expert stack
    assert m["active_baseline_adds"] < m["baseline_adds"]
    assert m["active_ratio"] > 1.0


# --------------------------------------------------------------- layer plans


def test_step_plan_decode_parity_all_sites():
    """Whole-step layer plan == per-region kernels == dense-effective decode
    (<= 1e-4), with every site routed and exactly one plan built."""
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    batch, smax = 2, 8
    state = api.init_decode_state(cfg, batch, smax)
    tok = jnp.asarray([[3]] * batch, jnp.int32)
    pos = jnp.asarray([0] * batch, jnp.int32)

    ex_plan = CompressedExecutor(art, interpret=None)
    ex_reg = CompressedExecutor(art, interpret=None, use_plans=False)
    run = lambda ex: jax.jit(
        lambda p: api.decode(p, cfg, state, tok, pos, executor=ex))(art.params)
    l_plan, s_plan = run(ex_plan)
    l_reg, _ = run(ex_reg)
    l_d, s_d = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos))(art.params)

    assert float(jnp.abs(l_plan - l_d).max()) <= 1e-4
    assert float(jnp.abs(l_plan - l_reg).max()) <= 1e-4
    assert ex_plan.n_layer_plans == 1
    assert ex_plan.routed == ex_plan.sites
    for leaf in ("k", "v", "kpos"):  # KV write-back outside the kernel
        d = jnp.abs(s_plan[leaf].astype(jnp.float32)
                    - s_d[leaf].astype(jnp.float32))
        assert float(d.max()) <= 1e-4, leaf
    # a second step from the plan-updated state keeps tracking dense
    tok2 = jnp.asarray([[5]] * batch, jnp.int32)
    pos2 = jnp.asarray([1] * batch, jnp.int32)
    l2p, _ = jax.jit(lambda p: api.decode(p, cfg, s_plan, tok2, pos2,
                                          executor=ex_plan))(art.params)
    l2d, _ = jax.jit(lambda p: api.decode(p, cfg, s_d, tok2, pos2))(art.params)
    assert float(jnp.abs(l2p - l2d).max()) <= 1e-4


def test_step_plan_bakes_uncovered_sites_dense():
    """An FFN-only artifact still gets a whole-step plan: attention q/k/v/o
    ride along as baked dense blocks, and the plan builds lazily inside the
    jitted trace without touching traced params."""
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp(),
                             include=lambda n: n.startswith("ffn."))
    assert all(n.startswith("ffn.") for n in art.records)
    ex = CompressedExecutor(art, interpret=None)
    state = api.init_decode_state(cfg, 2, 8)
    tok = jnp.asarray([[3]] * 2, jnp.int32)
    pos = jnp.asarray([0] * 2, jnp.int32)
    l_k, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                          executor=ex))(art.params)
    l_d, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos))(art.params)
    assert ex.n_layer_plans == 1  # the lazy in-trace build must not fall back
    assert float(jnp.abs(l_k - l_d).max()) <= 1e-4
    assert ex.routed == ex.sites == set(art.records)


def test_moe_plan_executor_parity():
    """Whole-step MoE plan (attention + router top-k + both expert
    super-stages in ONE launch) == per-region grouped kernels ==
    dense-effective decode, including a second decode step."""
    from repro.kernels import dispatch

    cfg = reduced_config(
        get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab=64, n_layers=2,
        moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16, capacity_factor=8.0))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    cfg = art.config
    state = api.init_decode_state(cfg, 2, 8)
    tok = jnp.asarray([[3]] * 2, jnp.int32)
    pos = jnp.asarray([0] * 2, jnp.int32)
    ex_plan = CompressedExecutor(art, interpret=None)
    ex_reg = CompressedExecutor(art, interpret=None, use_plans=False)
    run = lambda ex: jax.jit(
        lambda p: api.decode(p, cfg, state, tok, pos, executor=ex))(art.params)
    dispatch.reset_launch_count()
    t0 = dispatch.launch_count()
    l_plan, s_plan = run(ex_plan)
    n_launch = dispatch.launch_count() - t0
    l_reg, _ = run(ex_reg)
    l_d, s_d = jax.jit(lambda p: api.decode(p, cfg, state, tok,
                                            pos))(art.params)
    assert float(jnp.abs(l_plan - l_d).max()) <= 1e-4
    assert float(jnp.abs(l_plan - l_reg).max()) <= 1e-4
    # the routed block folds into the step plan: launches == plans == 1
    assert ex_plan.n_layer_plans == 1
    assert n_launch == 1, n_launch
    assert ex_plan.plan_fallbacks == {}
    assert ex_plan.routed == ex_plan.sites
    # second decode step from the plan-updated state keeps tracking dense
    tok2 = jnp.asarray([[5]] * 2, jnp.int32)
    pos2 = jnp.asarray([1] * 2, jnp.int32)
    l2p, _ = jax.jit(lambda p: api.decode(p, cfg, s_plan, tok2, pos2,
                                          executor=ex_plan))(art.params)
    l2d, _ = jax.jit(lambda p: api.decode(p, cfg, s_d, tok2, pos2))(art.params)
    assert float(jnp.abs(l2p - l2d).max()) <= 1e-4


def test_engine_step_plan_single_launch():
    """Engine-level (paged KV): plan tokens == dense tokens AND the measured
    Pallas launches per fused decode step equals the number of layer plans."""
    from repro.serving.engine import ServingEngine

    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    art = api.compress_model(params, cfg, _fp())
    eng_k = ServingEngine(artifact=art, n_slots=2, max_len=32)
    eng_d = ServingEngine(artifact=art, n_slots=2, max_len=32,
                          use_kernel=False)
    prompt = [5, 9, 2, 7]
    out_k = eng_k.generate([prompt], max_new_tokens=8, temperature=0.0)
    out_d = eng_d.generate([prompt], max_new_tokens=8, temperature=0.0)
    assert [r.tokens for r in out_k] == [r.tokens for r in out_d]
    assert eng_k.n_layer_plans == 1
    assert eng_k.pallas_launches_per_step == eng_k.n_layer_plans == 1


def test_pack_group_padding_waste_reported():
    """pack_group reports the zero-row / zero-slice padding fractions of the
    stacked [G, E, P, N, S] slab, and the executor mirrors them into the
    artifact's pipeline_stats."""
    from repro.core.lcc import lcc_decompose
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    decs = [lcc_decompose(rng.standard_normal(shape), algorithm="fp",
                          target_snr_db=35.0)
            for shape in [(48, 16), (8, 16), (12, 12)]]
    pg = ops.pack_group([ops.pack_decomposition(d) for d in decs])
    w = pg.waste
    assert w is not None
    assert len(w["row_waste"]) == len(decs)
    assert all(0.0 <= f <= 1.0 for f in w["row_waste"])
    # the (8, 16) member pads against the 48-row member: real waste shows up
    assert max(w["row_waste"]) > 0.0
    assert 0.0 <= w["mean_row_waste"] <= 1.0

    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex = CompressedExecutor(art, interpret=None, use_plans=False)
    state = api.init_decode_state(cfg, 1, 8)
    tok = jnp.asarray([[3]], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                 executor=ex))(art.params)
    pw = art.pipeline_stats.get("padding_waste", {})
    assert pw, "grouped regions must record their padding waste"
    assert all(0.0 <= v["mean_row_waste"] <= 1.0 for v in pw.values())


def test_artifact_plans_roundtrip(tmp_path):
    """Packed layer-plan stages persist through save/load, and a fresh
    executor on the loaded artifact reuses them (same decode numerics)."""
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex = CompressedExecutor(art, interpret=None)
    assert ex.step_plan(cfg) is not None  # builds + stores into art.plans
    assert "step" in art.plans

    d = str(tmp_path / "plan_art")
    art.save(d)
    art2 = CompressedModel.load(d)
    assert "step" in art2.plans
    for name, ps in art.plans["step"].items():
        ps2 = art2.plans["step"][name]
        assert ps2.k_alloc == ps.k_alloc and ps2.out_dim == ps.out_dim
        for f in ("prep_src", "prep_tgt", "gidx", "gexp", "gsgn", "outg",
                  "fs_mat", "dw_mat", "bias", "segs"):
            a, b = getattr(ps, f), getattr(ps2, f)
            assert (a is None) == (b is None), (name, f)
            if a is not None:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    ex2 = CompressedExecutor(art2, interpret=None)
    state = api.init_decode_state(cfg, 1, 8)
    tok = jnp.asarray([[3]], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    l1, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                         executor=ex))(art.params)
    l2, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                         executor=ex2))(art2.params)
    assert ex2.n_layer_plans == 1
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-6, atol=1e-6)


def test_pr8_plan_artifacts_without_segs_load_and_decode_bitwise(tmp_path):
    """PR 8-era saved plans carry no segment-packed layout: stripping ``segs``
    before save must load back with ``segs is None`` and decode through the
    original full-gather operand path bitwise-identically to the in-memory
    stripped plan."""
    import dataclasses

    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex = CompressedExecutor(art, interpret=None)
    assert ex.step_plan(cfg) is not None
    # simulate a PR 8 artifact: drop the segment descriptors before saving
    art.plans["step"] = {n: dataclasses.replace(ps, segs=None, seg_stats=None,
                                                waste=None)
                         for n, ps in art.plans["step"].items()}
    d = str(tmp_path / "pr8_art")
    art.save(d)
    art2 = CompressedModel.load(d)
    assert all(ps.segs is None for ps in art2.plans["step"].values())

    state = api.init_decode_state(cfg, 2, 8)
    tok = jnp.asarray([[3]] * 2, jnp.int32)
    pos = jnp.asarray([0] * 2, jnp.int32)
    ex_mem = CompressedExecutor(art, interpret=None)  # reuses stripped stages
    ex_load = CompressedExecutor(art2, interpret=None)
    l_mem, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                            executor=ex_mem))(art.params)
    l_load, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos,
                                             executor=ex_load))(art2.params)
    assert ex_load.n_layer_plans == 1  # legacy layout still plans
    np.testing.assert_array_equal(np.asarray(l_mem), np.asarray(l_load))
    # and the operand path still tracks dense within tolerance
    l_d, _ = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos))(art.params)
    assert float(jnp.abs(l_load - l_d).max()) <= 1e-4


def test_plan_fallback_reasons_and_segment_stats():
    """Ineligible families record a reason string in ``plan_fallbacks``;
    eligible plans record per-stage padding waste and segment-layout
    run-length stats into the artifact's pipeline_stats."""
    # hybrid family: step plan must fall back with a reason, not silently
    cfg_hyb = reduced_config(get_arch("zamba2-7b"), d_model=64, n_heads=4,
                             n_kv_heads=4, head_dim=16, d_ff=96, vocab=64,
                             ssm=SSMSpec(d_inner=64, d_state=16, head_dim=16,
                                         d_conv=4))
    params = api.init_params(jax.random.PRNGKey(0), cfg_hyb)
    art = api.compress_model(params, cfg_hyb, _fp())
    ex = CompressedExecutor(art, interpret=None)
    assert ex.step_plan(cfg_hyb) is None
    assert ex.plan_fallbacks.get("step") == "family:hybrid"

    # eligible dense family: stages carry segs + stats, recorded in the art
    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=2)
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    art = api.compress_model(params, cfg, _fp())
    ex = CompressedExecutor(art, interpret=None)
    plan = ex.step_plan(cfg)
    assert plan is not None and ex.plan_fallbacks == {}
    segged = [ps for ps in plan.stages.values() if ps.segs is not None]
    assert segged, "new plans must carry segment descriptors"
    seg = art.pipeline_stats.get("segment_layout", {})
    pw = art.pipeline_stats.get("padding_waste", {})
    assert any(k.startswith("plan.") for k in seg), seg
    assert any(k.startswith("plan.") for k in pw), pw
    for st in seg.values():
        assert st["p50_run_after"] >= st["p50_run_before"] or \
            st["n_runs_after"] <= st["n_runs_before"]
        assert 0.0 <= st["gather_frac"] <= 1.0
    for wv in (v for k, v in pw.items() if k.startswith("plan.")):
        assert 0.0 <= wv["row_waste"] <= 1.0
        assert 0.0 <= wv["slice_waste"] <= 1.0


def test_engine_plan_stats_and_fallback_metric():
    """Engine telemetry: ``plan_stats()`` reports plans/launches/fallback
    reasons and ``serving_plan_fallbacks_total{reason}`` counts each plan
    key once."""
    from repro.serving.engine import ServingEngine

    cfg = reduced_config(get_arch("zamba2-7b"), d_model=64, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96, vocab=64,
                         ssm=SSMSpec(d_inner=64, d_state=16, head_dim=16,
                                     d_conv=4))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    art = api.compress_model(params, cfg, _fp())
    eng = ServingEngine(artifact=art, n_slots=2, max_len=16)
    eng.generate([[5, 9]], max_new_tokens=4, temperature=0.0)
    st = eng.plan_stats()
    assert st["n_layer_plans"] == 0
    assert st["fallbacks"].get("step") == "family:hybrid"
    assert "pallas_launches_per_step" in st
    metric = eng.metrics.to_prometheus()
    assert 'serving_plan_fallbacks_total{reason="family:hybrid"} 1' in metric
