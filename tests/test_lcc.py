"""LCC decomposition: fidelity targets, apply==dense, adds accounting, slicing."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.csd import adds_csd_matrix
from repro.core.lcc import (LCCChain, FSProgram, lcc_decompose, snr_db)


@pytest.mark.parametrize("alg", ["fp", "fs"])
@pytest.mark.parametrize("shape", [(64, 8), (50, 13), (128, 24)])
def test_meets_snr_target(alg, shape):
    rng = np.random.default_rng(0)
    w = rng.standard_normal(shape)
    d = lcc_decompose(w, algorithm=alg, target_snr_db=40.0)
    assert d.achieved_snr_db(w) >= 40.0


@pytest.mark.parametrize("alg", ["fp", "fs"])
def test_apply_equals_dense(alg):
    rng = np.random.default_rng(1)
    w = rng.standard_normal((40, 17))
    d = lcc_decompose(w, algorithm=alg, target_snr_db=35.0)
    x = rng.standard_normal((17, 5))
    np.testing.assert_allclose(d.apply(x), d.to_dense() @ x, rtol=1e-9, atol=1e-9)


def test_factors_are_signed_powers_of_two():
    rng = np.random.default_rng(2)
    w = rng.standard_normal((32, 8))
    d = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    for (c0, c1), chain in zip(d.col_slices, d.slices):
        assert isinstance(chain, LCCChain)
        for f in chain.factors:
            vals = np.abs(f.sign.astype(np.float64) * np.exp2(f.exp.astype(np.float64)))
            nz = vals[f.sign != 0]
            assert np.all(np.log2(nz) == np.round(np.log2(nz)))  # exact powers of 2


def test_fs_adds_counts_binary_nodes_only():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((30, 6))
    d = lcc_decompose(w, algorithm="fs", target_snr_db=30.0)
    for s in d.slices:
        assert isinstance(s, FSProgram)
        nodes = np.asarray(s.nodes)
        assert s.num_adds() == int((nodes[:, 3] >= 0).sum())


def test_fs_beats_or_matches_fp_on_small_matrices():
    """Paper Sec. IV-B: FS is the better choice for small equivalent matrices."""
    rng = np.random.default_rng(4)
    w = rng.standard_normal((64, 10))
    fp = lcc_decompose(w, algorithm="fp", target_snr_db=40.0)
    fs = lcc_decompose(w, algorithm="fs", target_snr_db=40.0)
    assert fs.num_adds() <= fp.num_adds()


def test_lcc_beats_csd_baseline():
    """The headline claim: LCC needs ~2x fewer adds than CSD at equal SNR."""
    rng = np.random.default_rng(5)
    w = rng.standard_normal((300, 20))
    base = adds_csd_matrix(w, 8)
    d = lcc_decompose(w, algorithm="fs", frac_bits=8)
    assert base / d.num_adds() > 1.5


def test_zero_columns_and_rows_handled():
    rng = np.random.default_rng(6)
    w = rng.standard_normal((20, 6))
    w[:, 2] = 0.0
    w[5] = 0.0
    d = lcc_decompose(w, algorithm="fs", target_snr_db=40.0)
    assert d.achieved_snr_db(w) >= 40.0
    x = rng.standard_normal((6,))
    np.testing.assert_allclose(d.apply(x), d.to_dense() @ x, atol=1e-9)


def test_slicing_covers_wide_matrix():
    rng = np.random.default_rng(7)
    w = rng.standard_normal((32, 100))
    d = lcc_decompose(w, algorithm="fp", target_snr_db=30.0, slice_width=8)
    assert d.col_slices[0] == (0, 8)
    assert d.col_slices[-1][1] == 100
    assert d.achieved_snr_db(w) >= 30.0


@given(st.integers(min_value=0, max_value=2**31))
@settings(max_examples=30, deadline=None)
def test_decompose_random_seed_property(seed):
    """Property: decomposition always reaches its SNR target on generic matrices."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((24, 6)) * rng.uniform(0.1, 10)
    d = lcc_decompose(w, algorithm="fs", target_snr_db=30.0)
    assert d.achieved_snr_db(w) >= 30.0 or d.num_adds() > 0
    assert snr_db(w, d.to_dense()) == pytest.approx(d.achieved_snr_db(w))
