"""Distribution: sharding policy properties + multi-device semantics.

Multi-device tests run in a SUBPROCESS with a small host-device count so the
main test process keeps the real single-device view (the dry-run is the only
place that sees 512 fake devices).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import get_arch, reduced_config
from repro.distributed.elastic import HeartbeatMonitor, plan_for_devices
from repro.models import api

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------------ policy


def test_param_pspecs_divisibility():
    """Every assigned spec axis must divide the tensor dim (else compile fails)."""
    import jax
    from jax.sharding import Mesh
    from repro.distributed.sharding import params_pspecs
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    for arch in ("olmo-1b", "mixtral-8x22b", "whisper-small", "rwkv6-1.6b"):
        cfg = get_arch(arch)
        params = api.abstract_params(cfg)
        specs = params_pspecs(params, mesh)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda s: hasattr(s, "index"))
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            for dim, ax in zip(p.shape, tuple(s)):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % size == 0, (p.shape, tuple(s), arch)


def test_plan_for_devices():
    assert plan_for_devices(512).shape == (2, 16, 16)
    assert plan_for_devices(256).shape == (16, 16)
    assert plan_for_devices(240).shape == (15, 16)  # lost a host: shrink data axis


def test_heartbeat_monitor_flags_straggler():
    mon = HeartbeatMonitor(n_pods=2, timeout_s=100, straggler_factor=3.0)
    t = 0.0
    for step in range(8):  # pod0 1s/step, pod1 5s/step (straggler)
        mon.beat(0, t + step * 1.0)
        mon.beat(1, t + step * 5.0)
    failed = mon.failed_pods(now=40.0)
    assert 1 in failed
    assert mon.surviving_device_count(512, failed) == 256


# --------------------------------------------------------------- semantics


def test_compressed_psum_error_feedback_subprocess():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro import compat
        from repro.distributed.compress_grads import compressed_psum
        mesh = compat.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((2, 64, 32)), jnp.float32)  # per-pod grads
        e = jnp.zeros_like(g)

        def f(g, e):
            return compressed_psum({"w": g}, {"w": e}, "pod")

        fn = compat.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                              out_specs=(P("pod"), P("pod")), check_vma=False)
        (gh, eh) = fn(g, e)
        true_mean = np.asarray(g).mean(0)
        got = np.asarray(gh["w"][0])
        rel = np.abs(got - true_mean).max() / (np.abs(true_mean).max() + 1e-9)
        assert rel < 0.02, rel  # int8 quantization error bound
        # error feedback: residual equals local (v - decoded q)
        assert np.isfinite(np.asarray(eh["w"])).all()
        # second round with error feedback reduces bias on a CONSTANT gradient
        (gh2, eh2) = fn(g, eh["w"][None][0] if False else eh["w"])
        err1 = np.abs(np.asarray(gh["w"][0]) - true_mean).mean()
        err2 = np.abs((np.asarray(gh["w"][0]) + np.asarray(gh2["w"][0])) / 2
                      - true_mean).mean()
        assert err2 <= err1 + 1e-6
        print("OK")
    """)
    assert "OK" in out


def test_pjit_train_step_multidevice_subprocess():
    """End-to-end sharded train step on an 8-device host mesh."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_arch, reduced_config
        from repro.distributed import sharding
        from repro.distributed.act_shard import mesh_context
        from repro.optim.optimizers import adamw
        from repro.training.trainer import init_train_state, make_train_step
        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        cfg = reduced_config(get_arch("olmo-1b"), d_model=64, d_ff=128, vocab=256,
                             n_heads=4, n_kv_heads=4, head_dim=16)
        opt = adamw()
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        with mesh, mesh_context(mesh):
            state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            pspecs = sharding.params_pspecs(state, mesh)
            state = jax.device_put(state, sharding.named(mesh, pspecs))
            step = jax.jit(make_train_step(cfg, opt, lr=1e-3))
            losses = []
            for i in range(5):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses  # actually optimizes, sharded
        print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_gpipe_pipeline_subprocess():
    """GPipe stage runner == running layers sequentially."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import gpipe_forward, split_stages
        from repro import compat
        mesh = compat.make_mesh((4,), ("pipe",))
        rng = np.random.default_rng(0)
        L, D = 8, 16
        w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.3, jnp.float32)

        def stage_fn(ws, x):  # ws [L/S, D, D]
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(body, x, ws)
            return x

        x = jnp.asarray(rng.standard_normal((4, 2, D)), jnp.float32)  # [M, mb, D]
        got = gpipe_forward(split_stages(w, 4), x, stage_fn, mesh=mesh)
        want = x
        for i in range(L):
            want = jnp.tanh(want @ w[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_overlapped_ag_matmul_subprocess():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.overlap import overlapped_ag_matmul
        from repro import compat
        mesh = compat.make_mesh((4,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((6, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
        got = overlapped_ag_matmul(x, w, mesh=mesh, axis="model")
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_serving_decode_subprocess():
    """ServingEngine(mesh=...) on a 2-device CPU mesh: decode-state sharded
    over slots (data) or params tensor-parallel (model), generated tokens
    identical to the single-device engine and logits within 1e-4."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs import get_arch, reduced_config
        from repro.models import api
        from repro.serving.engine import ServingEngine
        assert jax.device_count() == 2
        cfg = reduced_config(get_arch("olmo-1b"))
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [[5, 9, 2], [7, 1], [4, 4, 4, 8], [30]]
        ref = ServingEngine(params, cfg, n_slots=4, max_len=64)
        r_ref = ref.generate(prompts, max_new_tokens=6)

        tok = jnp.asarray([[3], [1], [2], [7]], jnp.int32)
        pos = jnp.asarray([2, 1, 3, 0], jnp.int32)
        st0 = api.init_decode_state(cfg, 4, 64, kv_block=16)  # engine default layout
        l_ref, _ = ref._decode(ref.params, st0, tok, pos)

        for axes in (("data", "model"), ("model", "data")):
            mesh = compat.make_mesh((2, 1), axes)
            eng = ServingEngine(params, cfg, n_slots=4, max_len=64, mesh=mesh)
            r = eng.generate(prompts, max_new_tokens=6)
            assert [x.tokens for x in r] == [x.tokens for x in r_ref], axes
            st = jax.device_put(st0, eng._state_sh)
            l_sh, _ = eng._decode(eng.params, st, tok, pos)
            d = float(jnp.abs(l_ref.astype(jnp.float32)
                              - l_sh.astype(jnp.float32)).max())
            assert d <= 1e-4, (axes, d)
            print("mesh", axes[0], "max_diff", d)
        print("OK")
    """, devices=2)
    assert "OK" in out


def test_elastic_remesh_reshard_subprocess():
    """Simulated pod loss: save, rebuild smaller mesh, reshard, keep training."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.elastic import plan_for_devices, reshard_tree
        from jax.sharding import PartitionSpec as P
        # 'cluster' of 8 devices -> lose half -> 4
        plan_big = plan_for_devices(8, model_parallel=2, multi_pod_threshold=8)
        mesh_big = plan_big.build()
        w = jnp.arange(64.0).reshape(8, 8)
        specs = P("data", "model")
        from jax.sharding import NamedSharding
        w_sharded = jax.device_put(w, NamedSharding(mesh_big, specs))
        host = np.asarray(w_sharded)  # checkpoint (host copy)
        plan_small = plan_for_devices(4, model_parallel=2, multi_pod_threshold=8)
        mesh_small = plan_small.build(jax.devices()[:4])
        w2 = reshard_tree({"w": host}, mesh_small, {"w": specs})["w"]
        np.testing.assert_array_equal(np.asarray(w2), host)
        assert len(w2.sharding.device_set) == 4
        print("OK", plan_big.shape, plan_small.shape)
    """)
    assert "OK" in out


def test_mesh_sharded_layer_plan_subprocess():
    """Compressed artifact under a 2-device mesh decodes through the
    whole-step layer plan (one launch per plan, shard_map-wrapped), with
    tokens identical and logits within 1e-4 of the single-device engine."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat, core
        from repro.configs import get_arch, reduced_config
        from repro.models import api
        from repro.serving.engine import ServingEngine
        assert jax.device_count() == 2
        cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                             n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                             n_layers=2)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        comp = core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                      max_share_rel_err=0.06)
        art = api.compress_model(params, cfg, comp)
        prompts = [[5, 9, 2], [7, 1], [4, 4, 4, 8], [30]]
        ref = ServingEngine(artifact=art, n_slots=4, max_len=32)
        r_ref = ref.generate(prompts, max_new_tokens=6)
        st_ref = ref.plan_stats()
        assert st_ref["n_layer_plans"] == 1, st_ref

        tok = jnp.asarray([[3], [1], [2], [7]], jnp.int32)
        pos = jnp.asarray([2, 1, 3, 0], jnp.int32)
        st0 = api.init_decode_state(cfg, 4, 32, kv_block=16)
        l_ref, _ = ref._decode(ref.params, st0, tok, pos)

        for axes in (("data", "model"), ("model", "data")):
            mesh = compat.make_mesh((2, 1), axes)
            eng = ServingEngine(artifact=art, n_slots=4, max_len=32,
                                mesh=mesh)
            r = eng.generate(prompts, max_new_tokens=6)
            st = eng.plan_stats()
            assert st["n_layer_plans"] == 1, (axes, st)
            assert st["pallas_launches_per_step"] == 1, (axes, st)
            assert st["fallbacks"] == {}, (axes, st)
            assert [x.tokens for x in r] == [x.tokens for x in r_ref], axes
            stt = jax.device_put(st0, eng._state_sh)
            l_sh, _ = eng._decode(eng.params, stt, tok, pos)
            d = float(jnp.abs(l_ref.astype(jnp.float32)
                              - l_sh.astype(jnp.float32)).max())
            assert d <= 1e-4, (axes, d)
            print("mesh", axes[0], "plans", st["n_layer_plans"],
                  "launches", st["pallas_launches_per_step"], "max_diff", d)
        print("OK")
    """, devices=2)
    assert "OK" in out
