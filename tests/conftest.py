"""Shared test configuration: optional-dependency shim for ``hypothesis``.

Tier-1 must collect and pass with nothing beyond the baked image
(``requirements-dev.txt`` lists ``hypothesis`` as an optional extra).  When
the real package is importable we use it unchanged; otherwise we install a
minimal deterministic stand-in covering exactly the API surface these tests
use — ``@given`` over ``st.integers``/``st.floats`` plus ``@settings`` — by
replaying ``max_examples`` draws from a fixed-seed numpy Generator.  Property
coverage is narrower than real hypothesis (no shrinking, no example database)
but the sweeps stay seeded and reproducible.
"""
from __future__ import annotations

import sys
import types

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    _SHIM_SEED = 0xC0DE
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        """A draw rule: Generator -> python value."""

        def __init__(self, draw):
            self.draw = draw

    def _integers(*, min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value,
                                                      endpoint=True)))

    def _floats(*, min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def _settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies: _Strategy):
        def deco(fn):
            # NOT functools.wraps: it would forward fn's signature and make
            # pytest look for fixtures named after the drawn parameters.
            def runner():
                rng = np.random.default_rng(_SHIM_SEED)
                n = getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    fn(*[s.draw(rng) for s in strategies])
            runner.__name__ = fn.__name__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
