"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lcc import lcc_decompose
from repro.kernels import ops, ref
from repro.kernels.group_prox import group_prox
from repro.kernels.lcc_matmul import lcc_factor_matmul
from repro.kernels.shared_matmul import cluster_segment_sum


@pytest.mark.parametrize("n,k,b,s", [(128, 128, 128, 2), (256, 128, 64, 3),
                                     (128, 256, 32, 4), (384, 128, 128, 2)])
def test_lcc_factor_matmul_shapes(n, k, b, s):
    rng = np.random.default_rng(n + k + b)
    idx = jnp.asarray(rng.integers(0, k, (n, s)), jnp.int32)
    exp = jnp.asarray(rng.integers(-8, 8, (n, s)), jnp.int8)
    sign = jnp.asarray(rng.choice([-1, 0, 1], (n, s)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = lcc_factor_matmul(idx, exp, sign, x, block_n=128, block_k=128, block_b=min(b, 128))
    want = ref.lcc_factor_matmul_ref(idx, exp, sign, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lcc_factor_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    n, k, b, s = 128, 128, 128, 2
    idx = jnp.asarray(rng.integers(0, k, (n, s)), jnp.int32)
    exp = jnp.asarray(rng.integers(-6, 6, (n, s)), jnp.int8)
    sign = jnp.asarray(rng.choice([-1, 1], (n, s)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((k, b)), dtype)
    got = lcc_factor_matmul(idx, exp, sign, x)
    want = ref.lcc_factor_matmul_ref(idx, exp, sign, x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_chain_apply_matches_decomposition():
    rng = np.random.default_rng(8)
    w = rng.standard_normal((96, 24))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=40.0)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((24, 7)), jnp.float32)
    got = np.asarray(ops.apply_packed_decomposition(packed, x))
    want = dec.to_dense() @ np.asarray(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape,b", [((96, 24), 7), ((50, 13), 5), ((128, 24), 128),
                                     ((512, 16), 33), ((37, 5), 1)])
def test_fused_decomposition_matches_numpy_apply(shape, b):
    """Fused whole-chain kernel == LCCDecomposition.apply (numpy reference)
    over odd/padded shapes and multi-slice decompositions (acceptance: 1e-5)."""
    rng = np.random.default_rng(shape[0] + b)
    w = rng.standard_normal(shape)
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    assert len(dec.col_slices) >= 2 or shape[1] <= 16  # exercise multi-slice
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((shape[1], b)), jnp.float32)
    want = dec.apply(np.asarray(x, np.float64))
    got = np.asarray(ops.apply_packed_decomposition(packed, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_equals_per_factor_loop():
    """The single-launch kernel and the per-factor pallas_call loop are two
    implementations of the same chain — bitwise-comparable f32 results."""
    rng = np.random.default_rng(21)
    w = rng.standard_normal((160, 40))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0, slice_width=11)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((40, 19)), jnp.float32)
    fused = np.asarray(ops.apply_packed_decomposition(packed, x))
    loop = np.asarray(ops.apply_packed_decomposition(packed, x, fused=False))
    np.testing.assert_allclose(fused, loop, rtol=1e-6, atol=1e-6)


def test_fused_chain_padded_rows_stay_zero():
    """sign==0 invariant: rows beyond every factor's true out_dim decompress
    to zero and stay exactly zero through the whole chain."""
    from repro.kernels.lcc_chain_matmul import lcc_chain_matmul

    rng = np.random.default_rng(22)
    w = rng.standard_normal((200, 16))  # out_dim 200 pads to n_pad 256
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=30.0, slice_width=16)
    pc = ops.pack_chain(dec.slices[0], block=128)
    n_pad = pc.idx.shape[1]
    assert n_pad > pc.out_dim  # the invariant must have real rows to bite on
    x = jnp.zeros((1, pc.d_pad, 8), jnp.float32).at[0, : pc.in_dim].set(
        jnp.asarray(rng.standard_normal((pc.in_dim, 8)), jnp.float32))
    y = np.asarray(lcc_chain_matmul(pc.idx[None], pc.exp[None], pc.sign[None], x,
                                    block_b=8, first_width=pc.first_width))
    assert y.shape[0] == n_pad
    np.testing.assert_array_equal(y[pc.out_dim:], 0.0)
    want = dec.slices[0].apply(np.asarray(x[0, : pc.in_dim], np.float64))
    np.testing.assert_allclose(y[: pc.out_dim], want, rtol=1e-5, atol=1e-5)


def test_fused_kernel_onehot_formulation_matches_gather():
    """The compiled (one-hot/MXU) decompress branch == the gather branch,
    both run under the interpreter via the use_gather override — keeps the
    production-TPU formulation covered by CPU CI."""
    from repro.kernels.lcc_chain_matmul import lcc_chain_matmul

    rng = np.random.default_rng(28)
    w = rng.standard_normal((200, 16))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    packed = ops.pack_decomposition(dec)
    bb, b_pad = 8, 8
    x_pad = jnp.stack([
        jnp.pad(jnp.asarray(rng.standard_normal((c1 - c0, b_pad)), jnp.float32),
                ((0, packed.d_pad - (c1 - c0)), (0, 0)))
        for c0, c1 in packed.col_slices])
    args = (packed.idx, packed.exp, packed.sign, x_pad)
    kw = dict(block_b=bb, first_width=packed.first_width, interpret=True)
    gather = np.asarray(lcc_chain_matmul(*args, use_gather=True, **kw))
    onehot = np.asarray(lcc_chain_matmul(*args, use_gather=False, **kw))
    np.testing.assert_allclose(onehot, gather, rtol=1e-6, atol=1e-6)


def test_grouped_launch_matches_per_decomposition():
    """lcc_group_matmul applies G whole decompositions in ONE launch == the
    per-decomposition fused path, across mixed shapes, chain lengths, slice
    counts and an FS-only (dense-fallback) member."""
    rng = np.random.default_rng(31)
    decs = []
    for g, (shape, algo, sw) in enumerate([((24, 16), "fp", None),
                                           ((8, 16), "fp", None),
                                           ((24, 40), "fs", 8),
                                           ((12, 12), "fp", None)]):
        decs.append(lcc_decompose(rng.standard_normal(shape), algorithm=algo,
                                  target_snr_db=35.0, slice_width=sw))
    packed = [ops.pack_decomposition(d) for d in decs]
    pg = ops.pack_group(packed)
    xs = [jnp.asarray(rng.standard_normal((d.shape[1], 5)), jnp.float32)
          for d in decs]
    ys = ops.apply_packed_group(pg, xs)
    for g, (d, x, y) in enumerate(zip(decs, xs, ys)):
        want = np.asarray(ops.apply_packed_decomposition(packed[g], x))
        np.testing.assert_allclose(np.asarray(y), want, rtol=1e-6, atol=1e-6,
                                   err_msg=f"group member {g}")


def test_grouped_launch_onehot_formulation_matches_gather():
    """The grouped kernel's compiled (one-hot/MXU) branch == its gather
    branch under the interpreter — TPU formulation covered by CPU CI."""
    from repro.kernels.lcc_group_matmul import lcc_group_matmul

    rng = np.random.default_rng(32)
    decs = [lcc_decompose(rng.standard_normal((16, 12)), algorithm="fp",
                          target_snr_db=35.0) for _ in range(3)]
    pg = ops.pack_group([ops.pack_decomposition(d) for d in decs])
    e_max = pg.idx.shape[1]
    stacks = []
    for m in pg.members:
        slabs = [jnp.pad(jnp.asarray(rng.standard_normal((c1 - c0, 4)),
                                     jnp.float32),
                         ((0, pg.d_pad - (c1 - c0)), (0, 0)))
                 for c0, c1 in m.col_slices]
        slabs += [jnp.zeros((pg.d_pad, 4), jnp.float32)] * (e_max - len(slabs))
        stacks.append(jnp.stack(slabs))
    args = (pg.idx, pg.exp, pg.sign, jnp.stack(stacks))
    kw = dict(block_b=4, first_width=pg.first_width, interpret=True)
    gather = np.asarray(lcc_group_matmul(*args, use_gather=True, **kw))
    onehot = np.asarray(lcc_group_matmul(*args, use_gather=False, **kw))
    np.testing.assert_allclose(onehot, gather, rtol=1e-6, atol=1e-6)


def test_fused_kernel_interpret_override_matches():
    """Explicit interpret=True equals the auto-detected default on this host."""
    rng = np.random.default_rng(23)
    w = rng.standard_normal((64, 12))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((12, 6)), jnp.float32)
    auto = np.asarray(ops.apply_packed_decomposition(packed, x))
    forced = np.asarray(ops.apply_packed_decomposition(packed, x, interpret=True))
    np.testing.assert_allclose(auto, forced, rtol=1e-6, atol=1e-6)


def test_apply_packed_chain_matches_chain_apply():
    """Single-chain API: fused and per-factor paths == LCCChain.apply."""
    rng = np.random.default_rng(25)
    w = rng.standard_normal((96, 12))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=40.0, slice_width=12)
    chain = dec.slices[0]
    pc = ops.pack_chain(chain)
    x = jnp.asarray(rng.standard_normal((12, 9)), jnp.float32)
    want = chain.apply(np.asarray(x, np.float64))
    for fused in (True, False):
        got = np.asarray(ops.apply_packed_chain(pc, x, fused=fused))
        assert got.shape == (96, 9)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mlp_forward_compressed_matches_dense():
    """models/ wiring: fc1 through the fused kernel tracks the dense forward
    at the decomposition's SNR and preserves argmax decisions."""
    import jax as _jax
    from repro.models.mlp import init_mlp, mlp_forward, mlp_forward_compressed

    rng = np.random.default_rng(26)
    params = init_mlp(_jax.random.PRNGKey(0), in_dim=48, hidden=64, classes=10)
    dec = lcc_decompose(np.asarray(params["fc1"]["w"], np.float64),
                        algorithm="fp", target_snr_db=50.0)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((5, 48)), jnp.float32)
    ref = mlp_forward(params, x)
    got = mlp_forward_compressed(params, packed, x)
    assert got.shape == ref.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0.05, atol=0.05)
    np.testing.assert_array_equal(np.argmax(np.asarray(got), -1),
                                  np.argmax(np.asarray(ref), -1))


def test_lcc_matvec_vector_input_with_sharing():
    """serving LCCMatvec: 1-D input works with and without weight sharing."""
    from repro import core
    from repro.serving.engine import LCCMatvec

    rng = np.random.default_rng(27)
    w = rng.standard_normal((40, 24))
    for share in (False, True):
        cd = core.compress_dense_matrix(
            f"t.share{share}", w,
            core.CompressionConfig(algorithm="fp", weight_sharing=share), None)
        mv = LCCMatvec(cd)
        x = rng.standard_normal(24)
        got = np.asarray(mv(jnp.asarray(x, jnp.float32)))
        want = cd.apply(x)
        assert got.shape == (40,)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fused_vector_input_and_fs_dense_fallback():
    """1-D input squeeze + FS slices combine through the dense fallback."""
    rng = np.random.default_rng(24)
    w = rng.standard_normal((48, 10))
    dec = lcc_decompose(w, algorithm="fs", target_snr_db=35.0)
    packed = ops.pack_decomposition(dec)
    assert packed.dense  # FS programs run via their dense equivalent
    x = rng.standard_normal(10)
    got = np.asarray(ops.apply_packed_decomposition(packed, jnp.asarray(x, jnp.float32)))
    want = dec.apply(x)
    assert got.shape == (48,)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,b,c", [(128, 128, 128), (256, 64, 128), (128, 32, 256)])
def test_cluster_segment_sum(k, b, c):
    rng = np.random.default_rng(k + b + c)
    labels = jnp.asarray(rng.integers(0, c, k), jnp.int32)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = cluster_segment_sum(labels, x, num_clusters=c)
    want = ref.cluster_segment_sum_ref(labels, x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_shared_matmul_unaligned():
    """ops wrapper pads ragged (K, C, B) to block multiples."""
    rng = np.random.default_rng(9)
    cents = jnp.asarray(rng.standard_normal((33, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, 50), jnp.int32)
    x = jnp.asarray(rng.standard_normal((50, 9)), jnp.float32)
    got = np.asarray(ops.shared_matmul_tpu(cents, labels, x))
    want = np.asarray(cents) @ np.asarray(ref.cluster_segment_sum_ref(labels, x, 10))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("g,m", [(256, 64), (512, 33), (256, 301)])
@pytest.mark.parametrize("t", [0.0, 0.5, 10.0])
def test_group_prox_kernel(g, m, t):
    rng = np.random.default_rng(g + m)
    a = jnp.asarray(rng.standard_normal((g, m)), jnp.float32)
    got = group_prox(a, t)
    want = ref.group_prox_ref(a, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_group_prox_bf16():
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)
    got = group_prox(a, 1.3)
    want = ref.group_prox_ref(a, 1.3)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_factor_stream_roundtrip():
    """Deployment byte stream: serialize -> parse -> identical dense factor."""
    rng = np.random.default_rng(12)
    w = rng.standard_normal((64, 8))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    from repro.core.lcc import LCCChain
    chain = next(s for s in dec.slices if isinstance(s, LCCChain))
    for f in chain.factors:
        blob = ops.factor_to_stream(f)
        f2 = ops.stream_to_factor(blob)
        np.testing.assert_array_equal(f.to_dense(), f2.to_dense())
        # stream size ~= the 3-bytes-per-term model (+1/row +12 header)
        nnz = int((f.sign != 0).sum())
        assert len(blob) == 12 + f.out_dim + 3 * nnz


def test_group_prox_zero_rows_boundary_unaligned():
    """Parity with ``group_prox_rows_np`` on the hard cases: zero-norm rows
    (exact 0 out, no NaN), rows at/near the threshold boundary, and a group
    count that is not a block multiple (the wrapper pads and slices)."""
    from repro.core.group_lasso import group_prox_rows_np

    rng = np.random.default_rng(5)
    a = rng.standard_normal((37, 16))
    a[[3, 17, 36]] = 0.0  # structurally-pruned groups
    a[5] *= 2.0 / np.linalg.norm(a[5])     # exactly at the threshold
    a[9] *= 1.995 / np.linalg.norm(a[9])   # just under -> zeroed
    a[11] *= 2.005 / np.linalg.norm(a[11])  # just over -> survives, tiny
    af = np.asarray(a, np.float32)
    got = np.asarray(group_prox(jnp.asarray(af), 2.0))
    want = group_prox_rows_np(af, 2.0)
    assert np.isfinite(got).all()
    assert (got[[3, 17, 36]] == 0.0).all()
    assert (got[9] == 0.0).all()
    assert np.abs(got[11]).max() > 0.0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# CSD shift-add layer-plan stage vs ref oracles (bitwise)
# ---------------------------------------------------------------------------

def _csd_stage(idx, exp, sgn, k_in):
    """Hand-build a 1-layer PackedStage around a raw CSD chain [P, R, S]."""
    from repro.kernels.ops import PackedStage

    p, r, s = idx.shape
    return PackedStage(
        prep_src=np.arange(k_in, dtype=np.int32)[None],
        prep_tgt=np.arange(k_in, dtype=np.int32)[None],
        gidx=np.asarray(idx, np.int32)[None],
        gexp=np.asarray(exp, np.int8)[None],
        gsgn=np.asarray(sgn, np.int8)[None],
        outg=np.arange(r, dtype=np.int32)[None, None],
        fs_mat=None, dw_mat=None, bias=None,
        k_alloc=k_in + 1, d_src=k_in, out_dim=r, n_layers=1,
        site_names=("synthetic",))


def test_stage_matmul_csd_shift_add_bitwise_vs_ref():
    """The one-launch CSD shift-add stage matches the densify-then-matmul
    oracle BITWISE: every operand is a signed power of two times an integer
    input, all intermediates are dyadic rationals far inside the f32 mantissa,
    so both evaluation orders are exact and must agree to the last bit."""
    from repro.kernels import layer_plan

    rng = np.random.default_rng(11)
    k_in, r, p, s, b = 8, 8, 3, 2, 5
    idx = rng.integers(0, k_in, (p, r, s))
    exp = rng.integers(-2, 3, (p, r, s))
    sgn = rng.choice([-1, 0, 1], (p, r, s))
    sgn[1, 2] = 0  # a fully-dead row: must decompress to exactly 0.0
    x = np.asarray(rng.integers(-4, 5, (k_in, b)), np.float32)

    factors = [(jnp.asarray(idx[q], jnp.int32), jnp.asarray(exp[q], jnp.int8),
                jnp.asarray(sgn[q], jnp.int8)) for q in range(p)]
    want = np.asarray(ref.lcc_chain_apply_ref(factors, jnp.asarray(x)))

    ps = _csd_stage(idx, exp, sgn, k_in)
    got = np.asarray(layer_plan.stage_matmul(ps, jnp.asarray(x)[None]))[0]
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("p", [3, 4])
def test_fuse_csd_levels_bitwise(p):
    """Level fusion composes signed powers of two exactly: the fused stage
    must agree bitwise with both the unfused stage and the ref chain, for an
    even level count (full pairwise fusion) and an odd one (unfused tail)."""
    from repro.kernels import layer_plan

    rng = np.random.default_rng(100 + p)
    k_in, r, s, b = 8, 8, 2, 4
    idx = rng.integers(0, k_in, (p, r, s))
    exp = rng.integers(-2, 3, (p, r, s))
    sgn = rng.choice([-1, 0, 1], (p, r, s))
    sgn[0, 5] = 0  # dead parent row: fused terms through it must go dead too
    x = np.asarray(rng.integers(-4, 5, (k_in, b)), np.float32)

    factors = [(jnp.asarray(idx[q], jnp.int32), jnp.asarray(exp[q], jnp.int8),
                jnp.asarray(sgn[q], jnp.int8)) for q in range(p)]
    want = np.asarray(ref.lcc_chain_apply_ref(factors, jnp.asarray(x)))

    fi, fe, fs = ops._fuse_csd_levels(idx, exp, sgn)
    assert fi.shape[0] == (p + 1) // 2  # depth halved (odd tail rides along)
    got = np.asarray(layer_plan.stage_matmul(
        _csd_stage(fi, fe, fs, k_in), jnp.asarray(x)[None]))[0]
    np.testing.assert_array_equal(got, want)


def test_stage_matmul_csd_digits_reproduce_constants():
    """A 1-level stage built from ``csd_digits`` of real coefficients applies
    exactly c * x: shift-add reconstruction of a CSD-coded scalar is bitwise
    identical to the direct multiply for dyadic c and integer x."""
    from repro.core.csd import csd_digits
    from repro.kernels import layer_plan

    consts = [2.5, -3.75, 0.625, 1.0]
    digits = [csd_digits(c) for c in consts]
    s = max(len(d) for d in digits)
    r = len(consts)
    idx = np.zeros((1, r, s), np.int64)  # every row reads input row 0
    exp = np.zeros((1, r, s), np.int64)
    sgn = np.zeros((1, r, s), np.int64)
    for i, dig in enumerate(digits):
        for j, (e, z) in enumerate(dig):
            exp[0, i, j], sgn[0, i, j] = e, z

    rng = np.random.default_rng(3)
    x = np.asarray(rng.integers(-8, 9, (1, 6)), np.float32)
    got = np.asarray(layer_plan.stage_matmul(
        _csd_stage(idx, exp, sgn, 1), jnp.asarray(x)[None]))[0]
    want = np.asarray(consts, np.float32)[:, None] * x
    np.testing.assert_array_equal(got, want)
