"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lcc import lcc_decompose
from repro.kernels import ops, ref
from repro.kernels.group_prox import group_prox
from repro.kernels.lcc_matmul import lcc_factor_matmul
from repro.kernels.shared_matmul import cluster_segment_sum


@pytest.mark.parametrize("n,k,b,s", [(128, 128, 128, 2), (256, 128, 64, 3),
                                     (128, 256, 32, 4), (384, 128, 128, 2)])
def test_lcc_factor_matmul_shapes(n, k, b, s):
    rng = np.random.default_rng(n + k + b)
    idx = jnp.asarray(rng.integers(0, k, (n, s)), jnp.int32)
    exp = jnp.asarray(rng.integers(-8, 8, (n, s)), jnp.int8)
    sign = jnp.asarray(rng.choice([-1, 0, 1], (n, s)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = lcc_factor_matmul(idx, exp, sign, x, block_n=128, block_k=128, block_b=min(b, 128))
    want = ref.lcc_factor_matmul_ref(idx, exp, sign, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lcc_factor_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    n, k, b, s = 128, 128, 128, 2
    idx = jnp.asarray(rng.integers(0, k, (n, s)), jnp.int32)
    exp = jnp.asarray(rng.integers(-6, 6, (n, s)), jnp.int8)
    sign = jnp.asarray(rng.choice([-1, 1], (n, s)), jnp.int8)
    x = jnp.asarray(rng.standard_normal((k, b)), dtype)
    got = lcc_factor_matmul(idx, exp, sign, x)
    want = ref.lcc_factor_matmul_ref(idx, exp, sign, x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_chain_apply_matches_decomposition():
    rng = np.random.default_rng(8)
    w = rng.standard_normal((96, 24))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=40.0)
    packed = ops.pack_decomposition(dec)
    x = jnp.asarray(rng.standard_normal((24, 7)), jnp.float32)
    got = np.asarray(ops.apply_packed_decomposition(packed, x))
    want = dec.to_dense() @ np.asarray(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,b,c", [(128, 128, 128), (256, 64, 128), (128, 32, 256)])
def test_cluster_segment_sum(k, b, c):
    rng = np.random.default_rng(k + b + c)
    labels = jnp.asarray(rng.integers(0, c, k), jnp.int32)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = cluster_segment_sum(labels, x, num_clusters=c)
    want = ref.cluster_segment_sum_ref(labels, x, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_shared_matmul_unaligned():
    """ops wrapper pads ragged (K, C, B) to block multiples."""
    rng = np.random.default_rng(9)
    cents = jnp.asarray(rng.standard_normal((33, 10)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 10, 50), jnp.int32)
    x = jnp.asarray(rng.standard_normal((50, 9)), jnp.float32)
    got = np.asarray(ops.shared_matmul_tpu(cents, labels, x))
    want = np.asarray(cents) @ np.asarray(ref.cluster_segment_sum_ref(labels, x, 10))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("g,m", [(256, 64), (512, 33), (256, 301)])
@pytest.mark.parametrize("t", [0.0, 0.5, 10.0])
def test_group_prox_kernel(g, m, t):
    rng = np.random.default_rng(g + m)
    a = jnp.asarray(rng.standard_normal((g, m)), jnp.float32)
    got = group_prox(a, t)
    want = ref.group_prox_ref(a, t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_group_prox_bf16():
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)
    got = group_prox(a, 1.3)
    want = ref.group_prox_ref(a, 1.3)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_factor_stream_roundtrip():
    """Deployment byte stream: serialize -> parse -> identical dense factor."""
    rng = np.random.default_rng(12)
    w = rng.standard_normal((64, 8))
    dec = lcc_decompose(w, algorithm="fp", target_snr_db=35.0)
    from repro.core.lcc import LCCChain
    chain = next(s for s in dec.slices if isinstance(s, LCCChain))
    for f in chain.factors:
        blob = ops.factor_to_stream(f)
        f2 = ops.stream_to_factor(blob)
        np.testing.assert_array_equal(f.to_dense(), f2.to_dense())
        # stream size ~= the 3-bytes-per-term model (+1/row +12 header)
        nnz = int((f.sign != 0).sum())
        assert len(blob) == 12 + f.out_dim + 3 * nnz
