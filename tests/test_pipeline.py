"""Parallel compression pipeline: determinism vs the serial path, the
adds-budget allocator, content-addressed cache hits on tied weights,
structured progress events, and resume-after-SIGKILL through the CLI."""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.compress import (CompressibleConv, CompressibleDense,
                                 CompressionConfig, compress_conv_kernel,
                                 compress_dense_matrix, compress_model_params)
from repro.core.cost import ModelCostReport
from repro.pipeline import CompressionEvent, run_pipeline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _units(n_dense=4, with_conv=False, seed=0, shape=(40, 20)):
    rng = np.random.default_rng(seed)
    units = [CompressibleDense(name=f"d{i}", weight=rng.standard_normal(shape))
             for i in range(n_dense)]
    if with_conv:
        units.append(CompressibleConv(
            name="c0", kernel=rng.standard_normal((8, 4, 3, 3))))
    return units


def _cfg():
    return CompressionConfig(algorithm="fp", weight_sharing=True,
                             max_share_rel_err=0.06)


def _assert_dense_bitwise(a, b):
    assert a.effective.tobytes() == b.effective.tobytes()
    assert np.array_equal(a.kept_columns, b.kept_columns)
    if a.shared is None:
        assert b.shared is None
    else:
        assert a.shared.labels.tobytes() == b.shared.labels.tobytes()
        assert a.shared.centroids.tobytes() == b.shared.centroids.tobytes()
    da, db = a.decomposition, b.decomposition
    assert da.col_slices == db.col_slices
    assert da.meta == db.meta
    assert da.to_dense().tobytes() == db.to_dense().tobytes()


def _assert_records_bitwise(ra, rb):
    assert set(ra) == set(rb)
    for n in ra:
        if isinstance(ra[n], dict):  # conv record
            assert ra[n]["lcc_adds"] == rb[n]["lcc_adds"]
            assert ra[n]["channels_nonzero"] == rb[n]["channels_nonzero"]
            for ch in ra[n]["decompositions"]:
                assert (ra[n]["decompositions"][ch].to_dense().tobytes()
                        == rb[n]["decompositions"][ch].to_dense().tobytes())
        else:
            _assert_dense_bitwise(ra[n], rb[n])


def _report_rows(report):
    return [(l.name, l.baseline_adds, l.stage_adds, l.stage_bytes)
            for l in report.layers]


# ------------------------------------------------------------- determinism


def test_parallel_bitwise_identical_to_serial():
    """Worker fan-out must not change a single bit of the output, and the
    serial wrapper must match the direct Algorithm-1 calls."""
    units = _units(n_dense=4, with_conv=True)
    cfg = _cfg()
    ref_rep = ModelCostReport()
    ref = {}
    for u in units:
        if isinstance(u, CompressibleDense):
            ref[u.name] = compress_dense_matrix(u.name, u.weight, cfg, ref_rep)
        else:
            ref[u.name] = compress_conv_kernel(u.name, u.kernel, cfg, ref_rep)

    serial = run_pipeline(units, cfg, n_workers=1)
    parallel = run_pipeline(units, cfg, n_workers=2)
    _assert_records_bitwise(ref, serial.records)
    _assert_records_bitwise(ref, parallel.records)
    assert _report_rows(ref_rep) == _report_rows(serial.report) \
        == _report_rows(parallel.report)

    out, rep = compress_model_params(units, cfg)  # the thin serial wrapper
    _assert_records_bitwise(ref, out)
    assert _report_rows(ref_rep) == _report_rows(rep)


# ----------------------------------------------------------------- events


def test_structured_progress_events():
    events = []
    units = _units(n_dense=3)
    run_pipeline(units, _cfg(), n_workers=1, progress=events.append)
    assert all(isinstance(e, CompressionEvent) for e in events)
    kinds = {e.kind for e in events}
    assert {"plan", "unit_start", "slice_done", "unit_done"} <= kinds
    done = [e for e in events if e.kind == "unit_done"]
    assert [e.unit for e in done] == [u.name for u in units]
    for e in done:
        assert e.adds_before > 0 and e.adds_after > 0
        assert e.wall_s >= 0
        assert e.unit in str(e)  # old string-callback consumers stay readable


# ------------------------------------------------------------ cache hits


def test_cache_hits_on_tied_weights():
    """Two units sharing one weight matrix: the second is free (same
    content-addressed jobs), and its record is bitwise identical."""
    rng = np.random.default_rng(3)
    w = rng.standard_normal((40, 20))
    units = [CompressibleDense(name="tied_a", weight=w),
             CompressibleDense(name="tied_b", weight=w.copy())]
    res = run_pipeline(units, _cfg(), n_workers=1)
    n_slices = len(res.records["tied_a"].decomposition.col_slices)
    assert res.stats["cache_hits"] >= n_slices  # all of tied_b's jobs
    assert res.stats["cache_misses"] == n_slices
    _assert_dense_bitwise(res.records["tied_a"], res.records["tied_b"])


def test_cache_persists_across_runs(tmp_path):
    units = _units(n_dense=3)
    cache = str(tmp_path / "cache")
    cold = run_pipeline(units, _cfg(), n_workers=1, cache_dir=cache)
    warm = run_pipeline(units, _cfg(), n_workers=2, cache_dir=cache)
    assert cold.stats["cache_hits"] == 0
    assert warm.stats["cache_misses"] == 0
    assert warm.stats["cache_hits"] == warm.stats["jobs"]
    _assert_records_bitwise(cold.records, warm.records)
    assert _report_rows(cold.report) == _report_rows(warm.report)


# ---------------------------------------------------------- adds budget


def test_budget_allocation_lands_within_5pct(tmp_path):
    units = _units(n_dense=6, with_conv=True, seed=1)
    cfg = _cfg()
    cache = str(tmp_path / "cache")
    rich = run_pipeline(units, cfg, n_workers=1, cache_dir=cache)
    floor = run_pipeline(
        units, CompressionConfig(algorithm="fs", snr_offset_db=-9.0,
                                 prune_tol=1e-4, max_share_rel_err=None),
        n_workers=1, cache_dir=cache)
    lo = floor.report.total_stage("lcc")
    hi = rich.report.total_stage("lcc")
    assert lo < hi
    for frac in (0.4, 0.8):
        budget = int(lo + frac * (hi - lo))
        res = run_pipeline(units, cfg, budget_adds=budget, n_workers=2,
                           cache_dir=cache)
        landed = res.report.total_stage("lcc")
        # verified via the ModelCostReport: inside the budget, within 5%
        assert landed <= budget
        assert landed >= 0.95 * budget
        assert res.budget_info["landed_adds"] == landed
        # the allocator chose real per-unit plans
        assert set(res.unit_configs) == {u.name for u in units}


def test_budget_below_floor_emits_floor_plan():
    units = _units(n_dense=2, seed=2)
    events = []
    res = run_pipeline(units, _cfg(), budget_adds=1, n_workers=1,
                       progress=events.append)
    assert res.report.total_stage("lcc") > 1  # floor, not a crash
    assert any(e.kind == "budget" and "below the adds floor" in e.detail
               for e in events)


def test_artifact_records_per_unit_plans(tmp_path):
    """Budget runs record the allocator's plans in the CompressedModel and
    round-trip them through save/load."""
    import jax

    from repro.configs import get_arch
    from repro.configs.base import reduced_config
    from repro.core.artifact import CompressedModel
    from repro.models import api

    cfg = reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                         n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                         n_layers=1)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    cache = str(tmp_path / "cache")
    base = api.compress_model(params, cfg, include="ffn.", cache_dir=cache)
    budget = int(0.7 * base.report.total_stage("lcc"))
    art = api.compress_model(params, cfg, include="ffn.", budget_adds=budget,
                             n_workers=2, cache_dir=cache)
    assert art.report.total_stage("lcc") <= budget
    assert art.unit_configs  # allocator plans recorded
    art.save(str(tmp_path / "art"))
    back = CompressedModel.load(str(tmp_path / "art"))
    assert back.unit_configs == art.unit_configs
    assert back.unit_config_for("ffn.gate.l0") == art.unit_configs["ffn.gate.l0"]
    assert back.pipeline_stats["jobs"] == art.pipeline_stats["jobs"]


# -------------------------------------------------------- resume semantics


def test_resume_refuses_mismatched_weights(tmp_path):
    units = _units(n_dense=2, seed=4)
    run_dir = str(tmp_path / "run")
    run_pipeline(units, _cfg(), n_workers=1, run_dir=run_dir)
    other = _units(n_dense=2, seed=5)
    with pytest.raises(ValueError, match="hash"):
        run_pipeline(other, _cfg(), n_workers=1, run_dir=run_dir, resume=True)


def test_resume_reuses_manifest_plans(tmp_path):
    units = _units(n_dense=3, seed=6)
    run_dir = str(tmp_path / "run")
    first = run_pipeline(units, _cfg(), n_workers=1, run_dir=run_dir)
    events = []
    second = run_pipeline(units, _cfg(), n_workers=1, run_dir=run_dir,
                          resume=True, progress=events.append)
    assert any(e.kind == "resume" for e in events)
    assert second.stats["cache_misses"] == 0  # every slice from the cache
    _assert_records_bitwise(first.records, second.records)


# ----------------------------------------------------- SIGKILL + resume


def _cli_cmd(out_dir, *extra):
    return [sys.executable, "-m", "repro.launch.compress", "--arch", "olmo-1b",
            "--quickstart", "--workers", "2", "--seed", "0", "--quiet",
            "--out", str(out_dir), *extra]


def _cli_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def test_resume_after_sigkill_matches_uninterrupted(tmp_path):
    """SIGKILL a pipeline run mid-way, resume it, and require the artifact to
    be bitwise-identical to an uninterrupted run."""
    from repro.core.artifact import CompressedModel

    killed_dir = tmp_path / "killed"
    clean_dir = tmp_path / "clean"

    # start, wait until a few slice results are durably cached, SIGKILL
    proc = subprocess.Popen(_cli_cmd(killed_dir), env=_cli_env(), cwd=REPO,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    cache = killed_dir / "cache"
    deadline = time.time() + 120
    killed = False
    while time.time() < deadline and proc.poll() is None:
        done = len(list(cache.glob("*.msgpack"))) if cache.exists() else 0
        if done >= 4:
            proc.send_signal(signal.SIGKILL)
            proc.wait()
            killed = True
            break
        time.sleep(0.02)
    assert killed, "run finished before it could be killed; enlarge the model"
    assert not (killed_dir / "artifact").exists()  # it really died mid-run

    # resume to completion; a fresh run is the reference
    r = subprocess.run(_cli_cmd(killed_dir, "--resume"), env=_cli_env(),
                       cwd=REPO, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    r2 = subprocess.run(_cli_cmd(clean_dir), env=_cli_env(), cwd=REPO,
                        capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr

    resumed = CompressedModel.load(str(killed_dir / "artifact"))
    clean = CompressedModel.load(str(clean_dir / "artifact"))
    _assert_records_bitwise(resumed.records, clean.records)
    assert _report_rows(resumed.report) == _report_rows(clean.report)
    # dense-effective params match bitwise too
    import jax
    la = jax.tree_util.tree_leaves(resumed.params)
    lb = jax.tree_util.tree_leaves(clean.params)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    # the resumed run actually reused the killed run's work
    stats = json.loads((killed_dir / "stats.json").read_text())
    assert stats["cache_hits"] >= 4


# ------------------------------------------------- prune-aware slice plans


def test_sparse_plan_bitwise_identical_and_stats():
    """Keep-in-place pruning (prune_tol < 0): all-dead slices are skipped
    (0-add zero pieces), partially-dead slices shrink to their live columns —
    and the result is bitwise identical across serial, parallel, and the
    direct Algorithm-1 call, with the dead groups accounted in the stats."""
    rng = np.random.default_rng(7)
    w = rng.standard_normal((24, 40))
    dead = rng.choice(40, 17, replace=False)
    w[:, dead] = 0.0  # prox-style exactly-dead input groups
    units = [CompressibleDense(name="sparse", weight=w),
             CompressibleDense(name="full",
                               weight=rng.standard_normal((24, 40)))]
    cfg = CompressionConfig(algorithm="fp", weight_sharing=False,
                            prune_tol=-1e-9)

    ref_rep = ModelCostReport()
    ref = {u.name: compress_dense_matrix(u.name, u.weight, cfg, ref_rep)
           for u in units}
    serial = run_pipeline(units, cfg, n_workers=1)
    parallel = run_pipeline(units, cfg, n_workers=2)
    _assert_records_bitwise(ref, serial.records)
    _assert_records_bitwise(ref, parallel.records)
    assert _report_rows(ref_rep) == _report_rows(serial.report) \
        == _report_rows(parallel.report)

    rec = serial.records["sparse"]
    assert np.array_equal(rec.kept_columns, np.arange(40))  # keep-in-place
    assert (rec.effective[:, dead] == 0.0).all()  # dead columns stay exact 0
    for res in (serial, parallel):
        assert res.stats["dead_groups"] >= 17
        assert res.stats["skipped_jobs"] + res.stats["shrunk_jobs"] >= 1
    assert serial.stats["skipped_jobs"] == parallel.stats["skipped_jobs"]
    assert serial.stats["shrunk_jobs"] == parallel.stats["shrunk_jobs"]


def test_drop_mode_stats_unchanged():
    """Drop-mode pruning (prune_tol >= 0) keeps its original slice jobs:
    nothing skipped or shrunk, cache keys bitwise-stable."""
    res = run_pipeline(_units(n_dense=2), _cfg(), n_workers=1)
    assert res.stats["skipped_jobs"] == 0
    assert res.stats["shrunk_jobs"] == 0
