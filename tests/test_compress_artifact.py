"""Unified compression API: family adapters, the serializable CompressedModel
artifact, and engine-integrated LCC decode (fused kernel inside the jitted
decode step)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs import get_arch
from repro.configs.base import MoESpec, reduced_config
from repro.core.artifact import CompressedModel
from repro.models import api
from repro.serving.engine import ServingEngine


def _tiny_cfg():
    return reduced_config(get_arch("olmo-1b"), d_model=32, n_heads=2,
                          n_kv_heads=2, head_dim=16, d_ff=48, vocab=64,
                          n_layers=2)


def _fp_compression():
    return core.CompressionConfig(algorithm="fp", weight_sharing=True,
                                  max_share_rel_err=0.06)


@pytest.fixture(scope="module")
def dense_artifact():
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return api.compress_model(params, cfg, _fp_compression())


# ---------------------------------------------------------------- adapters


def test_adapter_covers_three_families(dense_artifact):
    """compress_model works for dense, MoE and ResNet via the registry —
    no ValueError carve-outs for supported families."""
    # dense transformer: FFN + attention projections
    names = set(dense_artifact.records)
    assert {"ffn.gate.l0", "ffn.up.l1", "ffn.down.l0", "attn.q.l0",
            "attn.o.l1"} <= names
    assert dense_artifact.report.total_baseline() > 0

    # MoE: per-expert dense matrices + attention
    cfg_m = reduced_config(
        get_arch("mixtral-8x22b"), d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab=64, n_layers=1,
        moe=MoESpec(n_experts=2, top_k=1, d_ff_expert=16, capacity_factor=8.0))
    pm = api.init_params(jax.random.PRNGKey(1), cfg_m)
    art_m = api.compress_model(pm, cfg_m, _fp_compression())
    assert {"moe.gate.l0.e0", "moe.gate.l0.e1", "moe.down.l0.e0",
            "attn.q.l0"} <= set(art_m.records)
    # dense-effective params still decode
    st = api.init_decode_state(cfg_m, 1, 8)
    logits, _ = api.decode(art_m.params, cfg_m, st,
                           jnp.asarray([[3]], jnp.int32),
                           jnp.asarray([0], jnp.int32))
    assert logits.shape == (1, cfg_m.vocab)

    # ResNet: conv kernels via the CMVM reshape + the linear head
    from repro.models.resnet import ResNetConfig, init_resnet, resnet_forward

    rcfg = ResNetConfig(stages=(1,), widths=(8,), classes=4, in_ch=3)
    rp = init_resnet(jax.random.PRNGKey(2), rcfg)
    art_r = api.compress_model(rp, rcfg, _fp_compression())
    assert {"stem", "block0.conv1", "block0.conv2", "head"} <= set(art_r.records)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 3, 8, 8)),
                    jnp.float32)
    assert resnet_forward(art_r.params, x).shape == (2, 4)


def test_unit_enumeration_no_family_carveouts():
    """Every assigned family enumerates compressible units (the PR-1 surface
    raised ValueError for anything but the dense-transformer FFN)."""
    for arch in ("olmo-1b", "qwen2-vl-7b", "mixtral-8x22b",
                 "deepseek-v2-lite-16b", "rwkv6-1.6b", "zamba2-7b",
                 "whisper-small"):
        cfg = reduced_config(get_arch(arch))
        params = api.init_params(jax.random.PRNGKey(3), cfg)
        units = api.compressible_units(params, cfg)
        assert units, f"{arch}: no compressible units"


def test_rebind_writes_effective_weight(dense_artifact):
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    w_new = np.full((cfg.d_ff, cfg.d_model), 0.25)
    p2 = api.rebind(params, cfg, "ffn.gate.l1", w_new)
    # target layer updated, original untouched, sibling layer untouched
    assert np.allclose(np.asarray(p2["blocks"]["ffn"]["gate"]["w"][1]), 0.25)
    assert not np.allclose(np.asarray(params["blocks"]["ffn"]["gate"]["w"][1]), 0.25)
    np.testing.assert_array_equal(np.asarray(p2["blocks"]["ffn"]["gate"]["w"][0]),
                                  np.asarray(params["blocks"]["ffn"]["gate"]["w"][0]))
    with pytest.raises(KeyError, match="no compressible unit"):
        api.rebind(params, cfg, "nope.l0", w_new)


# ---------------------------------------------------------------- artifact


def test_artifact_roundtrip_bitwise(dense_artifact, tmp_path):
    """Save/load through the Checkpointer: decode logits bitwise-identical."""
    cfg = dense_artifact.config
    d = str(tmp_path / "artifact")
    dense_artifact.save(d)
    art2 = CompressedModel.load(d)

    assert set(art2.records) == set(dense_artifact.records)
    assert set(art2.packed) == set(dense_artifact.packed)
    r1 = dense_artifact.records["ffn.gate.l0"]
    r2 = art2.records["ffn.gate.l0"]
    np.testing.assert_array_equal(r1.effective, r2.effective)
    np.testing.assert_array_equal(r1.decomposition.to_dense(),
                                  r2.decomposition.to_dense())

    state = api.init_decode_state(cfg, 1, 16)
    tok = jnp.asarray([[3]], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    run = jax.jit(lambda p: api.decode(p, cfg, state, tok, pos)[0])
    np.testing.assert_array_equal(np.asarray(run(dense_artifact.params)),
                                  np.asarray(run(art2.params)))


def test_artifact_corrupted_shard_skipped(dense_artifact, tmp_path):
    """A corrupted newest step falls back to the previous intact one."""
    d = str(tmp_path / "artifact")
    dense_artifact.save(d, step=0)
    dense_artifact.save(d, step=1)
    shard = os.path.join(d, "step_0000000001", "shard_0.msgpack")
    with open(shard, "r+b") as f:
        f.seek(200)
        f.write(b"\xff" * 16)
    art = CompressedModel.load(d)  # must not raise
    assert set(art.records) == set(dense_artifact.records)

    # nothing intact at all -> clean failure, not a crash elsewhere
    with open(os.path.join(d, "step_0000000000", "shard_0.msgpack"), "r+b") as f:
        f.seek(200)
        f.write(b"\xff" * 16)
    with pytest.raises(FileNotFoundError, match="no intact"):
        CompressedModel.load(d)


# ------------------------------------------------------- engine integration


def test_engine_decode_runs_fused_kernel(dense_artifact, monkeypatch):
    """ServingEngine(artifact=...) routes every compressed site (FFN *and*
    attention) through fused kernel launches inside the jitted decode step,
    and its logits match the dense-effective forward to <= 1e-4."""
    from repro.kernels import layer_plan, ops

    calls = {"chain": 0, "group": 0, "plan": 0}
    real_chain, real_group = ops.lcc_chain_matmul, ops.lcc_group_matmul
    real_plan = layer_plan.step_plan_matmul

    def counting_chain(*a, **k):
        calls["chain"] += 1
        return real_chain(*a, **k)

    def counting_group(*a, **k):
        calls["group"] += 1
        return real_group(*a, **k)

    def counting_plan(*a, **k):
        calls["plan"] += 1
        return real_plan(*a, **k)

    monkeypatch.setattr(ops, "lcc_chain_matmul", counting_chain)
    monkeypatch.setattr(ops, "lcc_group_matmul", counting_group)
    monkeypatch.setattr(layer_plan, "step_plan_matmul", counting_plan)

    cfg = dense_artifact.config
    eng = ServingEngine(artifact=dense_artifact, n_slots=2, max_len=32)
    assert eng.executor is not None
    assert eng.executor.sites == set(dense_artifact.records)
    res = eng.generate([[3, 1, 4], [1, 5]], max_new_tokens=4)
    assert all(r.finished for r in res)
    assert calls["chain"] + calls["group"] + calls["plan"] > 0, \
        "fused kernels were never traced into the decode step"
    # either the whole-stack layer plan fired (one launch per step) or the
    # per-region route traced at least one grouped launch
    assert calls["plan"] > 0 or calls["group"] > 0, \
        "neither a layer-plan nor a fused-region (grouped) launch was traced"
    # every compressed site dispatched through a fused kernel — nothing fell
    # back to the dense-effective matmul on the hot path
    assert eng.executor.routed == eng.executor.sites

    # same artifact served through the stock XLA dense-effective path
    eng_dense = ServingEngine(artifact=dense_artifact, n_slots=2, max_len=32,
                              use_kernel=False)
    assert eng_dense.executor is None
    res_d = eng_dense.generate([[3, 1, 4], [1, 5]], max_new_tokens=4)
    assert [r.tokens for r in res] == [r.tokens for r in res_d]

    state = api.init_decode_state(cfg, 1, 16)
    tok = jnp.asarray([[3]], jnp.int32)
    pos = jnp.asarray([0], jnp.int32)
    l_kernel, _ = api.decode(dense_artifact.params, cfg, state, tok, pos,
                             executor=eng.executor)
    l_dense, _ = api.decode(dense_artifact.params, cfg, state, tok, pos)
    assert float(jnp.abs(l_kernel - l_dense).max()) <= 1e-4


# ---------------------------------------------------------------- prefill


def test_bulk_prefill_matches_tokenwise():
    """One api.prefill forward writes the same KV the per-token decode loop
    produced (same greedy continuations), including slot reuse across
    requests of different lengths."""
    cfg = reduced_config(get_arch("olmo-1b"))
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    bulk = ServingEngine(params, cfg, n_slots=2, max_len=64)
    loop = ServingEngine(params, cfg, n_slots=2, max_len=64, bulk_prefill=False)
    prompts = [[5, 9, 2, 7, 11, 1, 3], [7, 1], [4, 4, 4, 8], [30]]
    r_bulk = bulk.generate(prompts, max_new_tokens=5)
    r_loop = loop.generate(prompts, max_new_tokens=5)
    assert [r.tokens for r in r_bulk] == [r.tokens for r in r_loop]


def test_bulk_prefill_mla():
    cfg = reduced_config(get_arch("deepseek-v2-lite-16b"))
    params = api.init_params(jax.random.PRNGKey(1), cfg)
    bulk = ServingEngine(params, cfg, n_slots=1, max_len=32)
    loop = ServingEngine(params, cfg, n_slots=1, max_len=32, bulk_prefill=False)
    a = bulk.generate([[3, 1, 4, 1, 5]], max_new_tokens=4)[0]
    b = loop.generate([[3, 1, 4, 1, 5]], max_new_tokens=4)[0]
    assert a.tokens == b.tokens


# ---------------------------------------------------------------- accounting


def test_shared_labels_dtype_and_bytes():
    """Weight-sharing labels are stored at their deployment width and the
    byte accounting reads the stored dtype (not an int64 assumption)."""
    rng = np.random.default_rng(0)
    cents = rng.standard_normal((24, 4))
    labels = rng.integers(0, 4, 32)
    w = cents[:, labels] + 1e-4 * rng.standard_normal((24, 32))
    report = core.ModelCostReport()
    cd = core.compress_dense_matrix(
        "shared_unit", w,
        core.CompressionConfig(algorithm="fp", weight_sharing=True,
                               max_share_rel_err=0.06), report)
    assert cd.shared is not None, "clustered matrix must trigger sharing"
    assert cd.shared.labels.dtype == np.uint16
    lc = report.layers[0]
    assert lc.stage_bytes["lcc"] == (cd.decomposition.storage_bytes()
                                     + cd.shared.labels.nbytes)
    # reference evaluation still works with the narrow label dtype
    x = rng.standard_normal((32, 3))
    np.testing.assert_allclose(cd.apply(x), cd.effective @ x[cd.kept_columns],
                               atol=1e-9)


def test_compress_ffn_for_serving_legacy_wrapper(dense_artifact):
    """The PR-1 entry point still returns (params_c, matvecs, report) and now
    delegates to the adapter registry."""
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    from repro.serving.engine import compress_ffn_for_serving

    params_c, matvecs, report = compress_ffn_for_serving(
        params, cfg, _fp_compression())
    assert set(matvecs) == {"gate", "up", "down"}
    assert all(len(v) == cfg.n_layers for v in matvecs.values())
    assert report.total_baseline() > 0
    # dense-effective FFN weights replaced, embeddings untouched
    assert not np.array_equal(np.asarray(params_c["blocks"]["ffn"]["gate"]["w"]),
                              np.asarray(params["blocks"]["ffn"]["gate"]["w"]))
    np.testing.assert_array_equal(np.asarray(params_c["embed"]),
                                  np.asarray(params["embed"]))
